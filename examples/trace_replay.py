"""Trace-driven policy comparison: an OSG-shaped day on a federation.

Generates a seeded diurnal trace (the workload the paper's Fig 2/3
evaluate against, synthesized — heavy-tailed runtimes, requirement mix,
correlated user bursts), streams it through the standard 3-backend
federation (static on-prem + billed elastic cloud + cheap reclaimable
spot) under THREE routing policies, and prints the comparison table:
same demand, same completions and core-hours (conservation), different
dollars and wait profiles.

Run:  PYTHONPATH=src python examples/trace_replay.py
"""
from repro.workload import (
    compare, comparison_table, diurnal_day, standard_policies,
)


def main():
    # a 3000-job OSG-shaped day, compressed to 6h so the demo runs fast
    trace = diurnal_day(3000, seed=7, duration_s=6 * 3600.0)
    print(f"trace: {trace.stats()}")

    policies = standard_policies(
        ("fill-first", "cheapest-first", "spot-with-fallback"))
    doc = compare(trace, policies, coalesce_s=10.0)
    print()
    print(comparison_table(doc))

    # every policy must conserve demand — differences are $ and latency
    c = doc["conservation"]
    assert c["ok"], c
    assert c["jobs_completed"] == [3000] * 3
    costs = {name: r["cost_total"] for name, r in doc["policies"].items()}
    waits = {name: r["jobs"]["p95_wait_s"]
             for name, r in doc["policies"].items()}
    print(f"\ncost by policy:     {costs}")
    print(f"p95 wait by policy: {waits}")
    assert costs["cheapest-first"] <= costs["fill-first"] + 1e-6, \
        "cheapest-first should never spend more than fill-first"
    # Fig 2/3-style series are there for plotting
    series = doc["policies"]["cheapest-first"]["series"]
    assert series["idle_jobs"]["t"] and series["provisioned_cores"]["t"]
    print("trace_replay OK")


if __name__ == "__main__":
    main()
