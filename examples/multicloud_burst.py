"""Federated provisioning: one HTCondor pool, three resource providers.

Reproduces the paper's two deployments SIMULTANEOUSLY — the on-prem
PRP/Nautilus cluster (§2–§5) and the GKE deployment with node
auto-provisioning (§6) — plus a spot pool with reclaims, all behind one
provisioner via the ScalingBackend API (the OSG follow-up's
"many heterogeneous providers feeding one pool" scenario):

  onprem  static 2×8-GPU nodes   donated capacity, sunk cost
  cloud   NAP autoscaler, 7-GPU nodes @ $2.50/h, scale-to-zero
  spot    NAP autoscaler, 8-GPU nodes @ $0.80/h, 40% reclaimed mid-burst

Routing is spot-with-fallback after the on-prem pool fills: demand goes
to the cheapest reclaimable capacity first, and preempted jobs fall back
through HTCondor's normal re-matchmaking (§5: preemption is routine).

Run:  PYTHONPATH=src python examples/multicloud_burst.py
"""
from repro.core import Simulation, gpu_job, load_ini

FEDERATION_INI = """\
[provision]
submit_interval_s=30
idle_timeout_s=180
startup_delay_s=30
routing_policy=cheapest-first

[k8s]
priority_class=opportunistic

[backend:onprem]
kind=static
nodes=2
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024

[backend:cloud]
kind=autoscale
capacity_dict=cpu:64,gpu:7,memory:512,disk:1024
max_nodes=6
node_hourly_cost=2.5
provision_delay_s=90
scale_down_delay_s=300

[backend:spot]
kind=autoscale
spot=true
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024
max_nodes=6
node_hourly_cost=0.8
provision_delay_s=90
scale_down_delay_s=300
"""


def main():
    cfg = load_ini(FEDERATION_INI)
    sim = Simulation.from_config(cfg, tick_s=5)
    assert len(sim.backends) == 3

    # burst beyond on-prem (16 slots) AND spot (48 slots) capacity so the
    # on-demand cloud absorbs the tail; then a second wave
    sim.submit_jobs(0, [gpu_job(900, gpus=1) for _ in range(80)])
    sim.submit_jobs(2400, [gpu_job(600, gpus=1) for _ in range(20)])
    # mid-burst the spot provider reclaims 40% of its pods (§5)
    sim.inject_pod_preemption(500, frac=0.4, backend="spot")

    for t in (600, 1200, 1800, 3000):
        sim.run(t)
        r = sim.recorder
        per = " ".join(
            f"{b.name}={b.live_pods():3d}p/{len(b.cluster.nodes)}n"
            for b in sim.backends)
        print(f" t={t:5.0f}s idle={r.last('idle_jobs'):3.0f} "
              f"${r.last('cost_rate') * 3600:5.2f}/h  {per}")

    sim.run_until_drained(max_t=40000)
    s = sim.summary()
    print(f"\ndone at t={sim.now:.0f}s: {s['jobs']['n']} jobs, "
          f"{s['pods_submitted']} pods, total cost ${s['cost_total']:.2f}")
    print(f"{'backend':8s} {'pods':>5s} {'reclaim':>7s} {'cost $':>8s} "
          f"{'waste':>6s} {'gpu-util':>8s}")
    for name, b in s["backends"].items():
        print(f"{name:8s} {b['pods_submitted']:5d} "
              f"{b['pods_reclaimed']:7d} {b['cost']:8.2f} "
              f"{b['waste_fraction']:6.1%} {b['gpu_utilization']:8.1%}")

    assert sim.queue.drained()
    assert s["jobs"]["n"] == 100
    per = sim.provisioner.stats.per_backend_submitted
    assert per.get("onprem", 0) > 0, "on-prem should absorb the base load"
    assert per.get("spot", 0) > 0, "spot is cheapest elastic capacity"
    assert s["backends"]["spot"]["pods_reclaimed"] > 0
    assert s["backends"]["onprem"]["cost"] == 0.0
    assert s["cost_total"] > 0
    print("multicloud_burst OK")


if __name__ == "__main__":
    main()
