"""Elastic, provisioner-managed SPMD training (the paper's technique
applied to data-parallel JAX training).

The training job's DP degree follows the worker pool: the provisioner
scales workers with demand; at each rescale boundary the runner
checkpoints, rebuilds the mesh over the claimed workers, and restores
state with resharding.  Mid-run we also PREEMPT workers (paper §5) and
show training resumes from the checkpoint with no loss excursion.

8 host-platform devices stand in for 8 pod slices.

Run:  PYTHONPATH=src python examples/elastic_train.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.configs import reduced_config                    # noqa: E402
from repro.launch.train import run_elastic                  # noqa: E402


def main():
    cfg = reduced_config("qwen2-1.5b")
    losses = run_elastic(cfg, steps=40, batch=8, seq=64,
                         ckpt_dir="/tmp/elastic_example_ckpt",
                         log_every=5)
    assert losses[-1] < losses[0], "loss must decrease across rescales"
    print(f"elastic training OK: {losses[0]:.2f} -> {losses[-1]:.2f} "
          f"across a 4->8 worker rescale")


if __name__ == "__main__":
    main()
