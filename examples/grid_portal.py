"""Paper §4 operation mode (b): the layered "grid portal".

When a community can't run its own provisioner, the Kubernetes resource
owner stands up a LOCAL dedicated HTCondor pool + a grid interface
(HTCondor-CE); the community's global pool submits PILOTS through the CE;
the local provisioner — knowing nothing about the community — scales
Kubernetes pods for whatever lands in the local queue.

Two queues, two matchmaking layers:
  community pool:  user jobs  ->  pilot factory (GlideinWMS stand-in)
  local pool:      pilot jobs ->  the paper's provisioner -> k8s pods
Pilots, once running, call home and pull user jobs — closing the loop.

Run:  PYTHONPATH=src python examples/grid_portal.py
"""
from repro.core import (
    Collector, Job, JobQueue, ProvisionerConfig, Simulation, gpu_job,
    onprem_nodes,
)


def main():
    # --- local pool at the resource owner, with the paper's provisioner
    local_cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=180,
                                  startup_delay_s=30)
    local = Simulation(local_cfg, nodes=onprem_nodes(4, gpus=8), tick_s=5)

    # --- community global pool: just a queue of user jobs here
    community = JobQueue()
    for _ in range(24):
        community.submit(Job(ad={"request_gpus": 1, "request_cpus": 1,
                                 "request_memory": 4},
                             runtime_s=600), now=0.0)

    # --- pilot factory: submits one PILOT job to the local pool per
    # idle user job (GlideinWMS pressure-based logic, simplified)
    submitted_pilots = [0]

    def pilot_factory(sim: Simulation, now: float):
        idle_users = community.n_idle()
        idle_pilots = sim.queue.n_idle() + sim.queue.n_running()
        deficit = idle_users - idle_pilots
        for _ in range(max(0, deficit)):
            # a pilot is itself a job: when it runs, it pulls user work
            def pilot_work(job, dt, *, q=community):
                # pull-mode: consume user jobs while any remain
                idle = q.idle_jobs()
                if not idle:
                    return True          # pilot exits when queue empty
                j = idle[0]
                q.claim(j.jid, f"pilot-{job.jid}", job.ad.get('_t', 0))
                j.remaining_s -= dt * 20  # pilot runs user payloads
                if j.remaining_s <= 0:
                    q.complete(j.jid, 0)
                else:
                    q.release(j.jid, 0, preempted=False)
                return False

            sim.queue.submit(
                Job(ad={"request_gpus": 1, "request_cpus": 1,
                        "request_memory": 4, "is_pilot": True},
                    runtime_s=1e9, work_fn=pilot_work), now)
            submitted_pilots[0] += 1

    t = 0.0
    while t < 4000:
        local.at(t, pilot_factory, name="pilot-factory")
        t += 60

    local.run(12000)
    done = len(community.completed_log)
    print(f"user jobs completed through the portal: {done}/24")
    print(f"pilots submitted: {submitted_pilots[0]}, "
          f"k8s pods: {local.provisioner.stats.submitted}")
    assert done == 24, "all community jobs must flow through the portal"
    print("grid portal OK")


if __name__ == "__main__":
    main()
