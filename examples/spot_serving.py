"""Serving under spot preemption (paper §5 + §6, inference flavor).

A continuous-batching engine serves requests while the provisioner-style
control loop watches its queue depth as the demand signal.  Mid-run we
simulate a spot reclaim: the engine (worker) dies, queued+in-flight
requests are re-enqueued — exactly how the provisioner's serve workers
recover — and a replacement engine drains the backlog.

Run:  PYTHONPATH=src python examples/spot_serving.py
"""
import numpy as np

import jax

from repro.configs import reduced_config
from repro.models import model as model_lib
from repro.models.param import materialize
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config("granite-8b")
    params = materialize(model_lib.init_model(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                        np.int32),
                    max_new_tokens=4) for i in range(10)]

    engine = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    for r in reqs[:6]:
        engine.submit(r)

    # serve a while, then the spot VM is reclaimed
    for _ in range(6):
        engine.step()
    served_before = len(engine.done)
    print(f"before reclaim: {served_before} done, "
          f"{engine.queue_depth()} queued, {engine.busy_slots()} in flight")

    # reclaim: lose the engine; recover unfinished requests (HTCondor
    # semantics: preempted jobs go back to idle)
    unfinished = [r for r in reqs[:6] if r.rid not in engine.done]
    for r in unfinished:
        r.output = None

    engine2 = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    for r in unfinished + reqs[6:]:
        engine2.submit(r)
    engine2.run_until_drained()

    total = len(engine.done) + len(engine2.done)
    print(f"after recovery: {total}/10 served "
          f"({len(engine2.done)} on the replacement worker)")
    assert total == 10
    print("spot serving OK")


if __name__ == "__main__":
    main()
