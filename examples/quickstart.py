"""Quickstart: the paper's auto-scaling loop end-to-end in 60 seconds.

1. Build a simulated Kubernetes cluster (4 nodes × 8 GPUs).
2. Configure the provisioner from the paper's own INI example (Fig 1).
3. Submit a burst of heterogeneous HTCondor jobs.
4. Watch pods scale up with demand and self-terminate after it drains.
5. Train a real (reduced) JAX model with the same framework underneath.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import io
import sys

from repro.core import (
    PAPER_EXAMPLE_INI, ProvisionerConfig, Simulation, gpu_job, load_ini,
    onprem_nodes,
)


def provisioning_demo():
    print("=== 1. provisioning demo (paper §2) ===")
    cfg = load_ini(PAPER_EXAMPLE_INI)      # the paper's Fig-1 config
    cfg.submit_interval_s = 30
    cfg.idle_timeout_s = 180
    cfg.startup_delay_s = 30
    # the Fig-1 affinity targets labeled GPU nodes
    nodes = onprem_nodes(4, gpus=8,
                         labels={"gpu-type": "A100",
                                 "nautilus.io/low-power": "false"})
    sim = Simulation(cfg, nodes=nodes, tick_s=5)

    sim.submit_jobs(0, [gpu_job(600, gpus=1) for _ in range(12)]
                    + [gpu_job(600, gpus=4) for _ in range(3)])
    sim.submit_jobs(3000, [gpu_job(300, gpus=1) for _ in range(6)])

    marks = [600, 1200, 3600, 6000]
    for t in marks:
        sim.run(t)
        r = sim.recorder
        print(f" t={t:5.0f}s idle_jobs={r.last('idle_jobs'):3.0f} "
              f"pods_running={r.last('running_pods'):3.0f} "
              f"workers_busy={r.last('busy_workers'):3.0f}")
    sim.run_until_drained(max_t=20000)
    s = sim.summary()
    print(f" done at t={sim.now:.0f}s: {s['jobs']['n']} jobs, "
          f"{s['pods_submitted']} pods, "
          f"worker util {s['workers']['utilization']:.0%}, "
          f"mean wait {s['jobs']['mean_wait_s']:.0f}s")
    assert sim.queue.drained() and not sim.collector.workers


def training_demo():
    print("=== 2. real JAX training on the same framework ===")
    from repro.configs import reduced_config
    from repro.launch.train import run_fixed

    losses = run_fixed(reduced_config("granite-8b"), steps=30, batch=8,
                       seq=64, ckpt_dir="/tmp/quickstart_ckpt",
                       log_every=10)
    assert losses[-1] < losses[0]
    print(f" loss {losses[0]:.2f} -> {losses[-1]:.2f} over 30 steps ✓")


if __name__ == "__main__":
    provisioning_demo()
    training_demo()
    print("quickstart OK")
