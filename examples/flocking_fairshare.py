"""Multi-schedd flocking with hierarchical fair-share: three communities,
one federated pool.

The OSG deployments the paper targets serve several communities, each
submitting through its own schedd into one shared HTCondor pool.  This
example splits an OSG-shaped day into three schedds by job kind
(astro / bio / ml as stand-ins), gives them 2:1:1 pool quotas and
per-user priority factors, and replays all three traces CONCURRENTLY on
one event loop into the standard 3-backend federation (static on-prem +
billed elastic cloud + cheap reclaimable spot).

What to look at in the output:

  * the per-schedd wait-time table — the big-quota community waits less
    than its raw demand share would suggest, because the negotiation
    cycle water-fills capacity by usage/quota, not queue depth;
  * conservation — the cross-schedd totals equal the trace's exactly
    (flocking moves work between submit hosts, never loses it);
  * per-user effective priorities — heavy submitters decay back toward
    the base priority once their burst drains.

Run:  PYTHONPATH=src python examples/flocking_fairshare.py
"""
from repro.core import Accountant, ScheddSpec, Simulation, load_ini
from repro.core.metrics import CompletedStats
from repro.workload import diurnal_day, replay_flock, split_trace
from repro.workload.compare import FEDERATION_INI


def main():
    # an OSG-shaped day, compressed to 6h so the demo runs fast
    trace = diurnal_day(3000, seed=7, duration_s=6 * 3600.0)
    parts = split_trace(trace, by="group", n_schedds=3)
    print(f"trace: {trace.stats()}")
    for name, part in parts.items():
        groups = sorted({r.group for r in part.records})
        print(f"  {name}: {len(part)} jobs from {groups}")

    # 2:1:1 quotas; the first schedd's heaviest submitter is deprioritized
    specs = [ScheddSpec("schedd00", quota=2.0),
             ScheddSpec("schedd01", quota=1.0),
             ScheddSpec("schedd02", quota=1.0)]
    acct = Accountant(half_life_s=6 * 3600.0)
    acct.set_priority_factor("user00", 2.0)

    cfg = load_ini(FEDERATION_INI.format(
        routing="cheapest-first", onprem_nodes=4,
        cloud_max_nodes=24, spot_max_nodes=24))
    sim = Simulation.from_config(
        cfg, schedds=specs, fairshare=acct, tick_s=30,
        negotiate_interval_s=60, metrics_interval_s=300)

    replayers = replay_flock(sim, parts, coalesce_s=10.0,
                             compact_completed=True)
    sim.run_until_drained(max_t=5e6)
    assert sim.drained(), "flocking replay failed to drain"

    print(f"\n{'schedd':<10s} {'jobs':>6s} {'mean wait':>10s} "
          f"{'p95 wait':>9s} {'quota':>6s}")
    merged = CompletedStats()
    for spec in specs:
        done = replayers[spec.name].stats.completed
        merged.merge(done)
        s = done.summary()
        print(f"{spec.name:<10s} {s['n']:>6d} {s['mean_wait_s']:>9.0f}s "
              f"{s['p95_wait_s']:>8.0f}s {spec.quota:>6.1f}")

    # cross-schedd conservation: the federation completed the exact day
    assert merged.n == len(trace), (merged.n, len(trace))
    expect = trace.total_core_seconds()
    assert abs(merged.core_seconds - expect) <= 1e-6 * expect, \
        "core-hour conservation violated across schedds"
    print(f"\nconservation OK: {merged.n} jobs, "
          f"{merged.core_seconds / 3600.0:.1f} core-hours across "
          f"{len(specs)} schedds")

    snap = sim.accountant.snapshot(sim.now)
    heavy = snap["users"].get("user00")
    print(f"user00 (factor 2.0) effective priority at drain: "
          f"{heavy['effective_priority']:.2f}")
    print("per-schedd deficit gauges:",
          {name: round(sim.recorder.schedd_values('deficit', name)[-1], 1)
           for name in sim.recorder.schedds_recorded()})
    print("flocking_fairshare OK")


if __name__ == "__main__":
    main()
