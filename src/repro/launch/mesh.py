"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init, and
smoke tests must keep seeing 1 device.

Geometry (TPU v5e pods): a pod is 16×16 = 256 chips; the multi-pod mesh
stacks 2 pods on a leading "pod" axis connected over DCN.  Axis meaning:

  pod    — data parallelism across pods (DCN: gradient sync only;
           the MoE all-to-all and TP collectives never cross it)
  data   — in-pod data parallelism / FSDP / expert parallelism (ICI)
  model  — tensor parallelism (ICI)
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_worker_mesh(n_devices: int | None = None, *,
                     model_parallel: int = 1):
    """Small mesh over locally visible devices (examples / elastic workers).
    data axis = n_devices / model_parallel."""
    devs = jax.devices()
    n = n_devices or len(devs)
    assert n % model_parallel == 0
    arr = np.array(devs[:n]).reshape(n // model_parallel, model_parallel)
    return jax.sharding.Mesh(arr, ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
