import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
then ``.compile()`` under the production mesh.  Sharding mismatches, OOMs
at compile, and unsupported collectives all surface here as bugs.

Per compiled cell we record (for EXPERIMENTS.md §Dry-run / §Roofline):
  * memory_analysis(): per-device argument/output/temp/peak bytes
  * cost_analysis():   HLO FLOPs and bytes accessed
  * collective bytes:  parsed from the optimized HLO — per-op wire-byte
    model documented in `collective_bytes_from_hlo`

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import (
    ARCH_NAMES, SHAPES, applicable, get_config, input_specs,
)
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.models.param import abstract_values, axes_tree
from repro.parallel.sharding import (
    batch_spec, constrainer, logical_to_spec, param_sharding_tree,
    rules_for, spec_tree,
)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainState, make_train_step

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e): roofline denominators
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (≈ per-chip usable)
DCN_BW = 25e9                # bytes/s per chip across pods (2× 100GbE-ish)


# ---------------------------------------------------------------------------
# Sharding construction per cell
# ---------------------------------------------------------------------------

def param_shardings(cfg: ModelConfig, mesh: Mesh, workload: str,
                    rules_name: str | None = None):
    if rules_name:
        from repro.parallel.sharding import preset
        rules = preset(rules_name)
    else:
        rules = rules_for(cfg, workload)
    ptree = model_lib.init_model(cfg)
    axes = axes_tree(ptree)
    return param_sharding_tree(ptree, rules, mesh), rules, axes


def _shardable(dim: int, mesh: Mesh, ax: str) -> bool:
    return ax in mesh.shape and dim % mesh.shape[ax] == 0 and dim > 0


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_spec, B: int):
    """Sharding tree for the decode cache: batch over ("pod","data") when
    divisible; heads over "model" when divisible, else the seq/capacity
    axis; B==1 long-context cells shard capacity over ("data","model")
    (sequence-parallel decode)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    psize = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    b_ok = B % psize == 0 if psize > 1 else False

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        bdim = batch_axes if b_ok else None
        if name in ("k", "v"):  # (n_scan, B, C, Hkv, Dh)
            _, _, C, Hkv, _ = shape
            if not b_ok:
                seq_ax = tuple(a for a in ("data", "model")
                               if _shardable(C, mesh, a))
                return P(None, None, seq_ax or None, None, None)
            if _shardable(Hkv, mesh, "model"):
                return P(None, bdim, None, "model", None)
            if _shardable(C, mesh, "model"):
                return P(None, bdim, "model", None, None)
            return P(None, bdim, None, None, None)
        if name == "pos":       # (n_scan, B, C)
            return P(None, bdim, None)
        if name == "conv":      # (n_scan, B, K-1, conv_ch)
            ch = shape[-1]
            m = "model" if _shardable(ch, mesh, "model") else None
            return P(None, bdim, None, m)
        if name == "ssm":       # (n_scan, B, H, P, N)
            H = shape[2]
            m = "model" if _shardable(H, mesh, "model") else None
            return P(None, bdim, m, None, None)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_spec)
    return jax.tree_util.tree_unflatten(
        treedef,
        [NamedSharding(mesh, leaf_spec(p, l)) for p, l in flat],
    )


def batch_shardings_for(cfg: ModelConfig, mesh: Mesh, specs: dict, B: int):
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    psize = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    bdim = batch_axes if (psize > 1 and B % psize == 0) else None
    return {
        k: NamedSharding(mesh, P(bdim, *([None] * (len(v.shape) - 1))))
        for k, v in specs.items()
    }


# ---------------------------------------------------------------------------
# Step builders (what gets lowered)
# ---------------------------------------------------------------------------

def build_train_lowerable(cfg: ModelConfig, mesh: Mesh, cell, *,
                          remat: str = "full", accum_steps: int = 1,
                          grad_compression: str | None = None,
                          unroll: bool = False, rules_name: str | None = None):
    p_sh, rules, axes = param_shardings(cfg, mesh, "train", rules_name)
    opt_cfg = OptimizerConfig(
        state_dtype=cfg.optimizer_state_dtype,
        # under the bf16 state policy (400B MoE) nu is bf16 too — fp32 nu
        # alone would add 3.1 GB/chip and blow the 16 GB v5e budget
        keep_nu_fp32=cfg.optimizer_state_dtype != "bfloat16",
    )
    step = make_train_step(
        cfg, opt_cfg, mesh, rules, accum_steps=accum_steps, remat=remat,
        grad_compression=grad_compression, param_axes=axes, unroll=unroll,
    )

    abstract_params = abstract_values(model_lib.init_model(cfg))
    mu_dt = jnp.dtype(opt_cfg.state_dtype)
    state = TrainState(
        params=abstract_params,
        opt={
            "mu": jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(p.shape, mu_dt),
                abstract_params),
            "nu": jax.tree_util.tree_map(
                lambda p: jax.ShapeDtypeStruct(
                    p.shape,
                    jnp.float32 if opt_cfg.keep_nu_fp32 else mu_dt),
                abstract_params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        },
        step=jax.ShapeDtypeStruct((), jnp.int32),
        rng=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    rep = NamedSharding(mesh, P())
    state_sh = TrainState(
        params=p_sh,
        opt={"mu": p_sh, "nu": p_sh, "count": rep},
        step=rep, rng=rep,
    )
    b_specs = input_specs(cfg, cell)
    b_sh = batch_shardings_for(cfg, mesh, b_specs, cell.global_batch)
    metrics_sh = {
        k: rep for k in ("loss", "ce", "z_loss", "moe_aux", "tokens",
                          "grad_norm", "clip_factor", "lr")
    }
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, b_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),   # state updates in place: halves peak HBM
    )
    return jitted, (state, b_specs)


def build_prefill_lowerable(cfg: ModelConfig, mesh: Mesh, cell, *,
                            unroll: bool = False):
    p_sh, rules, _ = param_shardings(cfg, mesh, "prefill")
    constrain = constrainer(rules, mesh)
    B, S = cell.global_batch, cell.seq_len

    def prefill_step(params, batch, cache):
        return model_lib.prefill(params, cfg, batch, cache, mesh=mesh,
                                 constrain=constrain, unroll=unroll)

    abstract_params = abstract_values(model_lib.init_model(cfg))
    b_specs = input_specs(cfg, cell)
    cache_spec = model_lib.init_cache(cfg, B, S, abstract=True)
    cache_sh = cache_shardings(cfg, mesh, cache_spec, B)
    b_sh = batch_shardings_for(cfg, mesh, b_specs, B)
    bdim = next(iter(b_sh.values())).spec[0]
    logits_sh = NamedSharding(
        mesh, P(bdim, "model" if cfg.vocab_size % mesh.shape["model"] == 0
                else None))
    rep = NamedSharding(mesh, P())
    jitted = jax.jit(
        prefill_step,
        in_shardings=(p_sh, b_sh, cache_sh),
        out_shardings=(logits_sh, cache_sh, rep),
        donate_argnums=(2,),   # cache fills in place
    )
    return jitted, (abstract_params, b_specs, cache_spec)


def build_decode_lowerable(cfg: ModelConfig, mesh: Mesh, cell, *,
                           unroll: bool = False):
    workload = "decode_long" if cell.name == "long_500k" else "decode"
    p_sh, rules, _ = param_shardings(cfg, mesh, workload)
    constrain = constrainer(rules, mesh)
    B, S = cell.global_batch, cell.seq_len

    def serve_step(params, tokens_t, cache, lengths):
        return model_lib.decode_step(params, cfg, tokens_t, cache, lengths,
                                     mesh=mesh, constrain=constrain,
                                     unroll=unroll)

    abstract_params = abstract_values(model_lib.init_model(cfg))
    specs = input_specs(cfg, cell)
    cache_sh = cache_shardings(cfg, mesh, specs["cache"], B)
    tok_sh = batch_shardings_for(
        cfg, mesh, {"tokens_t": specs["tokens_t"]}, B)["tokens_t"]
    bdim = tok_sh.spec[0]
    len_sh = NamedSharding(mesh, P(bdim))
    logits_sh = NamedSharding(
        mesh, P(bdim, "model" if cfg.vocab_size % mesh.shape["model"] == 0
                else None))
    jitted = jax.jit(
        serve_step,
        in_shardings=(p_sh, tok_sh, cache_sh, len_sh),
        out_shardings=(logits_sh, cache_sh, len_sh),
        donate_argnums=(2,),   # cache updates in place
    )
    return jitted, (abstract_params, specs["tokens_t"], specs["cache"],
                    specs["lengths"])


def build_lowerable(cfg, mesh, cell, *, unroll=False, **kw):
    if cell.kind == "train":
        return build_train_lowerable(cfg, mesh, cell, unroll=unroll, **kw)
    if cell.kind == "prefill":
        return build_prefill_lowerable(cfg, mesh, cell, unroll=unroll)
    return build_decode_lowerable(cfg, mesh, cell, unroll=unroll)


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^=]*\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b"
)


def collective_bytes_from_hlo(hlo: str) -> dict[str, float]:
    """Per-collective wire bytes (per device), from the optimized HLO.

    Model (ring algorithms, factor (N-1)/N ≈ 1 folded in):
      all-reduce         2 × result bytes   (reduce-scatter + all-gather)
      all-gather         1 × result bytes
      reduce-scatter     1 × operand ≈ result × N ... we see the *result*
                         shape, so ≈ result bytes × 1 (already scattered)
      all-to-all         1 × result bytes
      collective-permute 1 × result bytes
    Result shapes in the SPMD-partitioned module are per-device.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for m in _COLL_RE.finditer(hlo):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dtype]
        mult = 2.0 if kind == "all-reduce" else 1.0
        out[kind] += mult * nbytes
    out["total"] = sum(out.values())
    return out


def analyze_compiled(lowered, compiled, mesh: Mesh, cfg: ModelConfig,
                     cell) -> dict[str, Any]:
    chips = mesh_chip_count(mesh)
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "peak_memory_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    # Roofline terms (seconds). The SPMD module is per-device: cost_analysis
    # FLOPs/bytes are already per-device.
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll["total"] / ICI_BW

    # tokens processed per step
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
    else:
        tokens = cell.global_batch  # one token per sequence

    n_active = cfg.active_param_count_estimate()
    model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
    model_flops_per_chip = model_flops / chips

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    return {
        "arch": cfg.name,
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": dict(mesh.shape),
        "chips": chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll,
        "memory": mem,
        "roofline": {
            **terms,
            "bottleneck": bottleneck,
            "model_flops_per_chip": model_flops_per_chip,
            "useful_flop_ratio": (model_flops_per_chip / flops
                                  if flops > 0 else 0.0),
            "step_time_lower_bound_s": max(terms.values()),
            "roofline_fraction": (
                min(1.0, model_flops_per_chip / PEAK_FLOPS /
                    max(terms.values())) if max(terms.values()) > 0 else 0.0
            ),
        },
    }


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def _depth_variants(cfg: ModelConfig):
    """(variant_cfgs, extrapolate) for exact while-free cost accounting.

    XLA cost analysis counts a while-loop body ONCE, so the production
    scan build under-reports FLOPs/bytes/collectives by ~n_scan.  We lower
    fully-unrolled variants at depth 1×period and 2×period (and, for
    enc-dec, 1×/2× encoder depth) and extrapolate linearly — exact because
    the stack is homogeneous in depth.
    """
    import dataclasses as dc

    p = cfg.period
    if cfg.encoder is None:
        v1 = dc.replace(cfg, n_layers=p)
        v2 = dc.replace(cfg, n_layers=2 * p)

        def extrapolate(costs):
            c1, c2 = costs
            # clamp: fusion differences can make c2<c1 on tiny terms; a
            # negative per-layer body would extrapolate below zero
            body = {k: max(c2[k] - c1[k], 0.0) for k in c1}
            return {k: c1[k] + (cfg.n_scan - 1) * body[k] for k in c1}

        return [v1, v2], extrapolate

    enc = cfg.encoder
    v11 = dc.replace(cfg, n_layers=p,
                     encoder=dc.replace(enc, n_layers=1))
    v21 = dc.replace(cfg, n_layers=2 * p,
                     encoder=dc.replace(enc, n_layers=1))
    v12 = dc.replace(cfg, n_layers=p,
                     encoder=dc.replace(enc, n_layers=2))

    def extrapolate(costs):
        c11, c21, c12 = costs
        dec_body = {k: c21[k] - c11[k] for k in c11}
        enc_body = {k: c12[k] - c11[k] for k in c11}
        return {
            k: c11[k] + (cfg.n_scan - 1) * dec_body[k]
            + (enc.n_layers - 1) * enc_body[k]
            for k in c11
        }

    return [v11, v21, v12], extrapolate


def _cost_of(compiled) -> dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_ar": coll["all-reduce"],
        "coll_ag": coll["all-gather"],
        "coll_rs": coll["reduce-scatter"],
        "coll_a2a": coll["all-to-all"],
        "coll_cp": coll["collective-permute"],
    }


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             verbose: bool = True, analysis: bool = True,
             **build_kw) -> dict[str, Any]:
    from repro.kernels.flash_attention import ops as fa_ops

    cfg = get_config(arch)
    cell = SHAPES[shape]
    runs, reason = applicable(cfg, cell)
    if not runs:
        return {"arch": arch, "cell": shape, "skipped": True,
                "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)

    # -- phase 1: production scan build — proves compile, gives memory ----
    t0 = time.time()
    with mesh:
        jitted, args = build_lowerable(cfg, mesh, cell, **build_kw)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        result = analyze_compiled(lowered, compiled, mesh, cfg, cell)
    result["lower_s"] = round(t_lower, 1)
    result["compile_s"] = round(t_compile, 1)

    # -- phase 2: unrolled depth variants — exact cost extrapolation ------
    if analysis:
        t0 = time.time()
        variants, extrapolate = _depth_variants(cfg)
        costs = []
        fa_ops.FORCE_REFERENCE = True
        try:
            jax.clear_caches()  # flag affects traced code: drop stale traces
            for vcfg in variants:
                with mesh:
                    vj, vargs = build_lowerable(vcfg, mesh, cell,
                                                unroll=True, **build_kw)
                    vc = vj.lower(*vargs).compile()
                    costs.append(_cost_of(vc))
        finally:
            fa_ops.FORCE_REFERENCE = False
            jax.clear_caches()
        full = extrapolate(costs)
        # the microbatch-accumulation scan is a while loop too (body
        # counted once): scale by accum_steps (slight overcount of the
        # once-per-step optimizer tail — conservative direction)
        accum = build_kw.get("accum_steps", 1) or 1
        if accum > 1:
            full = {k: v * accum for k, v in full.items()}
        chips = mesh_chip_count(mesh)
        mf = result["roofline"]["model_flops_per_chip"]

        def mk_terms(flops, nbytes, coll):
            terms = {"compute_s": flops / PEAK_FLOPS,
                     "memory_s": nbytes / HBM_BW,
                     "collective_s": coll / ICI_BW}
            return {
                **terms,
                "bottleneck": max(terms, key=terms.get),
                "hlo_flops_per_chip": flops,
                "hlo_bytes_per_chip": nbytes,
                "useful_flop_ratio": mf / flops if flops else 0.0,
                "step_time_lower_bound_s": max(terms.values()),
                "roofline_fraction": (
                    min(1.0, mf / PEAK_FLOPS / max(terms.values()))
                    if max(terms.values()) > 0 else 0.0
                ),
            }

        coll_detail = {
            "total": full["coll"], "all-reduce": full["coll_ar"],
            "all-gather": full["coll_ag"],
            "reduce-scatter": full["coll_rs"],
            "all-to-all": full["coll_a2a"],
            "collective-permute": full["coll_cp"],
        }
        result["roofline_extrapolated"] = {
            **mk_terms(full["flops"], full["bytes"], full["coll"]),
            "collective_bytes_per_chip": coll_detail,
        }
        # kernel-adjusted: reference attention/SSD cost swapped for the
        # Pallas kernels' streaming model (see roofline_adjust.py)
        from repro.launch.roofline_adjust import kernel_adjusted

        adj = kernel_adjusted(
            {"flops": full["flops"], "bytes": full["bytes"]}, cfg, cell,
            chips)
        result["roofline_kernel_adjusted"] = {
            **mk_terms(adj["flops"], adj["bytes"], full["coll"]),
            "collective_bytes_per_chip": coll_detail,
            "adjustment": {k: v for k, v in adj.items()
                           if k not in ("flops", "bytes")},
        }
        result["analysis_s"] = round(time.time() - t0, 1)
    if verbose:
        ma = result["memory"]
        peak = ma.get("peak_memory_in_bytes",
                      ma.get("temp_size_in_bytes", 0))
        r = result.get("roofline_kernel_adjusted",
                       result.get("roofline_extrapolated",
                                  result["roofline"]))
        print(
            f"[dryrun] {arch} × {shape} × {'2x16x16' if multi_pod else '16x16'}"
            f" OK  lower={t_lower:.0f}s compile={t_compile:.0f}s"
            f" flops/chip={r.get('hlo_flops_per_chip', 0):.3g}"
            f" bytes/chip={r.get('hlo_bytes_per_chip', 0):.3g}"
            f" coll/chip={r.get('collective_bytes_per_chip', {}).get('total', 0):.3g}"
            f" peak={peak/2**30:.1f}GiB"
            f" bottleneck={r['bottleneck']}"
            f" roofline={r['roofline_fraction']:.2%}"
        )
        print("  memory_analysis:", json.dumps(ma))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_NAMES)
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--out", default=None, help="output dir for JSON")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--rules", default=None,
                    help="sharding preset override (e.g. zero3, zero3_ep)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            kw = {}
            if SHAPES[shape].kind == "train":
                kw = dict(remat=args.remat, accum_steps=args.accum_steps,
                          grad_compression=args.grad_compression,
                          rules_name=args.rules)
            try:
                # multi-pod pass proves the "pod" axis shards; the roofline
                # analysis (unrolled variants) is single-pod only
                res = run_cell(arch, shape, multi_pod=mp, analysis=not mp,
                               **kw)
            except Exception as e:
                res = {"arch": arch, "cell": shape, "multi_pod": mp,
                       "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] {arch} × {shape} FAILED: {e}")
            res["multi_pod"] = mp
            results.append(res)
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                suffix = "multi" if mp else "single"
                fn = os.path.join(
                    args.out, f"{arch}_{shape}_{suffix}.json")
                with open(fn, "w") as f:
                    json.dump(res, f, indent=1)
    n_err = sum(1 for r in results if "error" in r)
    print(f"[dryrun] done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
