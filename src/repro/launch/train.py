"""Training launcher: fixed-mesh or provisioner-managed (elastic) mode.

Fixed mode is the classic driver: build mesh → init sharded state → step
loop with async checkpoints.

Elastic mode is the paper's technique applied to SPMD training: the
training job advertises its demand to the JobQueue as *work units*; the
Provisioner scales a pool of workers (here: local device groups standing
in for pod slices); at every rescale boundary the runner re-materializes
the mesh from the currently-claimed workers and restores state onto it via
the checkpoint manager (reshard-on-restore).  Preemption of a worker mid-
step is tolerated: the job falls back to the last checkpoint, exactly the
fault model of paper §5.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-1.3b --reduced \
      --elastic --steps 60
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticTokenPipeline, stub_modality_inputs
from repro.launch.mesh import make_worker_mesh
from repro.models import model as model_lib
from repro.models.param import abstract_values, axes_tree, materialize
from repro.parallel.sharding import named_sharding_tree, rules_for
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (
    TrainState, init_train_state, make_train_step, state_shardings,
)


def build_state(cfg, mesh, rules, opt_cfg, seed=0):
    ptree = model_lib.init_model(cfg)
    axes = axes_tree(ptree)
    shardings = state_shardings(ptree, rules, mesh)

    def init_fn(rng):
        params = materialize(model_lib.init_model(cfg), rng)
        return init_train_state(params, opt_cfg, rng)

    with mesh:
        state = jax.jit(
            init_fn, out_shardings=shardings
        )(jax.random.PRNGKey(seed))
    return state, shardings, axes


def make_batch(cfg, pipe, step, mesh, batch):
    b = pipe.jax_batch_at(step, mesh)
    extra = stub_modality_inputs(cfg, batch)
    for k, v in extra.items():
        b[k] = jnp.asarray(v)
    if cfg.frontend is not None:
        # trim text so prefix+text == seq budget is respected by the model
        pass
    return b


def run_fixed(cfg, *, steps, batch, seq, ckpt_dir, model_parallel=1,
              log_every=10, ckpt_every=20):
    mesh = make_worker_mesh(model_parallel=model_parallel)
    rules = rules_for(cfg, "train")
    opt_cfg = OptimizerConfig(state_dtype=cfg.optimizer_state_dtype,
                              lr=1e-3)
    state, shardings, axes = build_state(cfg, mesh, rules, opt_cfg)
    step_fn = make_train_step(
        cfg, opt_cfg, mesh, rules, remat="none", param_axes=axes,
        lr_kwargs=dict(peak=1e-3, warmup_steps=10, total_steps=steps),
    )
    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(cfg.vocab_size, seq, batch)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None

    losses = []
    t0 = time.time()
    with mesh:
        for i in range(steps):
            b = make_batch(cfg, pipe, i, mesh, batch)
            state, metrics = jit_step(state, b)
            if i % log_every == 0 or i == steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                print(f"step {i:4d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({(time.time()-t0):.1f}s)")
            if mgr and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, {"params": state.params, "opt": state.opt,
                                 "step": state.step})
    if mgr:
        mgr.wait()
    return losses


def run_elastic(cfg, *, steps, batch, seq, ckpt_dir, log_every=10):
    """Provisioner-managed training: the worker pool size follows demand;
    rescale happens at checkpoint boundaries with state resharding.
    Demonstrated over host-platform devices standing in for slices."""
    from repro.core import (
        Collector, Job, JobQueue, KubeCluster, Provisioner,
        ProvisionerConfig, onprem_nodes,
    )

    n_dev = len(jax.devices())
    queue, collector = JobQueue(), Collector()
    cluster = KubeCluster(onprem_nodes(1, gpus=n_dev, cpus=64))
    pcfg = ProvisionerConfig(submit_interval_s=1, idle_timeout_s=30,
                             startup_delay_s=0, job_filter="")
    prov = Provisioner(pcfg, queue, collector, cluster)

    # the training job advertises one work unit per desired DP shard
    demand_schedule = {0: max(1, n_dev // 2), steps // 2: n_dev}
    opt_cfg = OptimizerConfig(state_dtype=cfg.optimizer_state_dtype, lr=1e-3)
    mgr = CheckpointManager(ckpt_dir, async_mode=False)
    pipe = SyntheticTokenPipeline(cfg.vocab_size, seq, batch)

    now = 0.0
    active_workers = 0
    state = mesh = jit_step = None
    losses = []

    def want_workers(i):
        w = 1
        for at, n in demand_schedule.items():
            if i >= at:
                w = n
        return w

    i = 0
    while i < steps:
        # --- control plane tick: jobs express demand, provisioner scales
        target = want_workers(i)
        idle_or_running = queue.n_idle() + queue.n_running()
        for _ in range(max(0, target - idle_or_running)):
            queue.submit(Job(ad={"request_gpus": 1, "arch": cfg.name},
                             runtime_s=1e9), now)
        prov.maybe_reconcile(now)
        cluster.schedule(now)
        collector.run_cycle(queue, now)
        n_claimed = sum(1 for w in collector.workers.values() if w.claimed)
        now += 2.0

        # --- rescale boundary: mesh follows the claimed-worker count
        usable = max(1, 1 << (n_claimed.bit_length() - 1)) if n_claimed else 0
        usable = min(usable, n_dev)
        if usable and usable != active_workers:
            print(f"[elastic] rescale: {active_workers} -> {usable} workers "
                  f"(claimed={n_claimed})")
            mesh = make_worker_mesh(usable)
            rules = rules_for(cfg, "train")
            ptree = model_lib.init_model(cfg)
            axes = axes_tree(ptree)
            shardings = state_shardings(ptree, rules, mesh)
            if state is None:
                state, shardings, axes = build_state(
                    cfg, mesh, rules, opt_cfg)
            else:
                # checkpoint -> restore onto the NEW mesh (resharding)
                mgr.save(i, {"params": state.params, "opt": state.opt},
                         blocking=True)
                tgt = {
                    "params": abstract_values(model_lib.init_model(cfg)),
                    "opt": jax.tree_util.tree_map(
                        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                        state.opt),
                }
                restored = mgr.restore(
                    mgr.latest_step(), tgt,
                    {"params": shardings.params, "opt": shardings.opt},
                )
                state = TrainState(
                    params=restored["params"], opt=restored["opt"],
                    step=jnp.asarray(i, jnp.int32),
                    rng=jax.random.PRNGKey(0),
                )
            step_fn = make_train_step(
                cfg, opt_cfg, mesh, rules, remat="none", param_axes=axes,
                lr_kwargs=dict(peak=1e-3, warmup_steps=10,
                               total_steps=steps),
            )
            jit_step = jax.jit(step_fn, donate_argnums=(0,))
            active_workers = usable

        if not active_workers:
            continue

        # --- one training step on the current mesh
        with mesh:
            b = make_batch(cfg, pipe, i, mesh, batch)
            state, metrics = jit_step(state, b)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"step {i:4d} loss {loss:8.4f} workers={active_workers}")
        i += 1
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--elastic", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.elastic:
        run_elastic(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                    ckpt_dir=args.ckpt_dir)
    else:
        run_fixed(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                  ckpt_dir=args.ckpt_dir,
                  model_parallel=args.model_parallel)


if __name__ == "__main__":
    main()
