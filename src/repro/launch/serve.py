"""Serving launcher: batched engine, optionally provisioner-managed.

Plain mode runs the continuous-batching ServeEngine on a reduced config.
Provisioned mode wires the engine queue depth into the JobQueue as demand
(one job per queued request batch) so the Provisioner scales serve workers
exactly the way it scales HTCondor execute pods — the paper's §2 logic with
"jobs" = inference requests.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as model_lib
from repro.models.param import materialize
from repro.serve.engine import Request, ServeEngine


def run_serve(cfg, *, n_requests: int, slots: int = 4, max_seq: int = 128,
              max_new: int = 8, seed: int = 0):
    rng = np.random.default_rng(seed)
    params = materialize(model_lib.init_model(cfg), jax.random.PRNGKey(seed))
    engine = ServeEngine(cfg, params, batch_slots=slots, max_seq=max_seq)

    t0 = time.time()
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, max_seq // 4))
        engine.submit(Request(rid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=max_new))
    ticks = engine.run_until_drained()
    dt = time.time() - t0

    done = engine.done
    toks = sum(len(r.output or []) for r in done.values())
    print(f"[serve] {len(done)}/{n_requests} requests, {toks} tokens, "
          f"{ticks} ticks, {dt:.1f}s ({toks/dt:.1f} tok/s)")
    lat = [r.finished_at - r.submitted_at for r in done.values()]
    print(f"[serve] latency mean={np.mean(lat):.2f}s p95="
          f"{np.percentile(lat, 95):.2f}s")
    return engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    run_serve(cfg, n_requests=args.requests, slots=args.slots,
              max_seq=args.max_seq, max_new=args.max_new)


if __name__ == "__main__":
    main()
