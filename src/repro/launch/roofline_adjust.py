"""Kernel-adjusted roofline terms.

The dry-run lowers on the CPU backend, where the attention/SSD compute is
the pure-jnp *reference* (Pallas TPU kernels cannot lower there).  The
reference materializes O(Sq×Skv) score tensors in HBM and computes the full
rectangle of QK^T/PV FLOPs; the production Pallas kernels (a) keep scores
in VMEM — HBM traffic is just the q/k/v/o streams — and (b) skip fully
masked blocks (≈½ the FLOPs for causal training, window/S for local
layers).

This module swaps the reference's measured cost for the kernel's modeled
cost, per call site:

  adjusted = raw  −  Σ_sites ref_cost(site)  +  Σ_sites kernel_cost(site)

``ref_cost`` is CALIBRATED, not hand-derived: we lower+compile the actual
reference function (and its grad, for training) at a small shape and
divide by the score-element count; linearity in score elements makes the
factor exact up to boundary terms.  ``kernel_cost`` is the analytic
streaming model (io bytes; matmul FLOPs × masked-block fraction).

All counts are per-chip under idealized even sharding: total/chips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeCell
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.ssd.ops import ssd_chunked_jnp
from repro.models.config import ModelConfig

_AD = jnp.bfloat16  # activation dtype on the wire


# ---------------------------------------------------------------------------
# Calibration (cached per process)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _calibrate_attention() -> dict[str, float]:
    """Per-score-element flops/bytes of the dense reference, fwd and grad."""
    B, Sq, Skv, Hq, Hkv, Dh = 2, 256, 512, 4, 2, 64
    elems = B * Hq * Sq * Skv
    q = jax.ShapeDtypeStruct((B, Sq, Hq, Dh), _AD)
    k = jax.ShapeDtypeStruct((B, Skv, Hkv, Dh), _AD)
    v = jax.ShapeDtypeStruct((B, Skv, Hkv, Dh), _AD)
    qp = jax.ShapeDtypeStruct((B, Sq), jnp.int32)
    kp = jax.ShapeDtypeStruct((B, Skv), jnp.int32)

    def fwd(q, k, v, qp, kp):
        return attention_reference(q, k, v, qp, kp, causal=True)

    def loss(q, k, v, qp, kp):
        return jnp.sum(
            attention_reference(q, k, v, qp, kp, causal=True)
            .astype(jnp.float32))

    def cost(fn):
        c = jax.jit(fn).lower(q, k, v, qp, kp).compile().cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return (float(c.get("flops", 0)), float(c.get("bytes accessed", 0)))

    f_fwd, b_fwd = cost(fwd)
    f_grad, b_grad = cost(jax.grad(loss, argnums=(0, 1, 2)))
    return {
        "f_fwd": f_fwd / elems, "b_fwd": b_fwd / elems,
        "f_grad": f_grad / elems, "b_grad": b_grad / elems,
        "dh": Dh,
    }


@functools.lru_cache(maxsize=None)
def _calibrate_ssd() -> dict[str, float]:
    """Per-intra-chunk-element flops/bytes of the chunked-jnp SSD."""
    B, S, H, P, G, N, Q = 2, 512, 4, 64, 1, 64, 128
    nc = S // Q
    elems = B * nc * Q * Q * H
    x = jax.ShapeDtypeStruct((B, S, H, P), _AD)
    dt = jax.ShapeDtypeStruct((B, S, H), jnp.float32)
    A = jax.ShapeDtypeStruct((H,), jnp.float32)
    Bm = jax.ShapeDtypeStruct((B, S, G, N), _AD)
    Cm = jax.ShapeDtypeStruct((B, S, G, N), _AD)
    D = jax.ShapeDtypeStruct((H,), jnp.float32)

    def fwd(x, dt, A, Bm, Cm, D):
        y, _ = ssd_chunked_jnp(x, dt, A, Bm, Cm, D, chunk=Q)
        return y

    def loss(x, dt, A, Bm, Cm, D):
        return jnp.sum(fwd(x, dt, A, Bm, Cm, D).astype(jnp.float32))

    def cost(fn):
        c = (jax.jit(fn).lower(x, dt, A, Bm, Cm, D).compile()
             .cost_analysis())
        if isinstance(c, (list, tuple)):
            c = c[0]
        return (float(c.get("flops", 0)), float(c.get("bytes accessed", 0)))

    f_fwd, b_fwd = cost(fwd)
    f_grad, b_grad = cost(jax.grad(loss, argnums=(0, 1, 3, 4)))
    return {
        "f_fwd": f_fwd / elems, "b_fwd": b_fwd / elems,
        "f_grad": f_grad / elems, "b_grad": b_grad / elems,
    }


# ---------------------------------------------------------------------------
# Call-site enumeration
# ---------------------------------------------------------------------------

def _causal_fraction(S: int, window: int | None) -> float:
    """Fraction of the Sq×Skv rectangle the kernel actually computes."""
    if window is None or window >= S:
        return 0.5 + 0.5 / max(S, 1)
    w = window
    # rows 0..w-1 see i+1 keys; rows w..S-1 see w keys
    total = w * (w + 1) / 2 + (S - w) * w
    return total / (S * S)


def attention_sites(cfg: ModelConfig, cell: ShapeCell):
    """Yield (elems_full, frac_eff, io_bytes, train?) per step, global
    (pre-division by chips). Covers decoder self-attn, encoder self-attn,
    and cross-attention; decode covers the cache-read row."""
    B = cell.global_batch
    Dh = cfg.d_head
    sites = []
    train = cell.kind == "train"

    if cell.kind in ("train", "prefill"):
        Sq = cell.seq_len
        for i in range(cfg.n_layers):
            if cfg.mixer_kind(i) != "attn":
                continue
            w = (cfg.attn_window
                 if cfg.attn_window is not None
                 and not cfg.layer_uses_global_attn(i) else None)
            elems = B * cfg.n_heads * Sq * Sq
            frac = _causal_fraction(Sq, w)
            io = (2 * B * Sq * cfg.n_heads * Dh
                  + 2 * B * Sq * cfg.n_kv_heads * Dh) * 2
            sites.append((elems, frac, io, train))
        if cfg.encoder is not None:
            F = cfg.encoder.n_frames
            for _ in range(cfg.encoder.n_layers):
                elems = B * cfg.n_heads * F * F
                io = 4 * B * F * cfg.n_heads * Dh * 2
                sites.append((elems, 1.0, io, train))
            for _ in range(cfg.n_layers):  # cross-attn q=Sq kv=F
                elems = B * cfg.n_heads * Sq * F
                io = (2 * B * Sq * cfg.n_heads * Dh
                      + 2 * B * F * cfg.n_kv_heads * Dh) * 2
                sites.append((elems, 1.0, io, train))
    else:  # decode: one token against the cache
        S = cell.seq_len
        for i in range(cfg.n_layers):
            if cfg.mixer_kind(i) != "attn":
                continue
            cap = cfg.kv_cache_len(i, S)
            elems = B * cfg.n_heads * 1 * cap
            io = (2 * B * 1 * cfg.n_heads * Dh
                  + 2 * B * cap * cfg.n_kv_heads * Dh) * 2
            sites.append((elems, 1.0, io, False))
        if cfg.encoder is not None:
            F = cfg.encoder.n_frames
            for _ in range(cfg.n_layers):
                elems = B * cfg.n_heads * 1 * F
                io = (2 * B * cfg.n_heads * Dh
                      + 2 * B * F * cfg.n_kv_heads * Dh) * 2
                sites.append((elems, 1.0, io, False))
    return sites


def ssd_sites(cfg: ModelConfig, cell: ShapeCell):
    """(elems_intra, io_bytes, train?) per SSM layer per step."""
    if cfg.ssm is None:
        return []
    s = cfg.ssm
    B = cell.global_batch
    H = s.n_heads(cfg.d_model)
    P, N, G = s.head_dim, s.d_state, s.ngroups
    sites = []
    train = cell.kind == "train"
    if cell.kind in ("train", "prefill"):
        S = cell.seq_len
        Q = min(s.chunk, S)
        nc = -(-S // Q)
        for i in range(cfg.n_layers):
            if cfg.mixer_kind(i) != "ssm":
                continue
            elems = B * nc * Q * Q * H
            io = (2 * B * S * H * P + B * S * H * 4
                  + 2 * B * S * G * N) * 2 + B * H * P * N * 4
            sites.append((elems, io, train))
    else:
        # decode step is O(H·P·N) — reference == kernel, no adjustment
        pass
    return sites


# ---------------------------------------------------------------------------
# The adjustment
# ---------------------------------------------------------------------------

def kernel_adjusted(raw: dict[str, float], cfg: ModelConfig,
                    cell: ShapeCell, chips: int) -> dict[str, float]:
    """raw: {"flops": per-chip, "bytes": per-chip} from the unrolled
    reference build.  Returns adjusted per-chip {"flops", "bytes"} plus the
    breakdown (for EXPERIMENTS.md)."""
    ca = _calibrate_attention()
    ref_flops = ref_bytes = 0.0
    ker_flops = ker_bytes = 0.0
    for elems, frac, io, train in attention_sites(cfg, cell):
        if train:
            # remat="full": fwd + recompute + bwd  (grad includes one fwd)
            f_ref = ca["f_grad"] + ca["f_fwd"]
            b_ref = ca["b_grad"] + ca["b_fwd"]
            io_mult = 4.0
        else:
            f_ref, b_ref, io_mult = ca["f_fwd"], ca["b_fwd"], 1.0
        ref_flops += f_ref * elems
        ref_bytes += b_ref * elems
        # kernel: same matmul flops ratio as reference, × masked fraction
        ker_flops += f_ref * elems * frac
        ker_bytes += io * io_mult

    cs = _calibrate_ssd()
    for elems, io, train in ssd_sites(cfg, cell):
        if train:
            f_ref = cs["f_grad"] + cs["f_fwd"]
            b_ref = cs["b_grad"] + cs["b_fwd"]
            io_mult = 4.0
        else:
            f_ref, b_ref, io_mult = cs["f_fwd"], cs["b_fwd"], 1.0
        ref_flops += f_ref * elems
        ref_bytes += b_ref * elems
        ker_flops += f_ref * elems          # SSD computes all chunks
        ker_bytes += io * io_mult

    adj_flops = max(raw["flops"] - ref_flops / chips + ker_flops / chips,
                    0.0)
    adj_bytes = max(raw["bytes"] - ref_bytes / chips + ker_bytes / chips,
                    0.0)
    return {
        "flops": adj_flops,
        "bytes": adj_bytes,
        "ref_attn_ssd_flops_per_chip": ref_flops / chips,
        "ref_attn_ssd_bytes_per_chip": ref_bytes / chips,
        "kernel_attn_ssd_flops_per_chip": ker_flops / chips,
        "kernel_attn_ssd_bytes_per_chip": ker_bytes / chips,
    }
