"""Checkpointing: atomic, optionally async, reshard-on-restore.

Fault-tolerance contract with the provisioner (paper §5 adapted to SPMD):
a preempted training worker group loses its slice mid-step; the job
restarts from ``latest_step()`` on whatever slice the provisioner hands it
next — possibly a *different* mesh shape (elastic DP).  Restore therefore
takes the *target* sharding tree and device_puts each leaf into it: the
on-disk layout is mesh-agnostic (full unsharded arrays per leaf).

Layout:
    <dir>/step_<n>/arrays.npz     flat {path: np.ndarray}
    <dir>/step_<n>/DONE           commit marker (atomic rename of tmp dir)

Async mode snapshots to host memory synchronously (cheap: device->host
copy) and writes to disk on a background thread — the train loop never
blocks on the filesystem, the standard large-scale trick.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",
                                                       "float16"):
            # npz cannot store ml_dtypes; widen losslessly to f32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str, *, async_mode: bool = True,
                 keep: int = 3):
        self.dir = directory
        self.async_mode = async_mode
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, blocking: bool = False,
             extra: dict | None = None):
        # synchronous device->host snapshot (consistent view of the step)
        host = _flatten_with_paths(tree)
        meta = {"step": int(step), "extra": extra or {}}

        if self.async_mode and not blocking:
            self.wait()  # at most one outstanding write
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write(self, step: int, host: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "DONE"))):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: PyTree,
                shardings: PyTree | None = None) -> PyTree:
        """Restore into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs). `shardings` (same structure) reshards each leaf
        onto the *current* mesh — elastic restore after a mesh change."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.exists(os.path.join(path, "DONE")):
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        with np.load(os.path.join(path, "arrays.npz")) as data:
            arrays = {k: data[k] for k in data.files}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        sh_leaves = (
            treedef.flatten_up_to(shardings) if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (pth, tgt), sh in zip(flat, sh_leaves):
            key = _SEP.join(_path_str(p) for p in pth)
            if key not in arrays:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = arrays[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {tgt.shape}"
                )
            arr = arr.astype(tgt.dtype)
            leaves.append(
                jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
            )
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def read_meta(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "meta.json")
        with open(path) as f:
            return json.load(f)
