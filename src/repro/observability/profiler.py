"""Negotiation-cycle profiler.

Attributes wall-clock per negotiation cycle to problem-build /
matchmaker `match` / plan-apply, and per provisioner reconcile to
collector-preview vs the rest — the phase split the million-job
roadmap item needs to know where a drain actually spends its time.

The collector/provisioner hot paths guard every timing site with a
single `if prof is not None:` check, so a simulation built without
telemetry pays one attribute load per cycle and nothing else.

Matchmaker-backend detail rides along: the jax backend reports, per
call, its padding bucket and whether that bucket was seen before
(first sight == XLA trace+compile, repeats == cached executable), and
`flush_staged` reports fused-batch size or the fallback reason.

Wall times land in registry histograms (scrapeable) and in bounded
per-cycle deques whose offsets are relative to profiler creation —
those deques feed the Chrome-trace exporter and are deliberately
*excluded* from snapshots: wall-clock measurements of a dead process
are not worth resuming, so a restore starts the profiler log empty
while the cumulative histograms carry over.
"""
from __future__ import annotations

import time
from collections import deque

from .registry import MetricRegistry, WALL_SECONDS_BUCKETS


class CycleProfiler:
    def __init__(self, registry: MetricRegistry, *,
                 cycle_log_max: int = 4096):
        self.phase_h = registry.histogram(
            "repro_cycle_phase_seconds",
            "Wall seconds per negotiation-cycle phase",
            ("phase",), WALL_SECONDS_BUCKETS)
        self.cycles_c = registry.counter(
            "repro_cycles_total", "Negotiation cycles by kind", ("kind",))
        # labelled by entry path: "cycle" covers match/match_cycles
        # dispatches from negotiation, "preview" the provisioner dry-run
        # dispatches.  The split exists because the preview path owns
        # its own jit (vmapped, guard-free) AND warms padding buckets
        # before the first recorded cycle — an unlabelled counter
        # under-reported vs `repro_matchmaker_seen_buckets` (measured
        # jit_compiles=0 on the 2k replay while buckets grew).
        self.jit_compiles = registry.counter(
            "repro_matchmaker_jit_compiles_total",
            "Matchmaker calls that hit a fresh padding bucket (XLA "
            "trace), by entry path", ("path",))
        self.reconcile_h = registry.histogram(
            "repro_reconcile_seconds",
            "Wall seconds per provisioner reconcile",
            (), WALL_SECONDS_BUCKETS)
        self.preview_h = registry.histogram(
            "repro_reconcile_preview_seconds",
            "Wall seconds spent in collector.preview per reconcile",
            (), WALL_SECONDS_BUCKETS)
        self.cycle_log_max = int(cycle_log_max)
        self.cycles: deque = deque(maxlen=self.cycle_log_max)
        self.reconciles: deque = deque(maxlen=self.cycle_log_max)
        self._t0 = time.perf_counter()

    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def record_cycle(self, *, t: float, kind: str, w_start: float,
                     build_s: float, match_s: float, apply_s: float,
                     claims: int = 0, backend: str = "",
                     compiled: bool | None = None,
                     fused_k: int | None = None,
                     fallback: str | None = None):
        """One negotiation cycle.  `w_start` is the absolute
        perf_counter at cycle start; durations are wall seconds."""
        self.phase_h.labels("build").observe(build_s)
        self.phase_h.labels("match").observe(match_s)
        self.phase_h.labels("apply").observe(apply_s)
        self.cycles_c.labels(kind).value += 1
        if compiled:
            self.jit_compiles.labels("cycle").value += 1
        rec = {"t": t, "kind": kind, "w0": w_start - self._t0,
               "build_s": build_s, "match_s": match_s, "apply_s": apply_s,
               "claims": claims, "backend": backend}
        if compiled is not None:
            rec["compiled"] = compiled
        if fused_k is not None:
            rec["fused_k"] = fused_k
        if fallback is not None:
            rec["fallback"] = fallback
        self.cycles.append(rec)

    def note_compile(self, path: str):
        """Attribute one fresh-bucket XLA trace to an entry path
        ("preview" from the collector dry run; record_cycle attributes
        the "cycle" path itself)."""
        self.jit_compiles.labels(path).value += 1

    def record_reconcile(self, *, t: float, w_start: float, wall_s: float,
                         preview_s: float, submitted: int = 0):
        self.reconcile_h.observe(wall_s)
        self.preview_h.observe(preview_s)
        self.reconciles.append(
            {"t": t, "w0": w_start - self._t0, "wall_s": wall_s,
             "preview_s": preview_s, "submitted": submitted})

    # -- aggregate view (compare.py phase-attribution columns) ---------------
    def phase_totals(self) -> dict:
        out = {}
        for phase in ("build", "match", "apply"):
            h = self.phase_h.labels(phase)
            out[phase + "_s"] = h.sum
        out["reconcile_s"] = self.reconcile_h.sum
        out["preview_s"] = self.preview_h.sum
        out["cycles"] = {k[0]: int(c.value)
                         for k, c in self.cycles_c.children.items()}
        by_path = {k[0]: int(c.value)
                   for k, c in self.jit_compiles.children.items()}
        # "jit_compiles" stays the all-paths total (pre-label surface)
        out["jit_compiles"] = sum(by_path.values())
        out["jit_compiles_by_path"] = by_path
        return out

    # -- Chrome-trace rows (wall offsets -> microseconds) --------------------
    def chrome_events(self, pid: int = 2) -> list:
        out = [{"ph": "M", "pid": pid, "name": "process_name",
                "args": {"name": "negotiation wall clock"}}]
        for rec in self.cycles:
            w = rec["w0"] * 1e6
            args = {"sim_t": rec["t"], "kind": rec["kind"],
                    "backend": rec["backend"], "claims": rec["claims"]}
            for key in ("compiled", "fused_k", "fallback"):
                if key in rec:
                    args[key] = rec[key]
            for phase in ("build", "match", "apply"):
                dur = rec[phase + "_s"] * 1e6
                out.append({"ph": "X", "pid": pid, "tid": 1,
                            "name": phase, "cat": "negotiation",
                            "ts": w, "dur": dur, "args": args})
                w += dur
        for rec in self.reconciles:
            w = rec["w0"] * 1e6
            out.append({"ph": "X", "pid": pid, "tid": 2,
                        "name": "reconcile", "cat": "provisioner",
                        "ts": w, "dur": rec["wall_s"] * 1e6,
                        "args": {"sim_t": rec["t"],
                                 "preview_s": rec["preview_s"],
                                 "submitted": rec["submitted"]}})
        return out
