"""Job-lifecycle spans.

Attaches to the existing `JobQueue` hook lists (idle/claim/release/
complete) — the same mechanism the provisioner uses for incremental
deficits — so enabling spans costs one extra callback per state
transition and disabling them costs nothing: the hooks are simply
never installed.

Every submitted job closes exactly one lifecycle span when it
completes.  At that instant all phase boundaries are already on the
`Job` record, so the tracker derives:

    wait = started_at - submitted_at     (idle + matchmaking latency)
    run  = completed_at - started_at     (final, successful execution)

and the invariant  wait + run == completed_at - submitted_at  holds
exactly (both in sim seconds).  Preemptions show up separately: each
release bumps `repro_job_preemptions_total` and the span records the
job's final `preempt_count`/`wasted_s`.

A bounded deque of structured events (submit/claim/release/span) with
job/schedd/backend labels doubles as the source for the Chrome-trace
exporter; sim time maps to trace microseconds.
"""
from __future__ import annotations

from collections import deque

from .registry import MetricRegistry, SIM_SECONDS_BUCKETS


class LifecycleTracker:
    def __init__(self, registry: MetricRegistry, *,
                 event_log_max: int = 20000):
        self.wait_h = registry.histogram(
            "repro_job_wait_seconds",
            "Sim seconds from submit to final start, per schedd",
            ("schedd",), SIM_SECONDS_BUCKETS)
        self.run_h = registry.histogram(
            "repro_job_run_seconds",
            "Sim seconds from final start to completion, per schedd",
            ("schedd",), SIM_SECONDS_BUCKETS)
        self.submits = registry.counter(
            "repro_job_submits_total", "Jobs submitted", ("schedd",))
        self.claims = registry.counter(
            "repro_job_claims_total", "Worker claims handed out",
            ("schedd",))
        self.preemptions = registry.counter(
            "repro_job_preemptions_total",
            "Claims released by preemption/reclaim", ("schedd",))
        self.spans = registry.counter(
            "repro_job_spans_total", "Lifecycle spans closed (completions)",
            ("schedd",))
        self.events: deque = deque(maxlen=int(event_log_max))
        self.event_log_max = int(event_log_max)
        self._collector = None
        self._attached: set[int] = set()

    def bind_collector(self, collector):
        """Lets claim events carry the worker's backend label."""
        self._collector = collector

    def attach_queue(self, q):
        if id(q) in self._attached:
            return
        self._attached.add(id(q))
        name = q.name
        q.add_idle_hook(lambda job, delta, _n=name: self._on_idle(job, delta, _n))
        q.add_claim_hook(lambda job, now, _n=name: self._on_claim(job, now, _n))
        q.add_release_hook(lambda job, now, _n=name: self._on_release(job, now, _n))
        q.add_complete_hook(lambda job, _n=name: self._on_complete(job, _n))

    # -- hook bodies ---------------------------------------------------------
    def _on_idle(self, job, delta, schedd):
        # A job entering IDLE that has never started is a fresh submit;
        # re-idling after a release re-fires with started_at reset < 0 too,
        # so the claim/release events disambiguate in the log.
        if delta == +1 and job.started_at < 0 and job.preempt_count == 0:
            self.submits.labels(schedd).value += 1
            self.events.append({"ev": "submit", "t": job.submitted_at,
                                "jid": job.jid, "schedd": schedd})

    def _worker_backend(self, wname):
        col = self._collector
        if col is None or wname is None:
            return ""
        w = col.workers.get(wname)
        return getattr(w, "backend", None) or ""

    def _on_claim(self, job, now, schedd):
        self.claims.labels(schedd).value += 1
        self.events.append({"ev": "claim", "t": now, "jid": job.jid,
                            "schedd": schedd, "worker": job.claimed_by,
                            "backend": self._worker_backend(job.claimed_by)})

    def _on_release(self, job, now, schedd):
        self.preemptions.labels(schedd).value += 1
        self.events.append({"ev": "release", "t": now, "jid": job.jid,
                            "schedd": schedd})

    def _on_complete(self, job, schedd):
        start = job.started_at if job.started_at >= 0 else job.completed_at
        wait = start - job.submitted_at
        run = job.completed_at - start
        self.wait_h.labels(schedd).observe(wait)
        self.run_h.labels(schedd).observe(run)
        self.spans.labels(schedd).value += 1
        self.events.append({"ev": "span", "jid": job.jid, "schedd": schedd,
                            "submit": job.submitted_at, "start": start,
                            "end": job.completed_at,
                            "preempts": job.preempt_count,
                            "wasted_s": job.wasted_s})

    # -- Chrome-trace rows (sim time -> microseconds) ------------------------
    def chrome_events(self, pid: int = 1) -> list:
        out = [{"ph": "M", "pid": pid, "name": "process_name",
                "args": {"name": "job lifecycle (sim time)"}}]
        for ev in self.events:
            if ev["ev"] == "span":
                tid = ev["jid"] % 256
                args = {"jid": ev["jid"], "schedd": ev["schedd"],
                        "preempts": ev["preempts"]}
                out.append({"ph": "X", "pid": pid, "tid": tid,
                            "name": f"wait j{ev['jid']}",
                            "cat": "job,wait",
                            "ts": ev["submit"] * 1e6,
                            "dur": (ev["start"] - ev["submit"]) * 1e6,
                            "args": args})
                out.append({"ph": "X", "pid": pid, "tid": tid,
                            "name": f"run j{ev['jid']}",
                            "cat": "job,run",
                            "ts": ev["start"] * 1e6,
                            "dur": (ev["end"] - ev["start"]) * 1e6,
                            "args": args})
            elif ev["ev"] == "release":
                out.append({"ph": "i", "pid": pid, "tid": ev["jid"] % 256,
                            "name": f"release j{ev['jid']}", "cat": "job",
                            "ts": ev["t"] * 1e6, "s": "t"})
        return out

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> dict:
        return {"events": [dict(ev) for ev in self.events]}

    def load_state(self, state: dict):
        self.events = deque(
            (dict(ev) for ev in state.get("events", [])),
            maxlen=self.event_log_max)
