"""Unified telemetry: metric registry, lifecycle spans, cycle profiler.

`Telemetry` is the facade the rest of the tree talks to.  The metric
*registry* is always live — the collector/provisioner/classad cache
counters that tests and benchmarks read moved into it, so they must
keep counting whether or not richer telemetry is on.  The `enabled`
flag gates the two pieces with per-event cost: job-lifecycle span
hooks (never installed when disabled) and the wall-clock cycle
profiler (every site guards on `profiler is not None`).

    sim = Simulation(..., telemetry=True)
    sim.telemetry.prometheus_text()   # exposition, also GET /metrics.prom
    sim.dump_trace("trace.json")      # Chrome trace-event JSON (Perfetto)

Snapshot semantics: registry values and the lifecycle event log are
sim-time data and serialize with the simulation; the profiler's
per-cycle wall-clock deques reset on restore (documented in
`Telemetry.state_dict`).
"""
from __future__ import annotations

import json

from .registry import (Counter, Gauge, Histogram, MetricFamily,
                       MetricRegistry, SIM_SECONDS_BUCKETS,
                       WALL_SECONDS_BUCKETS)
from .spans import LifecycleTracker
from .profiler import CycleProfiler

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricFamily", "MetricRegistry",
    "SIM_SECONDS_BUCKETS", "WALL_SECONDS_BUCKETS",
    "LifecycleTracker", "CycleProfiler", "Telemetry", "as_telemetry",
]

# pool gauges exported on scrape — the same series Recorder samples
# for the Fig 2/3 curves, read live via a registry collect hook.
_POOL_GAUGE_HELP = {
    "idle_jobs": "Idle jobs across all schedds",
    "running_jobs": "Running jobs across all schedds",
    "pending_pods": "Pods submitted but not yet placed",
    "running_pods": "Pods running",
    "ready_workers": "Advertised workers alive and ready",
    "busy_workers": "Workers with at least one claim",
    "live_nodes": "Live nodes across backends",
    "provisioned_cores": "CPU cores provisioned across backends",
    "cost_rate": "Aggregate cost rate across backends",
}


class Telemetry:
    def __init__(self, enabled: bool = True, *,
                 event_log_max: int = 20000, cycle_log_max: int = 4096):
        self.enabled = bool(enabled)
        self.registry = MetricRegistry()
        self.lifecycle = (LifecycleTracker(self.registry,
                                           event_log_max=event_log_max)
                          if self.enabled else None)
        self.profiler = (CycleProfiler(self.registry,
                                       cycle_log_max=cycle_log_max)
                         if self.enabled else None)
        self._sim = None
        self._pool_gauges = None
        self._cache_g = None
        self._mm_buckets_g = None

    # -- wiring --------------------------------------------------------------
    def attach_queue(self, q):
        if self.lifecycle is not None:
            self.lifecycle.attach_queue(q)

    def bind_collector(self, collector):
        if self.lifecycle is not None:
            self.lifecycle.bind_collector(collector)

    def attach_simulation(self, sim):
        """Register scrape-time pool gauges and span hooks on every
        schedd queue.  Pool gauges are registered even when `enabled`
        is False — they cost nothing until someone scrapes."""
        self._sim = sim
        if self._pool_gauges is None:
            self._pool_gauges = {
                name: self.registry.gauge("repro_pool_" + name, help)
                for name, help in _POOL_GAUGE_HELP.items()}
            self.registry.add_collect_hook(self._collect_pool)
            # ClassAd LRU effectiveness, read off the live caches at
            # scrape time (gauges, not counters: restores rebuild the
            # caches cold and counter resets would violate monotonicity)
            self._cache_g = {
                stat: self.registry.gauge(
                    "repro_classad_cache_" + stat,
                    f"ClassAd LRU memo {stat} (live cache object)",
                    ("cache",))
                for stat in ("hits", "misses", "entries")}
            self.registry.add_collect_hook(self._collect_caches)
            # every distinct padding bucket the jitted backend has seen
            # is one XLA trace; this counts ALL of them, including the
            # ones the provisioner's preview path triggers outside any
            # recorded negotiation cycle (which is why it can exceed
            # the profiler's cycle-attributed jit_compiles)
            self._mm_buckets_g = self.registry.gauge(
                "repro_matchmaker_seen_buckets",
                "Distinct padding buckets traced by the matchmaker "
                "backend (== XLA compiles, preview included)",
                ("backend",))
            self.registry.add_collect_hook(self._collect_matchmaker)
        for q in sim.queues:
            self.attach_queue(q)
        self.bind_collector(sim.collector)

    def _collect_pool(self):
        sim = self._sim
        if sim is None:
            return
        g = self._pool_gauges
        g["idle_jobs"].value = float(sim.pool_queue.n_idle())
        g["running_jobs"].value = float(sim.pool_queue.n_running())
        g["pending_pods"].value = float(
            len(sim.cluster_view.pending_pods()))
        g["running_pods"].value = float(
            len(sim.cluster_view.running_pods()))
        g["ready_workers"].value = float(
            len(sim.collector.alive_workers(sim.now)))
        g["busy_workers"].value = float(
            sum(1 for w in sim.collector.workers.values() if w.claimed))
        g["live_nodes"].value = float(
            sum(len(b.cluster.nodes) for b in sim.backends))
        g["provisioned_cores"].value = float(
            sum(n.capacity.get("cpu", 0)
                for b in sim.backends for n in b.cluster.nodes.values()))
        g["cost_rate"].value = float(
            sum(b.cost_rate() for b in sim.backends))

    def _collect_matchmaker(self):
        sim = self._sim
        if sim is None:
            return
        mm = sim.collector.matchmaker
        buckets = getattr(mm, "_seen_buckets", None)
        if buckets is not None:
            name = getattr(mm, "name", type(mm).__name__)
            self._mm_buckets_g.labels(name).value = float(len(buckets))

    def _collect_caches(self):
        sim = self._sim
        if sim is None:
            return
        for cname, cache in (("match", sim.collector._match_cache),
                             ("poll", sim.collector._poll_cache)):
            self._cache_g["hits"].labels(cname).value = float(cache.hits)
            self._cache_g["misses"].labels(cname).value = float(
                cache.misses)
            self._cache_g["entries"].labels(cname).value = float(
                len(cache))

    # -- exporters -----------------------------------------------------------
    def prometheus_text(self) -> str:
        return self.registry.prometheus_text()

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (object form) — load in Perfetto or
        chrome://tracing.  Job spans run on sim-time microseconds
        (pid 1); negotiation/reconcile phases on wall-clock offsets
        from profiler start (pid 2)."""
        if not self.enabled:
            raise ValueError(
                "telemetry is disabled; build with telemetry=True to trace")
        events = self.lifecycle.chrome_events(pid=1)
        events += self.profiler.chrome_events(pid=2)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump_trace(self, path: str) -> int:
        trace = self.chrome_trace()
        with open(path, "w") as fh:
            json.dump(trace, fh)
        return len(trace["traceEvents"])

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> dict:
        """Registry values + lifecycle event log (sim-time data, safe to
        resume).  The profiler's wall-clock cycle log is intentionally
        dropped: it measures a process that no longer exists, so a
        restored simulation starts it empty while the cumulative
        phase histograms (registry) carry over."""
        state = {"version": 1, "registry": self.registry.state_dict()}
        if self.lifecycle is not None:
            state["lifecycle"] = self.lifecycle.state_dict()
        return state

    def load_state(self, state: dict):
        self.registry.load_state(state.get("registry", {}))
        if self.lifecycle is not None and "lifecycle" in state:
            self.lifecycle.load_state(state["lifecycle"])


def as_telemetry(value) -> Telemetry:
    """Coerce the `Simulation(telemetry=...)` argument: None/False ->
    disabled shell (registry only), True -> fully enabled, a Telemetry
    instance passes through (shared between sims if you want one
    registry across a fleet)."""
    if isinstance(value, Telemetry):
        return value
    return Telemetry(enabled=bool(value))
