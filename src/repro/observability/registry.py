"""Metric registry: Counter/Gauge/Histogram families with labels.

One queryable namespace for every counter the pool keeps — the
provisioner's preview-memo and free-digest hit rates, the collector's
no-op-memo and fused-negotiation counters, the ClassAd LRU caches, the
job-lifecycle histograms, and the negotiation-cycle profiler all
register here (`repro_*` families), and the service tier renders the
whole registry as Prometheus text exposition (`GET /metrics.prom`).

Cost model: a counter child is one attribute increment on a dedicated
object (`child.value += 1` — the same cost as the bespoke int
attributes these families replaced), histogram observation is one
bisect over ~10 edges, and exposition/serialization walk the registry
only when asked.  Gauges that mirror live state (pool depths, cache
sizes) are set by *collect hooks* at exposition time, so an unscraped
registry never polls anything.

The registry serializes (`state_dict`/`load_state`) so snapshot/resume
carries telemetry forward; values are plain floats and label values are
coerced to strings, keeping the state JSON-safe.
"""
from __future__ import annotations

from bisect import bisect_right
from typing import Any, Callable, Iterable

# sim-time latency edges (seconds): job wait/run spans 1s..1 day
SIM_SECONDS_BUCKETS = (1.0, 5.0, 15.0, 60.0, 300.0, 1200.0, 3600.0,
                       14400.0, 86400.0)
# wall-time phase edges (seconds): negotiation phases run µs..seconds
WALL_SECONDS_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0, 10.0)


class Counter:
    """Monotone child; `value` is public for hot-path `+= 1` increments."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def inc(self, n: float = 1.0):
        self.value += n


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: `le` edges,
    an implicit +Inf bucket, plus running sum and count)."""

    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: tuple[float, ...]):
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.counts[bisect_right(self.edges, v)] += 1
        self.sum += v
        self.count += 1


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricFamily:
    """Named group of children keyed by label-value tuples."""

    def __init__(self, name: str, help: str, kind: str,
                 label_names: tuple[str, ...] = (),
                 buckets: tuple[float, ...] | None = None):
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.name = name
        self.help = help
        self.kind = kind
        self.label_names = tuple(label_names)
        self.buckets = tuple(buckets) if buckets is not None else None
        self.children: dict[tuple[str, ...], Any] = {}

    def _make_child(self):
        if self.kind == "histogram":
            return Histogram(self.buckets or SIM_SECONDS_BUCKETS)
        return _KINDS[self.kind]()

    def labels(self, *values) -> Any:
        key = tuple(str(v) for v in values)
        if len(key) != len(self.label_names):
            raise ValueError(
                f"{self.name} wants labels {self.label_names}, got {key}")
        child = self.children.get(key)
        if child is None:
            child = self.children[key] = self._make_child()
        return child


def _fmt(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _fmt_labels(names: Iterable[str], values: Iterable[str],
                extra: tuple[str, str] | None = None) -> str:
    pairs = [(n, v) for n, v in zip(names, values)]
    if extra is not None:
        pairs.append(extra)
    if not pairs:
        return ""
    inner = ",".join(
        '%s="%s"' % (n, v.replace("\\", r"\\").replace('"', r"\"")
                     .replace("\n", r"\n"))
        for n, v in pairs)
    return "{" + inner + "}"


class MetricRegistry:
    def __init__(self):
        self._families: dict[str, MetricFamily] = {}
        self._collect_hooks: list[Callable[[], None]] = []

    # -- family constructors (idempotent: same name returns the family) ------
    def _family(self, name, help, kind, label_names, buckets=None):
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.label_names != tuple(label_names):
                raise ValueError(
                    f"metric {name!r} re-registered with a different "
                    f"kind/labels")
            return fam
        fam = MetricFamily(name, help, kind, tuple(label_names), buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()):
        """Unlabeled: returns the single Counter child.  Labeled: returns
        the family (call `.labels(...)` for children)."""
        fam = self._family(name, help, "counter", labels)
        return fam if labels else fam.labels()

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()):
        fam = self._family(name, help, "gauge", labels)
        return fam if labels else fam.labels()

    def histogram(self, name: str, help: str = "",
                  labels: tuple[str, ...] = (),
                  buckets: tuple[float, ...] | None = None):
        fam = self._family(name, help, "histogram", labels, buckets)
        return fam if labels else fam.labels()

    def family(self, name: str) -> MetricFamily:
        return self._families[name]

    def get_value(self, name: str, *label_values) -> float:
        """Convenience read of one counter/gauge child (0.0 if the child
        has never been touched)."""
        fam = self._families[name]
        key = tuple(str(v) for v in label_values)
        child = fam.children.get(key)
        return float(child.value) if child is not None else 0.0

    # -- collect hooks (set live-state gauges at exposition time) ------------
    def add_collect_hook(self, fn: Callable[[], None]):
        self._collect_hooks.append(fn)

    def collect(self):
        for fn in self._collect_hooks:
            fn()

    # -- Prometheus text exposition (format version 0.0.4) -------------------
    def prometheus_text(self) -> str:
        self.collect()
        lines: list[str] = []
        for fam in self._families.values():
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in fam.children.items():
                if fam.kind == "histogram":
                    cum = 0
                    for edge, n in zip(child.edges, child.counts):
                        cum += n
                        lab = _fmt_labels(fam.label_names, key,
                                          ("le", _fmt(edge)))
                        lines.append(f"{fam.name}_bucket{lab} {cum}")
                    cum += child.counts[-1]
                    lab = _fmt_labels(fam.label_names, key, ("le", "+Inf"))
                    lines.append(f"{fam.name}_bucket{lab} {cum}")
                    lab = _fmt_labels(fam.label_names, key)
                    lines.append(f"{fam.name}_sum{lab} {_fmt(child.sum)}")
                    lines.append(f"{fam.name}_count{lab} {child.count}")
                else:
                    lab = _fmt_labels(fam.label_names, key)
                    lines.append(f"{fam.name}{lab} {_fmt(child.value)}")
        return "\n".join(lines) + "\n"

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> dict:
        fams = {}
        for fam in self._families.values():
            children = []
            for key, child in fam.children.items():
                if fam.kind == "histogram":
                    payload: Any = {"counts": list(child.counts),
                                    "sum": child.sum, "count": child.count}
                else:
                    payload = child.value
                children.append([list(key), payload])
            fams[fam.name] = {
                "kind": fam.kind,
                "help": fam.help,
                "labels": list(fam.label_names),
                "buckets": (list(fam.buckets)
                            if fam.buckets is not None else None),
                "children": children,
            }
        return {"families": fams}

    def load_state(self, state: dict):
        for name, fs in state.get("families", {}).items():
            buckets = fs.get("buckets")
            fam = self._family(
                name, fs.get("help", ""), fs["kind"],
                tuple(fs.get("labels", ())),
                tuple(buckets) if buckets is not None else None)
            for key, payload in fs.get("children", []):
                child = fam.labels(*key)
                if fam.kind == "histogram":
                    child.counts = [int(n) for n in payload["counts"]]
                    child.sum = float(payload["sum"])
                    child.count = int(payload["count"])
                else:
                    child.value = float(payload)
