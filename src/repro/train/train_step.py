"""The jit'd training step: loss → grads → (compressed) reduce → AdamW.

``make_train_step`` builds a pjit-ready function over (TrainState, batch);
data parallelism comes from batch sharding, tensor/expert parallelism from
the weight PartitionSpecs, remat from the model's scan policy.

Microbatch accumulation: ``accum_steps > 1`` splits the per-step batch on
the leading axis and lax.scan's the fwd+bwd, accumulating fp32 grads —
the standard trade of activation memory for (re)compute; the dry-run
memory_analysis is how a config picks the smallest accum that fits.

Cross-pod gradient compression: with ``grad_compression="int8"`` the grads
are *re-reduced* over the "pod" axis via parallel/collectives (int8 wire
format).  In-pod reduction stays in XLA's native bf16/fp32 psum (ICI is
fast; compression there costs more in quantize latency than it saves).
In that mode the loss is computed with pvary'd batch over pods so XLA's
own all-reduce does not already sum across pods.  For the dry-run roofline
both variants lower; EXPERIMENTS.md quantifies the collective-bytes delta.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.parallel import collectives
from repro.parallel.sharding import (
    ShardingRules, constrainer, named_sharding_tree, spec_tree, batch_spec,
)
from repro.train.optimizer import OptimizerConfig, adamw_init, adamw_update
from repro.train.schedule import lr_schedule

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: PyTree
    step: jax.Array
    rng: jax.Array


def init_train_state(params: PyTree, opt_cfg: OptimizerConfig,
                     rng: jax.Array) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params, opt_cfg),
        step=jnp.zeros((), jnp.int32),
        rng=rng,
    )


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    mesh: Mesh,
    rules: ShardingRules,
    *,
    accum_steps: int = 1,
    remat: str = "full",
    grad_compression: str | None = None,
    lr_kwargs: dict | None = None,
    param_axes: PyTree = None,
    unroll: bool = False,
) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    use_compression = grad_compression == "int8" and "pod" in mesh.shape
    if use_compression and rules.name not in ("base", "ep", "decode"):
        raise ValueError(
            "int8 grad compression composes with the TP presets (base/ep); "
            "FSDP weight all-gathers and zero3 batch-over-model sharding "
            "trip an XLA subgroup-manual partitioner check (upstream "
            "limitation) inside the partial-manual pod region"
        )
    lr_kwargs = lr_kwargs or {}
    grad_specs = (
        spec_tree(param_axes, rules, mesh) if param_axes is not None else None
    )
    if use_compression:
        # inside the partial-manual (pod) shard_map, activation constraints
        # must not name the manual axis: batch shards over "data" only.
        # vocab_act is disabled too — the CE scatter over a sharded vocab
        # trips an XLA subgroup-manual partitioner check (upstream).
        inner_rules = dataclasses.replace(
            rules, rules={**rules.rules,
                          "batch": tuple(a for a in rules.rules["batch"]
                                         if a != "pod"),
                          "batch_logits": None,
                          "vocab_act": None})
        constrain = constrainer(inner_rules, mesh)
    else:
        constrain = constrainer(rules, mesh)

    def loss_for_batch(params, batch):
        return model_lib.loss_fn(
            params, cfg, batch, mesh=mesh, constrain=constrain, remat=remat,
            unroll=unroll,
        )

    def compute_grads(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for_batch, has_aux=True
            )(params, batch)
            return grads, metrics
        # microbatch accumulation over the leading batch axis
        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            g_acc, m_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_for_batch, has_aux=True
            )(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g
            )
            m_acc = jax.tree_util.tree_map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        m0 = {
            "loss": jnp.zeros((), jnp.float32),
            "ce": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "moe_aux": jnp.zeros((), jnp.float32),
            "tokens": jnp.zeros((), jnp.float32),
        }
        (g, m), _ = jax.lax.scan(body, (g0, m0), micro)
        inv = 1.0 / accum_steps
        g = jax.tree_util.tree_map(lambda x: x * inv, g)
        m = {k: v * inv if k != "tokens" else v for k, v in m.items()}
        return g, m

    def compute_grads_compressed(params, batch, step):
        """Manual over the "pod" axis only (data/model stay auto): each pod
        derives grads from its own batch shard, then the pods exchange an
        int8-quantized mean instead of XLA's bf16/fp32 all-reduce.  Not
        composable with the MoE EP path (which opens its own full-manual
        shard_map) — MoE configs keep compression off."""

        def body(params, batch, step):
            grads, metrics = compute_grads(params, batch)
            leaves, treedef = jax.tree_util.tree_flatten(grads)
            out = []
            for i, g in enumerate(leaves):
                key = jax.random.fold_in(
                    jax.random.PRNGKey(17), step + jnp.uint32(i)
                )
                out.append(collectives.compressed_psum(g, ("pod",), key))
            grads = jax.tree_util.tree_unflatten(treedef, out)
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, "pod"), metrics
            )
            return grads, metrics

        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), jax.tree_util.tree_map(lambda _: P("pod"), batch),
                      P()),
            out_specs=(P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )(params, batch, step)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if use_compression:
            grads, metrics = compute_grads_compressed(
                state.params, batch, state.step.astype(jnp.uint32)
            )
        else:
            grads, metrics = compute_grads(state.params, batch)
        lr = lr_schedule(state.step, **lr_kwargs)
        new_params, new_opt, opt_metrics = adamw_update(
            state.params, grads, state.opt, opt_cfg, lr
        )
        metrics = {**metrics, **opt_metrics, "lr": lr}
        new_state = TrainState(
            params=new_params,
            opt=new_opt,
            step=state.step + 1,
            rng=jax.random.fold_in(state.rng, 0),
        )
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharding helpers for pjit-ing the step
# ---------------------------------------------------------------------------

def state_shardings(
    param_tree: PyTree, rules: ShardingRules, mesh: Mesh
) -> TrainState:
    """NamedSharding tree matching TrainState(params, opt, step, rng).
    `param_tree` is the tree of Param leaves (shape-aware specs)."""
    from repro.parallel.sharding import param_sharding_tree

    p_sh = param_sharding_tree(param_tree, rules, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=p_sh,
        opt={
            "mu": p_sh,
            "nu": p_sh,
            "count": rep,
        },
        step=rep,
        rng=rep,
    )


def batch_shardings(batch_spec_tree: dict, mesh: Mesh) -> dict:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        batch_spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_batch_specs(cfg: ModelConfig, mesh: Mesh) -> dict:
    out = {
        "tokens": batch_spec(mesh, None),
        "labels": batch_spec(mesh, None),
    }
    if cfg.encoder is not None:
        out["frames"] = batch_spec(mesh, None, None)
    if cfg.frontend is not None:
        out["patches"] = batch_spec(mesh, None, None)
    return out
