"""AdamW implemented on raw pytrees (no optax dependency).

Moments follow the config's ``optimizer_state_dtype`` policy: fp32 for
fidelity on ≤32B archs, bf16 to fit the 400B MoE in 256 × 16 GB HBM (the
dry-run's memory_analysis() validates the fit).  The second moment is
stored as rsqrt-friendly fp32 even under the bf16 policy when
``keep_nu_fp32`` is set — empirically the cheap half of the trade.

Sharding: moment trees inherit the parameter logical axes, so FSDP shards
optimizer state over "data" exactly like weights (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    keep_nu_fp32: bool = True


def adamw_init(params: PyTree, cfg: OptimizerConfig) -> PyTree:
    mu_dt = jnp.dtype(cfg.state_dtype)
    nu_dt = jnp.float32 if cfg.keep_nu_fp32 else mu_dt

    return {
        "mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, mu_dt), params
        ),
        "nu": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, nu_dt), params
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    params: PyTree,
    grads: PyTree,
    state: PyTree,
    cfg: OptimizerConfig,
    lr: jax.Array,
) -> tuple[PyTree, PyTree, dict]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd_math(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mu_n / c1
        nhat = nu_n / c2
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * step
        return (
            p_n.astype(p.dtype),
            mu_n.astype(mu.dtype),
            nu_n.astype(nu.dtype),
        )

    # Giant stacked leaves (e.g. a 400B MoE's (n_scan, E, d, f) expert
    # stack: ~3.8 GB bf16 PER SHARD) would materialize several fp32
    # temporaries at once if updated in one fused region — lax.map over
    # the leading (scan) axis caps the fp32 working set at 1/n_scan.
    _CHUNK_THRESHOLD = 1 << 27  # elements

    def upd(p, g, mu, nu):
        if p.ndim >= 2 and p.size >= _CHUNK_THRESHOLD and p.shape[0] > 1:
            return jax.lax.map(
                lambda args: upd_math(*args), (p, g, mu, nu)
            )
        return upd_math(p, g, mu, nu)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    metrics = {"grad_norm": gnorm, "clip_factor": clip}
    return new_p, new_state, metrics
