"""Mixture-of-Experts block: top-k router + two dispatch implementations.

``dense``   — GShard-style capacity dispatch with one-hot einsums.  O(T·E·C)
              dispatch FLOPs: only used for small configs (smoke tests,
              reference semantics for the EP path).
``ep``      — production expert-parallel path under shard_map:
                local top-k -> sort by destination device -> all_to_all
                -> local sort by expert -> batched expert GEMM (capacity
                padded) -> reverse all_to_all -> weighted combine.
              Experts are sharded over the "data" mesh axis (contiguous
              blocks of E/|data| per device), expert FF dim over "model",
              and the whole block is replicated over "pod" (all-to-all never
              crosses the pod boundary — DCN is too slow for per-layer a2a;
              pods sync through the gradient all-reduce instead).

Both paths drop tokens that overflow capacity (standard Switch behaviour)
and add a Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import dense_param, init_mlp, apply_mlp

Constrain = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def init_moe(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    f = m.d_ff_expert
    p = {
        "router": dense_param((d, m.n_experts), ("embed", None), "float32"),
        "gate": dense_param((m.n_experts, d, f), ("expert", "embed", "mlp"), dt,
                            fan_in=d),
        "up": dense_param((m.n_experts, d, f), ("expert", "embed", "mlp"), dt,
                          fan_in=d),
        "down": dense_param((m.n_experts, f, d), ("expert", "mlp", "embed"), dt,
                            fan_in=f),
    }
    if m.n_shared_experts > 0:
        p["shared"] = init_mlp(d, f * m.n_shared_experts, dt,
                               gated=cfg.gated_mlp, act=cfg.act)
    return p


def _router_topk(
    logits: jax.Array, k: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """fp32 softmax router. Returns (probs (T,E), gates (T,k), idx (T,k))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return probs, gates, idx.astype(jnp.int32)


def _aux_loss(probs: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch load-balance loss: E * sum_e f_e * P_e (local estimate)."""
    T = probs.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * idx.shape[1], 1)
    pmean = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pmean)


# ---------------------------------------------------------------------------
# Dense (capacity-einsum) dispatch — reference / small configs
# ---------------------------------------------------------------------------

def moe_forward_dense(p: dict, cfg: ModelConfig, x: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs, gates, idx = _router_topk(logits, m.top_k)
    aux = _aux_loss(probs, idx, m.n_experts)

    C = max(1, math.ceil(T * m.top_k * m.capacity_factor / m.n_experts))
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.int32)  # (T,k,E)
    flat = onehot.reshape(T * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - 1  # rank within expert, -1 if unused
    pos = pos.reshape(T, m.top_k, m.n_experts)
    within = (pos >= 0) & (pos < C)
    disp = jax.nn.one_hot(pos.clip(0, C - 1), C, dtype=x.dtype) * within[
        ..., None
    ].astype(x.dtype)  # (T,k,E,C)
    comb = disp * gates.astype(x.dtype)[:, :, None, None]
    disp = jnp.sum(disp, axis=1)  # (T,E,C)
    comb = jnp.sum(comb, axis=1)

    ex_in = jnp.einsum("tec,td->ecd", disp, xt)  # (E,C,d)
    h = jnp.einsum("ecd,edf->ecf", ex_in, p["gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", ex_in, p["up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * u).astype(x.dtype)
    ex_out = jnp.einsum("ecf,efd->ecd", h, p["down"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("tec,ecd->td", comb, ex_out)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xt, gated=cfg.gated_mlp, act=cfg.act)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel (all-to-all) dispatch — production path
# ---------------------------------------------------------------------------

def _sort_dispatch(values, key, n_buckets, capacity):
    """Stable-sort `values` rows into (n_buckets, capacity) with overflow drop.

    Returns (buffer, bucket_sorted, rank_sorted, order, kept_sorted) where
    `order` is the stable sort permutation and buffer[bucket, rank] =
    values[order][i] for kept entries."""
    A = key.shape[0]
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    counts = jnp.zeros((n_buckets,), jnp.int32).at[key_s].add(
        1, mode="drop"
    )
    starts = jnp.cumsum(counts) - counts  # exclusive
    rank = jnp.arange(A, dtype=jnp.int32) - starts[
        jnp.clip(key_s, 0, n_buckets - 1)
    ]
    kept = (rank >= 0) & (rank < capacity) & (key_s >= 0) & (key_s < n_buckets)
    b_idx = jnp.where(kept, key_s, 0)
    r_idx = jnp.where(kept, rank, 0)
    buf = jnp.zeros((n_buckets, capacity) + values.shape[1:], values.dtype)
    vals_s = values[order] * kept.reshape((-1,) + (1,) * (values.ndim - 1)).astype(
        values.dtype
    )
    buf = buf.at[b_idx, r_idx].add(vals_s)  # add: duplicate (0,0) slots masked to 0
    return buf, key_s, rank, order, kept


def _ep_local(xt, router_w, w_gate, w_up, w_down, *, m: MoEConfig,
              data_axis: str, model_axis: str, batch_axes: tuple[str, ...],
              dsz: int, cf: float):
    """Per-device body under shard_map. xt: (T_loc, d) local tokens.
    w_*: (E_loc, d, f_loc) local expert shards."""
    T_loc, d = xt.shape
    E = m.n_experts
    E_loc = E // dsz
    k = m.top_k

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router_w)
    probs, gates, idx = _router_topk(logits, k)
    aux = _aux_loss(probs, idx, E)
    aux = jax.lax.pmean(aux, batch_axes)

    A = T_loc * k
    expert_id = idx.reshape(A)                      # (A,)
    gate_val = gates.reshape(A)
    tok_row = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)
    dst = expert_id // E_loc                        # destination device
    e_local = expert_id % E_loc

    C_send = max(1, math.ceil(A * cf / dsz))
    send_x, dst_s, rank_s, order, kept = _sort_dispatch(
        xt[tok_row], dst, dsz, C_send
    )
    meta = jnp.where(kept, e_local[order], -1)
    send_meta = jnp.full((dsz, C_send), -1, jnp.int32).at[
        jnp.where(kept, dst_s, 0), jnp.where(kept, rank_s, 0)
    ].max(jnp.where(kept, meta, -1))

    recv_x = jax.lax.all_to_all(send_x, data_axis, 0, 0, tiled=False)
    recv_meta = jax.lax.all_to_all(send_meta, data_axis, 0, 0, tiled=False)

    n_recv = dsz * C_send
    rx = recv_x.reshape(n_recv, d)
    rm = recv_meta.reshape(n_recv)
    cap_e = max(1, math.ceil(n_recv * cf / max(E_loc, 1)))
    grouped, e_s, rank2, order2, kept2 = _sort_dispatch(
        rx, jnp.where(rm < 0, E_loc, rm), E_loc, cap_e
    )

    h = jnp.einsum("ecd,edf->ecf", grouped, w_gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", grouped, w_up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * u).astype(xt.dtype)
    y_g = jnp.einsum("ecf,efd->ecd", h, w_down,
                     preferred_element_type=jnp.float32)
    # TP-combine the down-projection partials in bf16: halves the wire
    # bytes of the largest per-layer collective (standard TP practice;
    # the f32 accumulation already happened inside the einsum)
    y_g = jax.lax.psum(y_g.astype(xt.dtype), model_axis)

    # scatter expert outputs back to recv order, then reverse the a2a
    ry = jnp.zeros((n_recv, d), xt.dtype)
    src_rows = jnp.where(kept2, order2, n_recv)  # drop overflow
    ry = ry.at[src_rows].add(
        y_g[jnp.where(kept2, e_s, 0), jnp.where(kept2, rank2, 0)]
        * kept2[:, None].astype(xt.dtype),
        mode="drop",
    )
    back = jax.lax.all_to_all(
        ry.reshape(dsz, C_send, d), data_axis, 0, 0, tiled=False
    )

    # combine at the sender: assignment a (in sorted order) lives at
    # back[dst_s[a], rank_s[a]] if kept.
    y_a = back[jnp.where(kept, dst_s, 0), jnp.where(kept, rank_s, 0)]
    y_a = y_a * kept[:, None].astype(xt.dtype)
    y_a = y_a * gate_val[order][:, None].astype(xt.dtype)
    y = jnp.zeros((T_loc, d), xt.dtype).at[tok_row[order]].add(y_a)
    return y, aux


def moe_forward_ep(p: dict, cfg: ModelConfig, x: jax.Array, mesh,
                   *, data_axis: str = "data", model_axis: str = "model"
                   ) -> tuple[jax.Array, jax.Array]:
    """shard_map EP dispatch. x: (B, S, d) with batch sharded over
    (pod?, data). Router weights replicated; experts sharded over data."""
    m = cfg.moe
    B, S, d = x.shape
    dsz = mesh.shape[data_axis]
    has_pod = "pod" in mesh.shape
    batch_axes = (("pod", data_axis) if has_pod else (data_axis,))
    bspec = P(batch_axes, None, None)

    def body(xb, router_w, w_gate, w_up, w_down):
        T_loc = xb.shape[0] * xb.shape[1]
        xt = xb.reshape(T_loc, d)
        y, aux = _ep_local(
            xt, router_w, w_gate, w_up, w_down,
            m=m, data_axis=data_axis, model_axis=model_axis,
            batch_axes=batch_axes, dsz=dsz, cf=m.capacity_factor,
        )
        return y.reshape(xb.shape), aux

    wspec = P(data_axis, None, model_axis)
    y, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(bspec, P(None, None), wspec, wspec,
                  P(data_axis, model_axis, None)),
        out_specs=(bspec, P()),
        check_vma=False,
    )(x, p["router"], p["gate"], p["up"], p["down"])

    if "shared" in p:
        y = y + apply_mlp(p["shared"], x, gated=cfg.gated_mlp, act=cfg.act)
    return y, aux


def moe_forward(p: dict, cfg: ModelConfig, x: jax.Array, mesh=None
                ) -> tuple[jax.Array, jax.Array]:
    """Dispatch-implementation selector: EP when a mesh with a data axis of
    size >1 is in scope, experts divide it, and the batch rows divide the
    DP shard count (shard_map needs exact divisibility — a B=1 long-context
    decode step routes its single token through the dense path instead)."""
    if mesh is not None and "data" in mesh.shape and mesh.shape["data"] > 1:
        batch_axes = [a for a in ("pod", "data") if a in mesh.shape]
        psize = 1
        for a in batch_axes:
            psize *= mesh.shape[a]
        if (
            cfg.moe.n_experts % mesh.shape["data"] == 0
            and x.shape[0] % psize == 0
            and cfg.moe.d_ff_expert % mesh.shape["model"] == 0
        ):
            return moe_forward_ep(p, cfg, x, mesh)
    return moe_forward_dense(p, cfg, x)
