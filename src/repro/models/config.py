"""Model configuration dataclasses + per-layer structure resolution.

A single ``ModelConfig`` covers all assigned families:
  dense       — llama-style decoder (qwen2, starcoder2, granite, qwen3)
  moe         — MoE decoder (llama4 maverick/scout)
  ssm         — attention-free Mamba2 / SSD stack (mamba2-1.3b)
  hybrid      — attn:ssm interleave with MoE (jamba)
  encdec      — encoder-decoder (whisper; conv frontend stubbed)
  vlm         — decoder with a vision-embedding prefix stub (llava-next)

The layer pattern is expressed as a *period*: layer i's mixer/ffn kind is a
pure function of ``i % period``, so stacks scan over ``n_layers // period``
steps of ``period`` sublayers with stackable parameters.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

MixerKind = Literal["attn", "ssm"]
FfnKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int                 # per-expert hidden size
    every: int = 1                   # MoE on layers where i % every == every-1
    n_shared_experts: int = 0        # always-on shared expert(s)
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256                 # SSD chunk length
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack of an enc-dec model (whisper). Frontend is stubbed:
    inputs arrive as precomputed frame embeddings of shape
    (batch, n_frames, d_model)."""
    n_layers: int
    n_frames: int                    # e.g. 1500 for whisper 30s windows


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: `input_specs` provides precomputed patch/frame
    embeddings (batch, n_prefix, d_input); a learned projector maps them to
    d_model and they are prepended to the token sequence."""
    n_prefix: int                    # e.g. 576 anyres patches
    d_input: int                     # e.g. 1024 (CLIP-L) for llava


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # attention flavor
    rope: bool = True
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: int | None = None        # local/chunked attention width
    global_attn_every: int | None = None  # every k-th layer is global (llama4)
    attn_logit_softcap: float | None = None

    # layer-pattern knobs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int | None = None         # hybrid: i % attn_every == attn_every-1

    # enc-dec / frontends
    encoder: EncoderConfig | None = None
    frontend: FrontendConfig | None = None

    # misc
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-5
    act: str = "silu"
    gated_mlp: bool = True
    tie_embeddings: bool = False
    max_seq_len: int = 8192

    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # optimizer state dtype policy (consumed by train/optimizer.py)
    optimizer_state_dtype: str = "float32"

    # ------------------------------------------------------------------
    # layer pattern
    # ------------------------------------------------------------------
    @property
    def period(self) -> int:
        p = 1
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.moe is not None and self.moe.every > 1:
            p = math.lcm(p, self.moe.every)
        if self.global_attn_every:
            p = math.lcm(p, self.global_attn_every)
        if self.n_layers % p != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by period={p}"
            )
        return p

    def mixer_kind(self, i: int) -> MixerKind:
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            assert self.attn_every is not None
            return "attn" if i % self.attn_every == self.attn_every - 1 else "ssm"
        return "attn"

    def ffn_kind(self, i: int) -> FfnKind:
        if self.family == "ssm":
            return "none"  # mamba2 blocks have no separate FFN
        if self.moe is not None and i % self.moe.every == self.moe.every - 1:
            return "moe"
        return "dense"

    def layer_uses_global_attn(self, i: int) -> bool:
        """Llama4-style: chunked attention except every k-th layer (global,
        NoPE). When global_attn_every is unset, a layer is global iff no
        window is configured."""
        if self.attn_window is None:
            return True
        if self.global_attn_every is None:
            return False
        return i % self.global_attn_every == self.global_attn_every - 1

    def layer_uses_rope(self, i: int) -> bool:
        """Llama4 iRoPE: global-attention layers are NoPE."""
        if not self.rope:
            return False
        if self.global_attn_every and self.layer_uses_global_attn(i):
            return False
        return True

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------
    @property
    def n_scan(self) -> int:
        return self.n_layers // self.period

    def kv_cache_len(self, i: int, seq_len: int) -> int:
        """Per-layer KV length: windowed layers only keep the window."""
        if self.mixer_kind(i) != "attn":
            return 0
        if self.attn_window is not None and not self.layer_uses_global_attn(i):
            return min(self.attn_window, seq_len)
        return seq_len

    def is_subquadratic(self) -> bool:
        """Can this arch run the long_500k decode cell? SSM/hybrid always;
        attention archs only if all-global layers are bounded by a window or
        the global layers are a strict subset (llama4 chunked+global)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None

    def param_count_estimate(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            mixer = self.mixer_kind(i)
            if mixer == "attn":
                q = d * self.n_heads * self.d_head
                kv = 2 * d * self.n_kv_heads * self.d_head
                o = self.n_heads * self.d_head * d
                total += q + kv + o
            else:
                assert self.ssm is not None
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                # in_proj -> [z, x, B, C, dt]; out_proj
                total += d * (2 * di + 2 * s.ngroups * s.d_state + nh)
                total += di * d
                total += s.d_conv * (di + 2 * s.ngroups * s.d_state)
            ffn = self.ffn_kind(i)
            if ffn == "dense":
                total += d * dff * (3 if self.gated_mlp else 2)
            elif ffn == "moe":
                m = self.moe
                per_exp = d * m.d_ff_expert * (3 if self.gated_mlp else 2)
                total += m.n_experts * per_exp + m.n_shared_experts * per_exp
                total += d * m.n_experts  # router
        if self.encoder is not None:
            # encoder layers: attn + dense ffn (+ cross-attn lives in decoder count above? no:)
            for _ in range(self.encoder.n_layers):
                total += 4 * d * self.n_heads * self.d_head  # self-attn
                total += d * dff * (3 if self.gated_mlp else 2)
            # decoder cross-attention (one per decoder layer)
            total += self.n_layers * 4 * d * self.n_heads * self.d_head
        if self.frontend is not None:
            total += self.frontend.d_input * d  # projector
        return total

    def active_param_count_estimate(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count_estimate()
        m = self.moe
        total = self.param_count_estimate()
        per_exp = self.d_model * m.d_ff_expert * (3 if self.gated_mlp else 2)
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if self.ffn_kind(i) == "moe"
        )
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_exp
        return total - inactive
