from repro.models.config import (  # noqa: F401
    EncoderConfig,
    FrontendConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.models import model  # noqa: F401
