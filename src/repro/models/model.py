"""Top-level model: embeddings + stacks + loss + prefill/decode entry points.

Batch conventions (all inputs int32/bfloat16 as noted):
  tokens : (B, S_text)            token ids
  labels : (B, S_text)            next-token targets; -1 = ignore
  frames : (B, F, d_model)        [encdec] precomputed frame embeddings (stub)
  patches: (B, P, d_input)        [vlm]    precomputed patch embeddings (stub)

For VLM archs the model sequence is [projected patches ++ token embeds] and
the loss applies only to text positions.  For enc-dec the encoder consumes
``frames`` and the decoder cross-attends to its output.  Non-RoPE archs
(whisper) add sinusoidal absolute position embeddings at the input.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_mod
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_embedding,
    apply_linear,
    apply_norm,
    apply_unembed,
    init_embedding,
    init_linear,
    init_norm,
)
from repro.models.param import PyTree

Constrain = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def _noop(x, axes):
    return x


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_model(cfg: ModelConfig) -> PyTree:
    cross = cfg.encoder is not None
    p: dict[str, Any] = {
        "embed": init_embedding(cfg.vocab_size, cfg.d_model, cfg.param_dtype),
        "stack": tfm.init_stack(cfg, cross=cross),
        "final_norm": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_linear(
            cfg.d_model, cfg.vocab_size, ("embed", "vocab"), cfg.param_dtype
        )
    if cross:
        p["encoder"] = {
            "stack": tfm.init_stack(cfg, n_layers=cfg.encoder.n_layers),
            "final_norm": init_norm(cfg.norm, cfg.d_model, cfg.param_dtype),
        }
    if cfg.frontend is not None:
        p["projector"] = init_linear(
            cfg.frontend.d_input, cfg.d_model, (None, "embed"), cfg.param_dtype
        )
    return p


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) int32 -> (B, S, d) float32 sinusoidal embeddings."""
    half = d // 2
    freqs = jnp.exp(
        -np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _maybe_abs_pos(cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    if cfg.rope:
        return x
    return (x.astype(jnp.float32) + sinusoidal(positions, cfg.d_model)).astype(
        x.dtype
    )


def _unembed(params: PyTree, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return apply_unembed(params["embed"], x)
    return jnp.einsum(
        "...d,dv->...v", x, params["unembed"]["w"],
        preferred_element_type=jnp.float32,
    )


def _encode(params: PyTree, cfg: ModelConfig, frames: jax.Array, *,
            mesh=None, constrain: Constrain = _noop,
            remat: str = "full", unroll: bool = False) -> jax.Array:
    B, F, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    x = (frames.astype(jnp.float32) + sinusoidal(pos, cfg.d_model)).astype(
        jnp.dtype(cfg.activation_dtype)
    )
    x, _ = tfm.stack_forward(
        params["encoder"]["stack"], cfg, x,
        positions=pos, causal=False, mesh=mesh, constrain=constrain,
        remat=remat, unroll=unroll,
    )
    return apply_norm(cfg.norm, params["encoder"]["final_norm"], x, cfg.norm_eps)


def _input_embeds(params: PyTree, cfg: ModelConfig, batch: dict,
                  constrain: Constrain) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions (B,S)). Prepends projected patches for
    VLM archs."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    x = apply_embedding(params["embed"], tokens)
    if cfg.frontend is not None:
        patches = batch["patches"].astype(x.dtype)
        pre = apply_linear(params["projector"], patches)
        x = jnp.concatenate([pre, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _maybe_abs_pos(cfg, x, positions)
    x = constrain(x, ("batch", "seq", "embed"))
    return x.astype(jnp.dtype(cfg.activation_dtype)), positions


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def forward(params: PyTree, cfg: ModelConfig, batch: dict, *,
            mesh=None, constrain: Constrain = _noop, remat: str = "full",
            unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, vocab) fp32, moe_aux)."""
    cross = cfg.encoder is not None
    enc_out = None
    if cross:
        enc_out = _encode(params, cfg, batch["frames"], mesh=mesh,
                          constrain=constrain, remat=remat, unroll=unroll)
    x, positions = _input_embeds(params, cfg, batch, constrain)
    x, aux = tfm.stack_forward(
        params["stack"], cfg, x,
        positions=positions, causal=True, cross=cross, enc_out=enc_out,
        mesh=mesh, constrain=constrain, remat=remat, unroll=unroll,
    )
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    if cfg.frontend is not None:  # only text positions produce logits
        x = x[:, cfg.frontend.n_prefix:, :]
    logits = _unembed(params, cfg, x)
    return logits, aux


def loss_fn(params: PyTree, cfg: ModelConfig, batch: dict, *,
            mesh=None, constrain: Constrain = _noop, remat: str = "full",
            z_loss: float = 1e-4, unroll: bool = False
            ) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch, mesh=mesh, constrain=constrain,
                          remat=remat, unroll=unroll)
    # keep the fp32 logits vocab-sharded through the CE math: without this
    # GSPMD gathers (B_loc, S, V) f32 per chip — 3 GB × several ops for a
    # 200k vocab (logsumexp/scatter partition fine over a sharded V)
    logits = constrain(logits, ("batch_logits", "seq", "vocab_act"))
    labels = batch["labels"]
    valid = (labels >= 0)
    labels_c = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * valid
    n = jnp.maximum(jnp.sum(valid), 1)
    ce_mean = jnp.sum(ce) / n
    zl = z_loss * jnp.sum(jnp.square(logz) * valid) / n
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    total = ce_mean + zl + aux_w * aux
    metrics = {
        "loss": total,
        "ce": ce_mean,
        "z_loss": zl,
        "moe_aux": aux,
        "tokens": n.astype(jnp.float32),
    }
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
               abstract: bool = False) -> PyTree:
    dtype = jnp.dtype(cfg.activation_dtype)
    n_enc = cfg.encoder.n_frames if cfg.encoder is not None else 0
    return tfm.init_stack_cache(
        cfg, batch, seq_len, dtype,
        cross=cfg.encoder is not None, n_enc=n_enc, abstract=abstract,
    )


def prefill(params: PyTree, cfg: ModelConfig, batch: dict, cache: PyTree, *,
            mesh=None, constrain: Constrain = _noop, unroll: bool = False
            ) -> tuple[jax.Array, PyTree, jax.Array]:
    """Processes the prompt, fills the cache.  Returns (last_logits (B, V),
    new_cache, lengths (B,))."""
    cross = cfg.encoder is not None
    enc_out = None
    if cross:
        enc_out = _encode(params, cfg, batch["frames"], mesh=mesh,
                          constrain=constrain, remat="none", unroll=unroll)
    x, positions = _input_embeds(params, cfg, batch, constrain)
    x, new_cache = tfm.stack_prefill(
        params["stack"], cfg, x, cache,
        positions=positions, cross=cross, enc_out=enc_out,
        mesh=mesh, constrain=constrain, unroll=unroll,
    )
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    last = x[:, -1, :]
    logits = _unembed(params, cfg, last)
    lengths = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    return logits, new_cache, lengths


def decode_step(params: PyTree, cfg: ModelConfig, tokens_t: jax.Array,
                cache: PyTree, lengths: jax.Array, *,
                mesh=None, constrain: Constrain = _noop,
                unroll: bool = False
                ) -> tuple[jax.Array, PyTree, jax.Array]:
    """One token per sequence.  tokens_t: (B, 1).  Returns (logits (B, V),
    new_cache, new_lengths)."""
    x = apply_embedding(params["embed"], tokens_t)
    x = _maybe_abs_pos(cfg, x, lengths[:, None])
    x = x.astype(jnp.dtype(cfg.activation_dtype))
    x, new_cache = tfm.stack_decode(
        params["stack"], cfg, x, cache, lengths,
        cross=cfg.encoder is not None, mesh=mesh, constrain=constrain,
        unroll=unroll,
    )
    x = apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, 0, :])
    return logits, new_cache, lengths + 1
