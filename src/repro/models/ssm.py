"""Mamba2 (SSD) block: fused input projection, causal depthwise conv,
selective state-space scan, gated RMS norm, output projection.

Used standalone (mamba2-1.3b) and inside the jamba hybrid interleave.  The
scan core is kernels.ssd (Pallas on TPU, chunked jnp elsewhere).

Decode state per layer:
  conv:  (B, d_conv-1, conv_ch)   rolling conv window (conv_ch = di + 2*G*N)
  ssm:   (B, H, P, N) fp32        recurrent state
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ops import ssd, ssd_decode_step
from repro.models.config import ModelConfig
from repro.models.layers import apply_linear, init_linear
from repro.models.param import Param, dense_param, ones_param, zeros_param

Constrain = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def _noop(x, axes):
    return x


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    conv_ch = di + 2 * s.ngroups * s.d_state
    return s, di, H, conv_ch


def init_ssm(cfg: ModelConfig) -> dict:
    s, di, H, conv_ch = _dims(cfg)
    d, dt = cfg.d_model, cfg.param_dtype
    proj_out = 2 * di + 2 * s.ngroups * s.d_state + H  # [z, xBC, dt]

    def a_log_init(key):
        # A in [1, 16) as in the Mamba2 reference init
        return jnp.log(
            jax.random.uniform(key, (H,), jnp.float32, 1.0, 16.0)
        )

    def dt_bias_init(key):
        # dt ~ LogUniform(1e-3, 1e-1) through softplus
        u = jax.random.uniform(key, (H,), jnp.float32)
        dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus

    return {
        "in_proj": init_linear(d, proj_out, ("embed", "ssm"), dt),
        "conv_w": dense_param((s.d_conv, conv_ch), ("conv", "ssm"), dt,
                              fan_in=s.d_conv),
        "conv_b": zeros_param((conv_ch,), ("ssm",), dt),
        "A_log": Param((H,), "float32", (None,), a_log_init),
        "D": ones_param((H,), (None,), "float32"),
        "dt_bias": Param((H,), "float32", (None,), dt_bias_init),
        "norm_scale": ones_param((di,), ("ssm",), dt),
        "out_proj": init_linear(di, d, ("ssm", "embed"), dt),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    s, di, H, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * gn], axis=-1)
    return z, xbc, dt  # dt: (..., H)


def _split_xbc(cfg: ModelConfig, xbc: jax.Array):
    s, di, H, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    x, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    lead = x.shape[:-1]
    x = x.reshape(*lead, H, s.head_dim)
    B = B.reshape(*lead, s.ngroups, s.d_state)
    C = C.reshape(*lead, s.ngroups, s.d_state)
    return x, B, C


def _gated_norm(p: dict, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    out = yf * jax.lax.rsqrt(var + eps) * p["norm_scale"].astype(jnp.float32)
    return out.astype(y.dtype)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps (K, C)."""
    K = w.shape[0]
    out = jax.lax.conv_general_dilated(
        xbc,
        w[:, None, :].astype(xbc.dtype),  # (K, 1, C) HIO
        window_strides=(1,),
        padding=[(K - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=xbc.shape[-1],
    )
    return out + b.astype(out.dtype)


def ssm_forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    constrain: Constrain = _noop,
    initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """Full-sequence Mamba2 block. x: (B, S, d_model)."""
    s, di, H, _ = _dims(cfg)
    proj = apply_linear(p["in_proj"], x)
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs, B, C = _split_xbc(cfg, xbc)
    xs = constrain(xs, ("batch", "seq", "ssm_heads", None))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd(xs, dt, A, B, C, p["D"], chunk=s.chunk,
                   initial_state=initial_state)
    y = constrain(y, ("batch", "seq", "ssm_heads", None))
    y = y.reshape(*y.shape[:-2], di)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = apply_linear(p["out_proj"], y)
    if return_state:
        # decode-ready state: SSD recurrent state + the raw conv window tail
        conv_tail = xbc_raw[:, -(s.d_conv - 1):, :]
        return out, {"ssm": state, "conv": conv_tail}
    return out


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, di, H, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype=dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }


def ssm_state_spec(cfg: ModelConfig, batch: int, dtype) -> dict:
    s, di, H, conv_ch = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, conv_ch), dtype),
        "ssm": jax.ShapeDtypeStruct((batch, H, s.head_dim, s.d_state),
                                    jnp.float32),
    }


def ssm_decode(
    p: dict,
    cfg: ModelConfig,
    x_t: jax.Array,      # (B, 1, d_model)
    state: dict,
    *,
    constrain: Constrain = _noop,
) -> tuple[jax.Array, dict]:
    s, di, H, conv_ch = _dims(cfg)
    B = x_t.shape[0]
    proj = apply_linear(p["in_proj"], x_t[:, 0])  # (B, proj_out)
    z, xbc, dt = _split_proj(cfg, proj)

    # rolling conv
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32),
        p["conv_w"].astype(jnp.float32)
    ) + p["conv_b"].astype(jnp.float32)
    xbc_t = jax.nn.silu(conv_out).astype(x_t.dtype)
    new_conv = window[:, 1:, :]

    xs, Bm, Cm = _split_xbc(cfg, xbc_t)  # (B,H,P), (B,G,N), (B,G,N)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    new_ssm, y = ssd_decode_step(state["ssm"], xs, dtf, A, Bm, Cm, p["D"])
    y = y.reshape(B, di)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = apply_linear(p["out_proj"], y)[:, None, :]  # (B,1,d)
    return out, {"conv": new_conv, "ssm": new_ssm}
