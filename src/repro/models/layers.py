"""Core layer primitives: norms, linears, embeddings, RoPE, MLPs.

Every module is an (init, apply) function pair.  ``init_*`` returns a tree
of :class:`~repro.models.param.Param`; ``apply_*`` consumes the matching
tree of plain arrays.  Logical axis names used here:

  vocab   — token embedding rows          (sharded over "model")
  embed   — the d_model axis              (FSDP-sharded over "data" on big archs)
  heads   — flattened q-head * head_dim   (sharded over "model")
  kv      — flattened kv-head * head_dim  (sharded over "model")
  mlp     — the d_ff axis                 (sharded over "model")
  expert  — MoE expert axis               (sharded over "data": expert parallelism)
  conv    — conv kernel taps              (replicated)
  ssm     — SSM state / inner axes        (sharded over "model")
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import (
    Param,
    dense_param,
    embed_param,
    ones_param,
    zeros_param,
)

Dtype = Any


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype: Dtype) -> dict:
    return {"scale": ones_param((d,), ("embed",), dtype)}


def apply_rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype: Dtype) -> dict:
    return {
        "scale": ones_param((d,), ("embed",), dtype),
        "bias": zeros_param((d,), ("embed",), dtype),
    }


def apply_layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_norm(kind: str, d: int, dtype: Dtype) -> dict:
    return init_layernorm(d, dtype) if kind == "layernorm" else init_rmsnorm(d, dtype)


def apply_norm(kind: str, p: dict, x: jax.Array, eps: float) -> jax.Array:
    if kind == "layernorm":
        return apply_layernorm(p, x, eps)
    return apply_rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# Linear / embedding
# ---------------------------------------------------------------------------

def init_linear(
    d_in: int,
    d_out: int,
    axes: tuple[str | None, str | None],
    dtype: Dtype,
    *,
    bias: bool = False,
    bias_axis: str | None = None,
    scale: float = 1.0,
) -> dict:
    p = {"w": dense_param((d_in, d_out), axes, dtype, fan_in=d_in, scale=scale)}
    if bias:
        p["b"] = zeros_param((d_out,), (bias_axis,), dtype)
    return p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    y = jnp.einsum(
        "...d,df->...f", x, p["w"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_embedding(vocab: int, d: int, dtype: Dtype) -> dict:
    return {"table": embed_param((vocab, d), ("vocab", "embed"), dtype)}


def apply_embedding(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def apply_unembed(p: dict, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table^T (fp32 logits)."""
    return jnp.einsum(
        "...d,vd->...v", x, p["table"], preferred_element_type=jnp.float32
    )


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Rotate pairs (x[..., ::2], x[..., 1::2]).  x: [..., seq, heads, d_head],
    positions: broadcastable to [..., seq]."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------

def init_mlp(
    d_model: int,
    d_ff: int,
    dtype: Dtype,
    *,
    gated: bool = True,
    act: str = "silu",
) -> dict:
    p = {
        "up": dense_param((d_model, d_ff), ("embed", "mlp"), dtype),
        "down": dense_param((d_ff, d_model), ("mlp", "embed"), dtype, fan_in=d_ff),
    }
    if gated:
        p["gate"] = dense_param((d_model, d_ff), ("embed", "mlp"), dtype)
    del act  # activation choice lives in the config, not the param tree
    return p


def _activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {name}")


def apply_mlp(p: dict, x: jax.Array, *, gated: bool = True, act: str = "silu") -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, p["up"], preferred_element_type=jnp.float32)
    if gated:
        gate = jnp.einsum(
            "...d,df->...f", x, p["gate"], preferred_element_type=jnp.float32
        )
        h = _activation(act, gate) * up
    else:
        h = _activation(act, up)
    h = h.astype(x.dtype)
    return jnp.einsum(
        "...f,fd->...d", h, p["down"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
