"""Layer-stack composition: pre-norm blocks scanned over depth.

The stack is organized around the config's layer *period* p: layer i's
(mixer, ffn, window, rope) kinds depend only on ``slot = i % p``, so the
parameters are stored as ``{"slot0": stacked, ..., "slot{p-1}": stacked}``
with each leaf stacked over ``n_scan = n_layers // p``.  One ``lax.scan``
over n_scan applies p sublayers per step — HLO size is O(p), independent of
depth (critical for the 48–64 layer archs on the 512-device dry-run).

Decode threads per-layer caches through the same scan (xs = (params, cache),
ys = new cache).  Cache *structure* is slot-static: attention slots carry
{k, v, pos}, SSM slots carry {conv, ssm}, cross-attention adds {xk, xv, xpos}.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.param import stack_params

Constrain = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def _noop(x, axes):
    return x


# ---------------------------------------------------------------------------
# Static per-slot layer description
# ---------------------------------------------------------------------------

class SlotSpec:
    """Static (trace-time) description of sublayer slot `s` of the period."""

    def __init__(self, cfg: ModelConfig, slot: int, *, cross: bool = False):
        self.slot = slot
        self.mixer = cfg.mixer_kind(slot)
        self.ffn = cfg.ffn_kind(slot)
        self.cross = cross
        self.rope_on = cfg.layer_uses_rope(slot)
        if self.mixer == "attn":
            if cfg.attn_window is not None and not cfg.layer_uses_global_attn(slot):
                self.window = cfg.attn_window
            else:
                self.window = None
        else:
            self.window = None

    def cache_capacity(self, cfg: ModelConfig, seq_len: int) -> int:
        if self.window is not None:
            return min(self.window, seq_len)
        return seq_len


def slot_specs(cfg: ModelConfig, *, cross: bool = False) -> list[SlotSpec]:
    return [SlotSpec(cfg, s, cross=cross) for s in range(cfg.period)]


# ---------------------------------------------------------------------------
# Single block init/apply
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, spec: SlotSpec) -> dict:
    d, dt = cfg.d_model, cfg.param_dtype
    p: dict[str, Any] = {"norm1": init_norm(cfg.norm, d, dt)}
    if spec.mixer == "attn":
        p["mixer"] = attn.init_attention(cfg)
    else:
        p["mixer"] = ssm_mod.init_ssm(cfg)
    if spec.cross:
        p["norm_ca"] = init_norm(cfg.norm, d, dt)
        p["cross"] = attn.init_attention(cfg, cross=True)
    if spec.ffn == "dense":
        p["norm2"] = init_norm(cfg.norm, d, dt)
        p["ffn"] = init_mlp(d, cfg.d_ff, dt, gated=cfg.gated_mlp, act=cfg.act)
    elif spec.ffn == "moe":
        p["norm2"] = init_norm(cfg.norm, d, dt)
        p["ffn"] = moe_mod.init_moe(cfg)
    return p


def apply_block(
    p: dict,
    cfg: ModelConfig,
    spec: SlotSpec,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool,
    mesh=None,
    enc_out: jax.Array | None = None,
    constrain: Constrain = _noop,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        mix = attn.attn_forward(
            p["mixer"], cfg, h,
            rope_on=spec.rope_on, window=spec.window, causal=causal,
            positions=positions, constrain=constrain, mesh=mesh,
        )
    else:
        mix = ssm_mod.ssm_forward(p["mixer"], cfg, h, constrain=constrain)
    x = x + mix
    if spec.cross:
        assert enc_out is not None
        h = apply_norm(cfg.norm, p["norm_ca"], x, cfg.norm_eps)
        x = x + attn.attn_forward(
            p["cross"], cfg, h, kv_ctx=enc_out, constrain=constrain,
        )
    if spec.ffn == "dense":
        h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        x = x + apply_mlp(p["ffn"], h, gated=cfg.gated_mlp, act=cfg.act)
    elif spec.ffn == "moe":
        h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        y, aux_l = moe_mod.moe_forward(p["ffn"], cfg, h, mesh=mesh)
        x = x + y
        aux = aux + aux_l
    x = constrain(x, ("batch", "seq", "embed"))
    return x, aux


# ---------------------------------------------------------------------------
# Stack init
# ---------------------------------------------------------------------------

def init_stack(cfg: ModelConfig, *, n_layers: int | None = None,
               cross: bool = False) -> dict:
    """Stacked params: {"slotS": leaf(n_scan, ...)}."""
    n_layers = cfg.n_layers if n_layers is None else n_layers
    p_period = cfg.period
    if n_layers % p_period != 0:
        raise ValueError((n_layers, p_period))
    n_scan = n_layers // p_period
    specs = slot_specs(cfg, cross=cross)
    out = {}
    for spec in specs:
        out[f"slot{spec.slot}"] = stack_params(
            [init_block(cfg, spec) for _ in range(n_scan)]
        )
    return out


# ---------------------------------------------------------------------------
# Stack forward (training / prefill-as-forward / encoder)
# ---------------------------------------------------------------------------

def stack_forward(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    causal: bool = True,
    cross: bool = False,
    enc_out: jax.Array | None = None,
    mesh=None,
    constrain: Constrain = _noop,
    remat: str = "full",
    unroll: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (x, total_moe_aux).  ``unroll=True`` unrolls the depth scan
    (used by the dry-run cost analysis: XLA counts a while body once, so
    scanned stacks under-report FLOPs by n_scan; see launch/dryrun.py)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    specs = slot_specs(cfg, cross=cross)

    def step(x, slices):
        aux = jnp.zeros((), jnp.float32)
        for spec in specs:
            bp = slices[f"slot{spec.slot}"]
            x, aux_l = apply_block(
                bp, cfg, spec, x,
                positions=positions, causal=causal, mesh=mesh,
                enc_out=enc_out, constrain=constrain,
            )
            aux = aux + aux_l
        return x, aux

    if remat == "full":
        step = jax.checkpoint(step)
    elif remat == "dots":
        step = jax.checkpoint(
            step,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    elif remat != "none":
        raise ValueError(f"unknown remat policy {remat}")

    def body(carry, xs):
        x, aux = carry
        x, aux_l = step(x, xs)
        return (x, aux + aux_l), None

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params, unroll=unroll
    )
    return x, aux


# ---------------------------------------------------------------------------
# Decode: caches threaded through the scan
# ---------------------------------------------------------------------------

def init_stack_cache(
    cfg: ModelConfig, batch: int, seq_len: int, dtype,
    *, cross: bool = False, n_enc: int = 0, abstract: bool = False,
    n_layers: int | None = None,
) -> dict:
    """Cache pytree matching the stacked-params scan structure; each leaf has
    leading n_scan axis."""
    n_layers = cfg.n_layers if n_layers is None else n_layers
    n_scan = n_layers // cfg.period
    specs = slot_specs(cfg, cross=cross)
    out = {}
    for spec in specs:
        cap = spec.cache_capacity(cfg, seq_len)
        slot: dict[str, Any] = {}
        if spec.mixer == "attn":
            base = (attn.kv_cache_spec(cfg, batch, cap, dtype) if abstract
                    else attn.init_kv_cache(cfg, batch, cap, dtype))
            slot["self"] = base
        else:
            base = (ssm_mod.ssm_state_spec(cfg, batch, dtype) if abstract
                    else ssm_mod.init_ssm_state(cfg, batch, dtype))
            slot["ssm"] = base
        if spec.cross:
            xc = (attn.kv_cache_spec(cfg, batch, n_enc, dtype) if abstract
                  else attn.init_kv_cache(cfg, batch, n_enc, dtype))
            slot["crosskv"] = xc
        out[f"slot{spec.slot}"] = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((n_scan, *l.shape), l.dtype)
            if abstract else jnp.broadcast_to(l[None], (n_scan, *l.shape)).copy(),
            slot,
        )
    return out


def apply_block_decode(
    p: dict,
    cfg: ModelConfig,
    spec: SlotSpec,
    x_t: jax.Array,       # (B, 1, d)
    cache: dict,
    lengths: jax.Array,   # (B,)
    *,
    mesh=None,
    constrain: Constrain = _noop,
) -> tuple[jax.Array, dict]:
    new_cache: dict[str, Any] = {}
    h = apply_norm(cfg.norm, p["norm1"], x_t, cfg.norm_eps)
    if spec.mixer == "attn":
        mix, kvc = attn.attn_decode(
            p["mixer"], cfg, h, cache["self"], lengths,
            rope_on=spec.rope_on, window=spec.window, constrain=constrain,
        )
        new_cache["self"] = kvc
    else:
        mix, st = ssm_mod.ssm_decode(p["mixer"], cfg, h, cache["ssm"],
                                     constrain=constrain)
        new_cache["ssm"] = st
    x_t = x_t + mix
    if spec.cross:
        h = apply_norm(cfg.norm, p["norm_ca"], x_t, cfg.norm_eps)
        y, _ = attn.attn_decode(
            p["cross"], cfg, h, cache["crosskv"], lengths, cross=True,
        )
        x_t = x_t + y
        new_cache["crosskv"] = cache["crosskv"]
    if spec.ffn == "dense":
        h = apply_norm(cfg.norm, p["norm2"], x_t, cfg.norm_eps)
        x_t = x_t + apply_mlp(p["ffn"], h, gated=cfg.gated_mlp, act=cfg.act)
    elif spec.ffn == "moe":
        h = apply_norm(cfg.norm, p["norm2"], x_t, cfg.norm_eps)
        y, _ = moe_mod.moe_forward(p["ffn"], cfg, h, mesh=mesh)
        x_t = x_t + y
    return x_t, new_cache


def stack_decode(
    params: dict,
    cfg: ModelConfig,
    x_t: jax.Array,
    cache: dict,
    lengths: jax.Array,
    *,
    cross: bool = False,
    mesh=None,
    constrain: Constrain = _noop,
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    specs = slot_specs(cfg, cross=cross)

    def body(x_t, xs):
        slices, cache_slices = xs
        new_slots = {}
        for spec in specs:
            key = f"slot{spec.slot}"
            x_t, nc = apply_block_decode(
                slices[key], cfg, spec, x_t, cache_slices[key], lengths,
                mesh=mesh, constrain=constrain,
            )
            new_slots[key] = nc
        return x_t, new_slots

    x_t, new_cache = jax.lax.scan(body, x_t, (params, cache),
                                  unroll=unroll)
    return x_t, new_cache


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also fills the decode caches
# ---------------------------------------------------------------------------

def apply_block_prefill(
    p: dict,
    cfg: ModelConfig,
    spec: SlotSpec,
    x: jax.Array,
    cache: dict,
    *,
    positions: jax.Array,
    mesh=None,
    enc_out: jax.Array | None = None,
    constrain: Constrain = _noop,
) -> tuple[jax.Array, dict]:
    new_cache: dict[str, Any] = {}
    h = apply_norm(cfg.norm, p["norm1"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        mix, (k, v) = attn.attn_forward(
            p["mixer"], cfg, h,
            rope_on=spec.rope_on, window=spec.window, causal=True,
            positions=positions, constrain=constrain, return_kv=True,
            mesh=mesh,
        )
        new_cache["self"] = attn.cache_fill(cache["self"], k, v, positions)
    else:
        mix, st = ssm_mod.ssm_forward(
            p["mixer"], cfg, h, constrain=constrain, return_state=True,
        )
        new_cache["ssm"] = {
            "conv": st["conv"].astype(cache["ssm"]["conv"].dtype),
            "ssm": st["ssm"],
        }
    x = x + mix
    if spec.cross:
        assert enc_out is not None
        h = apply_norm(cfg.norm, p["norm_ca"], x, cfg.norm_eps)
        y, (xk, xv) = attn.attn_forward(
            p["cross"], cfg, h, kv_ctx=enc_out, constrain=constrain,
            return_kv=True,
        )
        x = x + y
        B, F = xk.shape[0], xk.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
        new_cache["crosskv"] = attn.cache_fill(cache["crosskv"], xk, xv, enc_pos)
    if spec.ffn == "dense":
        h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        x = x + apply_mlp(p["ffn"], h, gated=cfg.gated_mlp, act=cfg.act)
    elif spec.ffn == "moe":
        h = apply_norm(cfg.norm, p["norm2"], x, cfg.norm_eps)
        y, _ = moe_mod.moe_forward(p["ffn"], cfg, h, mesh=mesh)
        x = x + y
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache


def stack_prefill(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    cache: dict,
    *,
    positions: jax.Array | None = None,
    cross: bool = False,
    enc_out: jax.Array | None = None,
    mesh=None,
    constrain: Constrain = _noop,
    unroll: bool = False,
) -> tuple[jax.Array, dict]:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    specs = slot_specs(cfg, cross=cross)

    def body(x, xs):
        slices, cache_slices = xs
        new_slots = {}
        for spec in specs:
            key = f"slot{spec.slot}"
            x, nc = apply_block_prefill(
                slices[key], cfg, spec, x, cache_slices[key],
                positions=positions, mesh=mesh, enc_out=enc_out,
                constrain=constrain,
            )
            new_slots[key] = nc
        return x, new_slots

    x, new_cache = jax.lax.scan(body, x, (params, cache), unroll=unroll)
    return x, new_cache
