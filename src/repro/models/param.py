"""Parameter container with logical-axis annotations.

Params are plain nested dicts whose leaves are ``Param`` objects during
construction.  ``unzip`` splits a Param tree into (values, logical_axes) so
the training/serving code works on plain arrays while the sharding layer
derives PartitionSpecs from the axes tree.  ``Param`` is deliberately *not*
a pytree node: it is treated as a leaf and unzipped exactly once.

Initializers are lazy (callables), so the same builder runs in three modes:
  * real init      — materialize arrays (smoke tests, examples)
  * abstract init  — ShapeDtypeStruct only (dry-run; no allocation)
  * spec-only      — just the logical axes (sharding rules)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class Param:
    """A single weight: shape/dtype + logical axis names + lazy initializer."""

    shape: tuple[int, ...]
    dtype: Any
    axes: tuple[str | None, ...]
    init: Callable[[jax.Array], jax.Array]  # rng -> array

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(
                f"Param rank mismatch: shape {self.shape} vs axes {self.axes}"
            )

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def _tree_map_params(fn: Callable[[Param], Any], tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_param)


def axes_tree(tree: PyTree) -> PyTree:
    """Extract the logical-axes tree (tuples of axis names) from a Param tree."""
    return _tree_map_params(lambda p: p.axes, tree)


def abstract_values(tree: PyTree) -> PyTree:
    """ShapeDtypeStruct tree — used by the dry-run (never allocates)."""
    return _tree_map_params(lambda p: p.abstract(), tree)


def materialize(tree: PyTree, rng: jax.Array) -> PyTree:
    """Materialize all params with independent fold_in'd keys (real init)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_param)
    keys = jax.random.split(rng, len(leaves))
    vals = [p.init(k).astype(p.dtype) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def param_count(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    return sum(int(np.prod(p.shape)) for p in leaves)


def param_bytes(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_param)
    return sum(
        int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize for p in leaves
    )


# ---------------------------------------------------------------------------
# Standard initializers
# ---------------------------------------------------------------------------

def normal_init(stddev: float) -> Callable[[jax.Array], jax.Array]:
    def init(key, *, _s=stddev):
        return _s * jax.random.normal(key, (), dtype=jnp.float32)

    return init


def dense_param(
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    dtype: Any,
    *,
    fan_in: int | None = None,
    scale: float = 1.0,
) -> Param:
    """Truncated-normal matmul weight with 1/sqrt(fan_in) scaling."""
    if fan_in is None:
        fan_in = shape[0]
    stddev = scale / math.sqrt(max(fan_in, 1))

    def init(key, *, shape=shape, stddev=stddev):
        return stddev * jax.random.truncated_normal(
            key, -2.0, 2.0, shape, dtype=jnp.float32
        )

    return Param(shape, dtype, axes, init)


def embed_param(
    shape: tuple[int, ...], axes: tuple[str | None, ...], dtype: Any
) -> Param:
    def init(key, *, shape=shape):
        return jax.random.normal(key, shape, dtype=jnp.float32)

    return Param(shape, dtype, axes, init)


def zeros_param(
    shape: tuple[int, ...], axes: tuple[str | None, ...], dtype: Any
) -> Param:
    return Param(shape, dtype, axes, lambda key, *, shape=shape: jnp.zeros(shape))


def ones_param(
    shape: tuple[int, ...], axes: tuple[str | None, ...], dtype: Any
) -> Param:
    return Param(shape, dtype, axes, lambda key, *, shape=shape: jnp.ones(shape))


def const_param(
    value: np.ndarray, axes: tuple[str | None, ...], dtype: Any
) -> Param:
    arr = np.asarray(value)
    return Param(
        tuple(arr.shape), dtype, axes, lambda key, *, arr=arr: jnp.asarray(arr)
    )


# ---------------------------------------------------------------------------
# Layer stacking (for lax.scan over depth)
# ---------------------------------------------------------------------------

def stack_params(trees: list[PyTree]) -> PyTree:
    """Stack structurally identical Param trees along a new leading axis.

    The leading axis is the scan (layer) axis and is never sharded, so its
    logical axis name is ``"layers"`` (mapped to None by the sharding rules).
    """
    if not trees:
        raise ValueError("cannot stack zero layers")
    flat = [jax.tree_util.tree_flatten(t, is_leaf=is_param) for t in trees]
    treedef = flat[0][1]
    for _, td in flat[1:]:
        if td != treedef:
            raise ValueError("stack_params: mismatched layer structures")
    stacked = []
    for leaves in zip(*[f[0] for f in flat]):
        p0 = leaves[0]
        n = len(leaves)
        for p in leaves[1:]:
            if p.shape != p0.shape or p.axes != p0.axes:
                raise ValueError(
                    f"stack_params: leaf mismatch {p.shape}/{p.axes} vs"
                    f" {p0.shape}/{p0.axes}"
                )

        def init(key, *, ps=leaves):
            keys = jax.random.split(key, len(ps))
            return jnp.stack([p.init(k) for p, k in zip(ps, keys)])

        stacked.append(
            Param((n, *p0.shape), p0.dtype, ("layers", *p0.axes), init)
        )
    return jax.tree_util.tree_unflatten(treedef, stacked)
