"""GQA attention block: projections, RoPE, QK-norm, KV caches, windows.

The (q,k,v) -> o core is delegated to kernels.flash_attention.ops (Pallas on
TPU, chunked jnp elsewhere).  Everything here is position-driven so the same
code path covers training, prefill, rolling-window decode and cross-attention.

KV cache layout per attention layer (stacked over the scan axis by the stack):
  k:   (B, C, Hkv, Dh)    C = capacity (full seq len, or window for local layers)
  v:   (B, C, Hkv, Dh)
  pos: (B, C) int32       absolute position held in each slot; -1 = empty

Rolling-window layers write slot = position % C; global layers slot = position.
RoPE is applied before caching, so cached keys never need re-rotation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention.ops import flash_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_linear,
    apply_rmsnorm,
    apply_rope,
    init_linear,
    ones_param,
)

Constrain = Callable[[jax.Array, tuple[str | None, ...]], jax.Array]


def _noop_constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    return x


def init_attention(cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.param_dtype
    p = {
        "wq": init_linear(d, H * Dh, ("embed", "heads"), dt,
                          bias=cfg.qkv_bias, bias_axis="heads"),
        "wk": init_linear(d, Hkv * Dh, ("embed", "kv"), dt,
                          bias=cfg.qkv_bias, bias_axis="kv"),
        "wv": init_linear(d, Hkv * Dh, ("embed", "kv"), dt,
                          bias=cfg.qkv_bias, bias_axis="kv"),
        "wo": init_linear(H * Dh, d, ("heads", "embed"), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": ones_param((Dh,), (None,), dt)}
        p["k_norm"] = {"scale": ones_param((Dh,), (None,), dt)}
    return p


def _project_qkv(
    p: dict,
    cfg: ModelConfig,
    xq: jax.Array,
    xkv: jax.Array,
    *,
    rope_on: bool,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    constrain: Constrain,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    q = apply_linear(p["wq"], xq).reshape(B, Sq, H, Dh)
    k = apply_linear(p["wk"], xkv).reshape(B, Skv, Hkv, Dh)
    v = apply_linear(p["wv"], xkv).reshape(B, Skv, Hkv, Dh)

    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope_on:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)

    q = constrain(q, ("batch", "seq", "heads_act", None))
    k = constrain(k, ("batch", "seq", "kv_act", None))
    v = constrain(v, ("batch", "seq", "kv_act", None))
    return q, k, v


def _sp_attention(
    q, k, v, q_pos, kv_pos, mesh, *, causal, window, softcap,
):
    """Sequence-parallel attention under shard_map (explicit collectives).

    Used when the head count does not divide the TP axis (llama4: 40 heads
    on model=16): instead of letting GSPMD replicate the attention 16×
    (or all-gather Q per head group — both observed, both awful), shard
    the SEQ dim over "model", all-gather only K/V (+positions) per layer,
    and run the local flash path on the chip's query rows.  Absolute
    positions make cross-shard causality exact with no ring schedule.
    Differentiable: the all-gather transposes to a reduce-scatter of
    dK/dV in the backward pass.
    """
    tp = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    psize = 1
    for a in batch_axes:
        psize *= mesh.shape[a]
    bax = batch_axes if (psize > 1 and q.shape[0] % psize == 0) else None

    def body(q_l, k_l, v_l, qp_l, kp_l):
        k_f = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        kp_f = jax.lax.all_gather(kp_l, "model", axis=1, tiled=True)
        return flash_attention(
            q_l, k_f, v_f, qp_l, kp_f,
            causal=causal, window=window, softcap=softcap,
        )

    qspec = P(bax, "model", None, None)
    pspec = P(bax, "model")
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(qspec, qspec, qspec, pspec, pspec),
        out_specs=qspec,
        check_vma=False,
    )(q, k, v, q_pos, kv_pos)


def _use_sp(cfg: ModelConfig, mesh, Sq: int, Skv: int, B: int,
            cross: bool) -> bool:
    if mesh is None or cross:
        return False
    tp = dict(mesh.shape).get("model", 1)
    if tp <= 1 or cfg.n_heads % tp == 0:
        return False  # plain TP head sharding works
    return Sq > 1 and Sq % tp == 0 and Skv % tp == 0


def attn_forward(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    rope_on: bool = True,
    window: int | None = None,
    causal: bool = True,
    positions: jax.Array | None = None,
    kv_ctx: jax.Array | None = None,
    kv_ctx_positions: jax.Array | None = None,
    constrain: Constrain = _noop_constrain,
    return_kv: bool = False,
    mesh=None,
):
    """Full-sequence attention (training / prefill / encoder / cross).
    With return_kv=True returns (out, (k, v)) for cache filling — k is
    post-RoPE, matching the decode-path cache convention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if kv_ctx is None:  # self-attention
        xkv, kv_positions = x, positions
    else:  # cross-attention over encoder output
        xkv = kv_ctx
        if kv_ctx_positions is None:
            kv_ctx_positions = jnp.broadcast_to(
                jnp.arange(xkv.shape[1], dtype=jnp.int32), (B, xkv.shape[1])
            )
        kv_positions = kv_ctx_positions
        causal = False

    q, k, v = _project_qkv(
        p, cfg, x, xkv,
        rope_on=rope_on and kv_ctx is None,
        q_positions=positions, kv_positions=kv_positions,
        constrain=constrain,
    )
    if _use_sp(cfg, mesh, q.shape[1], k.shape[1], q.shape[0],
               kv_ctx is not None):
        o = _sp_attention(
            q, k, v, positions, kv_positions, mesh,
            causal=causal, window=window, softcap=cfg.attn_logit_softcap,
        )
    else:
        o = flash_attention(
            q, k, v, positions, kv_positions,
            causal=causal, window=window, softcap=cfg.attn_logit_softcap,
        )
    o = constrain(o, ("batch", "seq", "heads_act", None))
    o = o.reshape(B, S, cfg.n_heads * cfg.d_head)
    out = apply_linear(p["wo"], o)
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def init_kv_cache(
    cfg: ModelConfig, batch: int, capacity: int, dtype
) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((batch, capacity, Hkv, Dh), dtype=dtype),
        "v": jnp.zeros((batch, capacity, Hkv, Dh), dtype=dtype),
        "pos": jnp.full((batch, capacity), -1, dtype=jnp.int32),
    }


def kv_cache_spec(
    cfg: ModelConfig, batch: int, capacity: int, dtype
) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": jax.ShapeDtypeStruct((batch, capacity, Hkv, Dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, capacity, Hkv, Dh), dtype),
        "pos": jax.ShapeDtypeStruct((batch, capacity), jnp.int32),
    }


def cache_fill(
    cache: dict,
    k: jax.Array,            # (B, S, Hkv, Dh)
    v: jax.Array,
    positions: jax.Array,    # (B, S)
) -> dict:
    """Bulk-write keys/values. slot = position % capacity (exact for global
    layers, rolling for local windows).  For rolling layers later writes
    overwrite earlier slots, matching the window semantics.

    B=1 single-token writes (long-context decode) use a masked
    where-update instead of a scatter: with no batch dim to partition by,
    a dynamic scatter makes GSPMD replicate the whole seq-sharded cache
    (a 26 GB/chip blowup on the long_500k cell), while the elementwise
    form partitions trivially.  Batched decode keeps the O(1) scatter —
    the masked form would pay a full cache rewrite per step."""
    C = cache["k"].shape[1]
    slots = positions % C  # (B, S)
    if positions.shape[1] == 1 and positions.shape[0] == 1:
        hit = (jnp.arange(C, dtype=jnp.int32)[None, :] == slots)  # (B, C)
        new_k = jnp.where(hit[:, :, None, None],
                          k.astype(cache["k"].dtype), cache["k"])
        new_v = jnp.where(hit[:, :, None, None],
                          v.astype(cache["v"].dtype), cache["v"])
        new_pos = jnp.where(hit, positions.astype(jnp.int32), cache["pos"])
        return {"k": new_k, "v": new_v, "pos": new_pos}
    bidx = jnp.arange(k.shape[0], dtype=jnp.int32)[:, None]
    new_k = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
    new_pos = cache["pos"].at[bidx, slots].set(positions.astype(jnp.int32))
    return {"k": new_k, "v": new_v, "pos": new_pos}


def attn_decode(
    p: dict,
    cfg: ModelConfig,
    x_t: jax.Array,          # (B, 1, d_model)
    cache: dict,
    lengths: jax.Array,      # (B,) current sequence lengths (positions of x_t)
    *,
    rope_on: bool = True,
    window: int | None = None,
    cross: bool = False,
    constrain: Constrain = _noop_constrain,
) -> tuple[jax.Array, dict]:
    """One decode step. For cross-attention the cache is read-only."""
    B = x_t.shape[0]
    q_positions = lengths[:, None].astype(jnp.int32)  # (B,1)

    if cross:
        H, Dh = cfg.n_heads, cfg.d_head
        q = apply_linear(p["wq"], x_t).reshape(B, 1, H, Dh)
        if cfg.qk_norm:
            q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        o = flash_attention(
            q, cache["k"], cache["v"], q_positions, cache["pos"],
            causal=False, window=None, softcap=cfg.attn_logit_softcap,
        )
        o = o.reshape(B, 1, H * Dh)
        return apply_linear(p["wo"], o), cache

    q, k_t, v_t = _project_qkv(
        p, cfg, x_t, x_t,
        rope_on=rope_on,
        q_positions=q_positions, kv_positions=q_positions,
        constrain=constrain,
    )
    cache = cache_fill(cache, k_t, v_t, q_positions)
    o = flash_attention(
        q, cache["k"], cache["v"], q_positions, cache["pos"],
        causal=True, window=window, softcap=cfg.attn_logit_softcap,
    )
    o = constrain(o, ("batch", "seq", "heads_act", None))
    o = o.reshape(B, 1, cfg.n_heads * cfg.d_head)
    return apply_linear(p["wo"], o), cache
