"""Pallas TPU water-fill: the negotiation claim/absorb inner loop.

One negotiation cycle walks cohorts in processing order and, per cohort,
converts the request row into per-worker takes against the shrinking
free-resource matrix — ``fits = floor(min_r free_r/want_r + eps)`` then
the greedy prefix allocation ``take = clip(d - exclusive_cumsum(fits),
0, fits)``.  The jax backend runs this as a chunked `lax.scan`; here the
same chunk walk is a Pallas kernel so the free matrix lives in VMEM for
the whole cycle instead of round-tripping through HBM per scan step.

Tiling: grid = (nch,) with the single chunk axis sequential
("arbitrary") — chunk c+1 must observe chunk c's claims, so the free
matrix is a VMEM scratch that persists across grid steps (initialised at
``program_id == 0`` via pl.when, flushed to the output block every step;
the last step's write is the result).  Per grid step the kernel holds:

  want/safe/big (chunk, R8)   request rows (R padded 6 -> 8 sublanes)
  crow          (chunk, Wp)   uint8 compat mask, Wp a lane multiple
  free          (R8, Wp)      f32/f64 VMEM scratch — THE carry
  left          (1, 1)        remaining claim budget scratch

The drain guard is identical to the jax backend's: a chunk whose
componentwise-minimum request exceeds every worker's free vector in some
resource is provably empty and skips its cohort loop via pl.when (takes
rows are pre-zeroed, so skipping is claim-exact).

dtype passes through: float64 under interpret mode (bit-identical to the
jax/numpy backends — this is what CI pins), float32 when compiled for a
real TPU (Mosaic has no f64 path; exact while quantities are integers
below 2**24).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.matchmaker.base import FIT_EPS

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# accept either so the kernel runs on both sides of the rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

_R_SUBLANES = 8           # resource-axis padding (f32 min tile is (8, 128))


def _waterfill_kernel(
    freeT_ref,    # (R8, Wp)     initial free matrix (read once)
    left_ref,     # (1, 1)       initial claim budget (read once)
    want_ref,     # (1, chunk, R8)
    safe_ref,     # (1, chunk, R8)  want where want>0 else 1
    big_ref,      # (1, chunk, R8)  0 where want>0 else sentinel
    d_ref,        # (1, chunk)      cohort demand
    crow_ref,     # (1, chunk, Wp)  uint8 compat mask
    cmin_ref,     # (1, R8)         chunk componentwise-min live request
    takes_ref,    # (1, chunk, Wp)  int32 out
    ran_ref,      # (1, 1)          int32 out — 1 if the chunk executed
    free_out,     # (R8, Wp)        out — final free matrix
    left_out,     # (1, 1)          out — final budget
    free_s,       # (R8, Wp)       VMEM scratch: free carry across chunks
    left_s,       # (1, 1)         VMEM scratch: budget carry
    *,
    chunk: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        free_s[...] = freeT_ref[...]
        left_s[...] = left_ref[...]

    free0 = free_s[...]
    left0 = left_s[0, 0]

    # drain guard — same arithmetic as the jax backend's chunk_step: a
    # worker below the chunk's min live request in ANY resource fits no
    # cohort of the chunk; all workers failing somewhere skips the loop
    cmin = cmin_ref[0, :]
    ok = free0 >= (cmin * (1.0 - 2 * FIT_EPS))[:, None]
    alive = jnp.any(jnp.all(ok, axis=0)) & (left0 > 0)

    takes_ref[...] = jnp.zeros_like(takes_ref)
    ran_ref[0, 0] = alive.astype(jnp.int32)

    @pl.when(alive)
    def _run():
        def body(c, carry):
            free, left = carry
            want = want_ref[0, c, :]
            safe = safe_ref[0, c, :]
            big = big_ref[0, c, :]
            d = jnp.minimum(d_ref[0, c], left)
            crow = crow_ref[0, c, :].astype(free.dtype)
            ratio = free / safe[:, None] + big[:, None]
            fits = jnp.maximum(
                jnp.floor(jnp.min(ratio, axis=0) + FIT_EPS), 0.0)
            fits = jnp.minimum(fits, d) * crow
            cum = jnp.cumsum(fits)
            take = jnp.clip(d - (cum - fits), 0.0, fits)
            takes_ref[0, c, :] = jnp.round(take).astype(jnp.int32)
            free = free - want[:, None] * take[None, :]
            left = left - jnp.sum(take)
            return free, left

        free, left = lax.fori_loop(0, chunk, body, (free0, left0))
        free_s[...] = free
        left_s[0, 0] = left

    # every step flushes the carry; the last grid step's write is final
    free_out[...] = free_s[...]
    left_out[...] = left_s[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def waterfill_pallas(
    freeT: jax.Array,      # (R8, Wp)
    left: jax.Array,       # (1, 1)
    want: jax.Array,       # (nch, chunk, R8)
    safe: jax.Array,       # (nch, chunk, R8)
    big: jax.Array,        # (nch, chunk, R8)
    demand: jax.Array,     # (nch, chunk)
    crow: jax.Array,       # (nch, chunk, Wp) uint8
    chunk_min: jax.Array,  # (nch, R8)
    *,
    interpret: bool = False,
):
    """Returns (takes (nch, chunk, Wp) int32, ran (nch, 1) int32,
    freeT_after (R8, Wp), left_after (1, 1))."""
    nch, chunk, R8 = want.shape
    Wp = crow.shape[2]
    dt = freeT.dtype

    kernel = functools.partial(_waterfill_kernel, chunk=chunk)
    takes, ran, free_out, left_out = pl.pallas_call(
        kernel,
        grid=(nch,),
        in_specs=[
            pl.BlockSpec((R8, Wp), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, chunk, R8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chunk, R8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chunk, R8), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk, Wp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, R8), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, Wp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((R8, Wp), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nch, chunk, Wp), jnp.int32),
            jax.ShapeDtypeStruct((nch, 1), jnp.int32),
            jax.ShapeDtypeStruct((R8, Wp), dt),
            jax.ShapeDtypeStruct((1, 1), dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((R8, Wp), dt),
            pltpu.VMEM((1, 1), dt),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(freeT, left, want, safe, big, demand, crow, chunk_min)
    return takes, ran, free_out, left_out
