"""Water-fill entry point: shape adaptation + backend dispatch.

`waterfill` takes the matchmaker's chunked device layout — the same
(nch, chunk, R) / (R, Wp) tensors the jax backend's scan consumes — pads
the tiny resource axis to the TPU's 8-sublane tile, and runs the Pallas
kernel.  Off-TPU (CI, CPU dry-runs) the kernel executes in interpret
mode: the identical program graph evaluated by XLA:CPU, which is what
lets the differential suite pin bit-identity against the jax and numpy
backends in float64 without TPU hardware.

Resource-axis padding is semantics-free by the same convention the
matchmaker uses for zero-request lanes: padded `want` rows are 0, `safe`
1, `big` the sentinel (their fit ratio is huge and never the min), the
padded free rows are 0 and never decremented, and padded `chunk_min`
lanes are 0 so the drain guard's `free >= 0` test cannot veto a chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.waterfill.kernel import _R_SUBLANES, waterfill_pallas
from repro.kernels.waterfill.ref import waterfill_reference


def _pad_r(x: np.ndarray, axis: int, value: float) -> np.ndarray:
    pad = (-x.shape[axis]) % _R_SUBLANES
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=value)


def waterfill(
    freeT: np.ndarray,       # (R, Wp)
    left: float,             # claim budget (may be inf)
    want: np.ndarray,        # (nch, chunk, R)
    safe: np.ndarray,        # (nch, chunk, R)
    big: np.ndarray,         # (nch, chunk, R)
    demand: np.ndarray,      # (nch, chunk)
    crow: np.ndarray,        # (nch, chunk, Wp) uint8
    chunk_min: np.ndarray,   # (nch, R)
    *,
    dtype,
    interpret: bool | None = None,
):
    """Returns (takes (nch, chunk, Wp) int32, freeT_after (R, Wp),
    ran (nch,) bool) — the jax backend's `_run` contract."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    R = freeT.shape[0]
    takes, ran, free_out, _left_out = waterfill_pallas(
        jnp.asarray(_pad_r(freeT, 0, 0.0), dtype=dtype),
        jnp.full((1, 1), left, dtype=dtype),
        jnp.asarray(_pad_r(want, 2, 0.0), dtype=dtype),
        jnp.asarray(_pad_r(safe, 2, 1.0), dtype=dtype),
        jnp.asarray(_pad_r(big, 2, 1e15), dtype=dtype),
        jnp.asarray(demand, dtype=dtype),
        jnp.asarray(crow),                           # uint8 mask
        jnp.asarray(_pad_r(chunk_min, 1, 0.0), dtype=dtype),
        interpret=interpret,
    )
    return takes, free_out[:R], (ran[:, 0] != 0)


__all__ = ["waterfill", "waterfill_reference"]
