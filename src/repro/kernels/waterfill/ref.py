"""Dense jnp water-fill reference — the oracle the Pallas kernel and the
chunked jax scan must both reproduce bit-for-bit (in float64).

Semantics (the seed negotiator's greedy first-match walk, closed form):
cohorts are visited in the given row order; per cohort the per-worker
fit count is ``floor(min_r free_r/want_r + FIT_EPS)`` over the cohort's
request vector (zero-request resources never constrain), masked by
compat, capped at the cohort's remaining demand, and allocated greedily
worker-by-worker via the exclusive prefix sum.  An optional claim
budget caps the total takes across the whole cycle.

This module is deliberately UNCHUNKED and unguarded — no drain skip, no
padding tricks — so it stays an independent check on the fast paths
rather than a re-statement of them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.matchmaker.base import FIT_EPS

_ZERO_WANT_BIG = 1e15     # ratio offset for zero-request resource lanes


def waterfill_reference(
    free: jax.Array,       # (W, R) free capacity per worker
    requests: jax.Array,   # (C, R) per-job request vector per cohort
    demand: jax.Array,     # (C,)   idle jobs per cohort
    compat: jax.Array,     # (C, W) 0/1 requirements mask
    budget: jax.Array | float = jnp.inf,
):
    """Returns (takes (C, W) int32, free_after (W, R))."""
    dt = free.dtype
    freeT = free.T                                   # (R, W)
    pos = requests > 0
    safe = jnp.where(pos, requests, jnp.ones((), dt))
    big = jnp.where(pos, jnp.zeros((), dt), _ZERO_WANT_BIG)
    crow = compat.astype(dt)

    def step(carry, x):
        freeT, left = carry
        want, safe_c, big_c, d, cr = x
        d = jnp.minimum(d, left)
        ratio = freeT / safe_c[:, None] + big_c[:, None]
        fits = jnp.maximum(jnp.floor(jnp.min(ratio, axis=0) + FIT_EPS), 0.0)
        fits = jnp.minimum(fits, d) * cr
        cum = jnp.cumsum(fits)
        take = jnp.clip(d - (cum - fits), 0.0, fits)
        freeT = freeT - want[:, None] * take[None, :]
        left = left - jnp.sum(take)
        return (freeT, left), jnp.round(take).astype(jnp.int32)

    left0 = jnp.asarray(budget, dtype=dt)
    (freeT, _left), takes = lax.scan(
        step, (freeT, left0),
        (requests, safe, big, demand.astype(dt), crow))
    return takes, freeT.T
