from repro.kernels.waterfill.ops import (  # noqa: F401
    waterfill, waterfill_reference,
)
