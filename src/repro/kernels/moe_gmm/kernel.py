"""Pallas TPU expert-grouped matmul (MegaBlocks-style ragged GEMM).

Formulation: tokens arrive pre-sorted by expert; group boundaries are
aligned to the row-tile size BT (the MoE dispatch layer pads each expert's
queue to a BT multiple — capacity-style, so alignment is free).  Each row
tile therefore belongs to exactly ONE expert, whose id is delivered via
scalar prefetch (PrefetchScalarGridSpec): the rhs BlockSpec index_map reads
``tile_expert[it]`` and DMAs only that expert's (BK, BN) weight tile —
no (T, K, N) gather ever materializes.

Grid = (nT, nN, nK), K innermost sequential with a VMEM f32 accumulator;
BT = BN = BK = 128-aligned MXU tiles.  Row tiles past the last real group
(tile_expert == E) skip the matmul and write zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# accept either so the kernels run on both sides of the rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _gmm_kernel(
    eid_ref,     # (nT,) int32 scalar-prefetch: expert id per row tile
    lhs_ref,     # (BT, BK)
    rhs_ref,     # (1, BK, BN)
    out_ref,     # (BT, BN)
    acc_ref,     # (BT, BN) f32 scratch
    *,
    nk: int,
    n_experts: int,
):
    it = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = eid_ref[it] < n_experts

    @pl.when(valid)
    def _mm():
        acc_ref[...] += jax.lax.dot_general(
            lhs_ref[...].astype(jnp.float32),
            rhs_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ik == nk - 1)
    def _fin():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def tile_expert_map(group_sizes: jax.Array, n_tiles: int, bt: int) -> jax.Array:
    """Expert id owning each row tile (tiles past the total get E)."""
    E = group_sizes.shape[0]
    offsets = jnp.cumsum(group_sizes)                       # end offsets
    starts = jnp.arange(n_tiles, dtype=jnp.int32) * bt      # tile start rows
    return jnp.sum(
        starts[:, None] >= offsets[None, :], axis=1
    ).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_n", "block_k", "interpret")
)
def gmm_pallas(
    lhs: jax.Array,          # (T, K) expert-sorted rows
    rhs: jax.Array,          # (E, K, N)
    group_sizes: jax.Array,  # (E,) int32, each a multiple of block_t
    *,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    T, K = lhs.shape
    E, _, N = rhs.shape
    BT = min(block_t, max(T, 8))
    BN = min(block_n, max(N, 128))
    BK = min(block_k, max(K, 128))

    padT, padK, padN = (-T) % BT, (-K) % BK, (-N) % BN
    lhs_p = jnp.pad(lhs, ((0, padT), (0, padK)))
    rhs_p = jnp.pad(rhs, ((0, 0), (0, padK), (0, padN)))
    Tp, Kp, Np = T + padT, K + padK, N + padN
    nt, nn, nk = Tp // BT, Np // BN, Kp // BK

    eids = tile_expert_map(group_sizes, nt, BT)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nt, nn, nk),
        in_specs=[
            pl.BlockSpec((BT, BK), lambda it, in_, ik, eid: (it, ik)),
            # clamp in the index_map: invalid tiles (eid == E) DMA expert
            # E-1's tile but skip the matmul and emit zeros in the kernel
            pl.BlockSpec((1, BK, BN),
                         lambda it, in_, ik, eid:
                         (jnp.minimum(eid[it], E - 1), ik, in_)),
        ],
        out_specs=pl.BlockSpec((BT, BN), lambda it, in_, ik, eid: (it, in_)),
        scratch_shapes=[pltpu.VMEM((BT, BN), jnp.float32)],
    )
    kernel = functools.partial(_gmm_kernel, nk=nk, n_experts=E)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, Np), lhs.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(eids, lhs_p, rhs_p)
    return out[:T, :N]
