from repro.kernels.moe_gmm.ops import gmm  # noqa: F401
