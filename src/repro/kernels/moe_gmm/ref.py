"""Oracle for the expert-grouped matmul (ragged GEMM, MegaBlocks-style).

Layout: tokens are pre-sorted by expert into one flat activation matrix.

  lhs:         (T, K)   sorted token activations
  rhs:         (E, K, N) per-expert weights
  group_sizes: (E,)     int32; sum(group_sizes) <= T (tail rows are padding)

out[t] = lhs[t] @ rhs[e(t)] where e(t) is the expert owning row t, i.e. the
unique e with  offsets[e] <= t < offsets[e+1],  offsets = cumsum(group_sizes).
Padding rows (t >= sum(group_sizes)) produce zeros.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_of_row(group_sizes: jax.Array, T: int) -> jax.Array:
    """(T,) int32 expert id per row; rows past the total get E (out of range)."""
    E = group_sizes.shape[0]
    offsets = jnp.cumsum(group_sizes)  # (E,) end offset per expert
    rows = jnp.arange(T, dtype=jnp.int32)
    # expert id = number of offsets <= row index
    return jnp.sum(rows[:, None] >= offsets[None, :], axis=1).astype(jnp.int32)


def gmm_reference(
    lhs: jax.Array, rhs: jax.Array, group_sizes: jax.Array
) -> jax.Array:
    T, K = lhs.shape
    E, _, N = rhs.shape
    eid = expert_of_row(group_sizes, T)  # (T,)
    valid = eid < E
    eid_c = jnp.minimum(eid, E - 1)
    w = rhs[eid_c]  # (T, K, N) gather — oracle only; kernels never do this
    out = jnp.einsum(
        "tk,tkn->tn", lhs.astype(jnp.float32), w.astype(jnp.float32)
    )
    out = jnp.where(valid[:, None], out, 0.0)
    return out.astype(lhs.dtype)
