"""Grouped matmul entry point.

TPU -> Pallas ragged GEMM (kernel.py); otherwise jax.lax.ragged_dot (XLA's
native ragged contraction, exact same semantics as ref.gmm_reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("backend",))
def gmm(
    lhs: jax.Array,
    rhs: jax.Array,
    group_sizes: jax.Array,
    *,
    backend: str = "auto",
) -> jax.Array:
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu"
    )
    if use_pallas:
        from repro.kernels.moe_gmm.kernel import gmm_pallas

        return gmm_pallas(lhs, rhs, group_sizes)
    return jax.lax.ragged_dot(
        lhs, rhs, group_sizes.astype(jnp.int32)
    ).astype(lhs.dtype)


__all__ = ["gmm"]
