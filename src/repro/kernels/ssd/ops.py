"""Chunked SSD (state-space duality) — the Mamba2 training-time algorithm.

Block decomposition over chunks of length Q (Dao & Gu, arXiv:2405.21060 §6):

  within-chunk (quadratic, MXU-friendly):
      L[i,j]   = exp(cumA_i - cumA_j) * dt_j          (j <= i, else 0)
      scores   = (C_i . B_j) * L[i,j]
      Y_intra  = scores @ X
  chunk state contribution:
      S_c      = sum_j exp(cumA_Q - cumA_j) * dt_j * X_j (outer) B_j
  inter-chunk recurrence (linear scan over n_chunks):
      state_c  = exp(cumA_Q) * state_{c-1} + S_c
      Y_inter[i] = exp(cumA_i) * (C_i @ state_{c-1})

Dispatch: TPU -> Pallas kernel (kernel.py); else the jnp path below.
Both share the exact semantics of ref.ssd_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _expand_groups(t: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) by repeating each group over its heads."""
    G = t.shape[2]
    return jnp.repeat(t, n_heads // G, axis=2)


def ssd_chunked_jnp(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)).  Sequences that are not
    a multiple of the chunk are zero-padded at the tail: pad steps have
    dt = 0, so decay = exp(0) = 1 and contribution = 0 — the state passes
    through unchanged and padded outputs are sliced off."""
    Bsz, S_orig, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S_orig)
    if S_orig % Q != 0:
        pad = Q - S_orig % Q
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = padf(x), padf(dt), padf(Bm), padf(Cm)
    S = x.shape[1]
    nc = S // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bh = _expand_groups(Bm.astype(jnp.float32), H).reshape(Bsz, nc, Q, H, N)
    Ch = _expand_groups(Cm.astype(jnp.float32), H).reshape(Bsz, nc, Q, H, N)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    dA = dtf * Af  # (B,nc,Q,H) log-decay per step
    cumA = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk
    totA = cumA[:, :, -1, :]  # (B,nc,H)

    # ---- within-chunk quadratic term -------------------------------------
    # L[b,c,h,i,j] = exp(cumA_i - cumA_j) * dt_j  for j <= i
    ci = cumA[:, :, :, None, :]  # (B,nc,Q,1,H)
    cj = cumA[:, :, None, :, :]  # (B,nc,1,Q,H)
    li = jnp.tril(jnp.ones((Q, Q), dtype=bool))[None, None, :, :, None]
    decay = jnp.where(li, jnp.exp(ci - cj), 0.0)  # (B,nc,Q,Q,H)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * decay
    scores = scores * dtf[:, :, None, :, :]  # multiply dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # ---- chunk state contributions ---------------------------------------
    # S_c = sum_j exp(totA - cumA_j) * dt_j * X_j (outer) B_j   (B,nc,H,P,N)
    w = jnp.exp(totA[:, :, None, :] - cumA) * dtf  # (B,nc,Q,H)
    s_contrib = jnp.einsum("bcjh,bcjhp,bcjhn->bchpn", w, xf, Bh)

    # ---- inter-chunk linear recurrence ------------------------------------
    if initial_state is None:
        state0 = jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
    else:
        state0 = initial_state.astype(jnp.float32)

    def step(state, inputs):
        contrib, tot = inputs  # (B,H,P,N), (B,H)
        prev = state
        state = jnp.exp(tot)[:, :, None, None] * state + contrib
        return state, prev

    final_state, prev_states = jax.lax.scan(
        step,
        state0,
        (s_contrib.swapaxes(0, 1), totA.swapaxes(0, 1)),
    )
    prev_states = prev_states.swapaxes(0, 1)  # (B,nc,H,P,N) state entering chunk

    # ---- inter-chunk output term ------------------------------------------
    y_inter = jnp.einsum(
        "bcihn,bchpn->bcihp", Ch * jnp.exp(cumA)[..., None], prev_states
    )

    y = y_intra + y_inter + Df[None, None, None, :, None] * xf
    y = y.reshape(Bsz, S, H, P)[:, :S_orig].astype(x.dtype)
    return y, final_state


def ssd_decode_step(
    state: jax.Array,  # (B,H,P,N) fp32
    x_t: jax.Array,    # (B,H,P)
    dt_t: jax.Array,   # (B,H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B,G,N)
    C_t: jax.Array,    # (B,G,N)
    D: jax.Array,      # (H,)
) -> tuple[jax.Array, jax.Array]:
    """Single-token state update; O(H*P*N) per token, O(1) in context."""
    Bsz, H, P, N = state.shape
    G = B_t.shape[1]
    Bh = jnp.repeat(B_t.astype(jnp.float32), H // G, axis=1)  # (B,H,N)
    Ch = jnp.repeat(C_t.astype(jnp.float32), H // G, axis=1)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))[:, :, None, None]
    delta = (dtf[:, :, None] * xf)[..., None] * Bh[:, :, None, :]
    new_state = decay * state + delta
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return new_state, y.astype(x_t.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def ssd(
    x, dt, A, Bm, Cm, D,
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
    backend: str = "auto",
):
    """Public chunked-SSD entry point (see module docstring)."""
    use_pallas = backend == "pallas" or (
        backend == "auto" and jax.default_backend() == "tpu"
    )
    if use_pallas:
        from repro.kernels.ssd.kernel import ssd_pallas

        return ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                          initial_state=initial_state)
    return ssd_chunked_jnp(x, dt, A, Bm, Cm, D, chunk=chunk,
                           initial_state=initial_state)


__all__ = ["ssd", "ssd_chunked_jnp", "ssd_decode_step"]
