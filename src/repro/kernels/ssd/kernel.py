"""Pallas TPU kernel for chunked SSD (Mamba2 state-space duality).

Grid = (B, H, nc): batch and head parallel, chunk axis sequential
("arbitrary") carrying the recurrent state in a VMEM scratch (P, N) f32.

Per grid step the kernel computes, entirely in VMEM/f32 (see ops.py for the
math derivation):

  intra-chunk   scores = (C @ B^T) * decay(L) * dt    (Q,Q) MXU matmul
                y_intra = scores @ x                   (Q,Q)x(Q,P)
  state update  S += x^T @ (w * B)                     (P,Q)x(Q,N)
  inter-chunk   y_inter = (C * exp(cumA)) @ S_prev^T   (Q,N)x(N,P)

Q = chunk (default 256), P = head_dim (64), N = d_state (128): all matmul
dims are MXU-aligned multiples of 64/128.  VMEM working set per step is
(Q*P + 2*Q*N + Q*Q + P*N) * 4B ≈ 0.7 MB at Q=256.

The wrapper pads S to a chunk multiple with dt = 0 (decay = 1, zero
contribution — state passes through, outputs sliced off) and repeats
B/C groups to heads (G is small; per-head duplication keeps the grid
simple, and B/C blocks are tiny next to x).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# accept either so the kernels run on both sides of the rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _ssd_kernel(
    x_ref,       # (1, Q, 1, P)
    dt_ref,      # (1, Q, 1)
    A_ref,       # (1,)  SMEM
    B_ref,       # (1, Q, 1, N)
    C_ref,       # (1, Q, 1, N)
    D_ref,       # (1,)  SMEM
    init_ref,    # (1, 1, P, N) initial state
    y_ref,       # (1, Q, 1, P)
    fin_ref,     # (1, 1, P, N) final state (written at last chunk)
    state_ref,   # (P, N) f32 scratch — recurrent state across chunks
    *,
    nc: int,
):
    ic = pl.program_id(2)
    Q, P = x_ref.shape[1], x_ref.shape[3]
    N = B_ref.shape[3]

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    Bm = B_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    Cm = C_ref[0, :, 0, :].astype(jnp.float32)       # (Q, N)
    A = A_ref[0].astype(jnp.float32)                 # scalar
    D = D_ref[0].astype(jnp.float32)

    dA = dt * A                                       # (Q,) log decay
    cumA = jnp.cumsum(dA)                             # inclusive
    tot = cumA[-1]

    # intra-chunk: L[i,j] = exp(cumA_i - cumA_j) * dt_j for j <= i
    ci = cumA[:, None]
    cj = cumA[None, :]
    tril = jnp.tril(jnp.ones((Q, Q), dtype=jnp.bool_))
    decay = jnp.where(tril, jnp.exp(ci - cj), 0.0)    # (Q, Q)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (Q, Q) = C_i . B_j
    scores = scores * decay * dt[None, :]
    y_intra = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (Q, P)

    # inter-chunk: y_inter = (C * exp(cumA)) @ state_prev^T  -> (Q, P)
    state_prev = state_ref[...]                        # (P, N)
    c_scaled = Cm * jnp.exp(cumA)[:, None]
    y_inter = jax.lax.dot_general(
        c_scaled, state_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y = y_intra + y_inter + D * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: S = exp(tot) * S + x^T @ (w * B), w = exp(tot - cumA)*dt
    w = jnp.exp(tot - cumA) * dt                       # (Q,)
    contrib = jax.lax.dot_general(
        x, Bm * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (P, N)
    state_ref[...] = jnp.exp(tot) * state_prev + contrib

    @pl.when(ic == nc - 1)
    def _fin():
        fin_ref[0, 0] = state_ref[...]


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_pallas(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)
    A: jax.Array,      # (H,)
    Bm: jax.Array,     # (B, S, G, N)
    Cm: jax.Array,     # (B, S, G, N)
    D: jax.Array,      # (H,)
    *,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    Bsz, S_orig, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, max(S_orig, 8))

    pad = (-S_orig) % Q
    if pad:
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = padf(x), padf(dt), padf(Bm), padf(Cm)
    S = x.shape[1]
    nc = S // Q

    # expand groups to heads so the grid is uniform over H
    Bh = jnp.repeat(Bm, H // G, axis=2)   # (B, S, H, N)
    Ch = jnp.repeat(Cm, H // G, axis=2)

    if initial_state is None:
        init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, fin = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, Bh, Ch, D, init)
    return y[:, :S_orig], fin
