"""Sequential-scan oracle for the Mamba2 SSD (state-space duality) op.

Shapes (Mamba2 conventions):
  x:  (B, S, H, P)   inputs per head            (P = head_dim)
  dt: (B, S, H)      positive step sizes        (softplus already applied)
  A:  (H,)           negative decay per head    (A = -exp(A_log))
  Bm: (B, S, G, N)   input projections          (N = d_state, G = ngroups)
  Cm: (B, S, G, N)   output projections
  D:  (H,)           skip connection

Recurrence (per head h, group g = h % G ... heads are split evenly over
groups, i.e. g = h // (H // G)):

  state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * x_t  (outer) Bm_t
  y_t     = state_t @ Cm_t + D_h * x_t

state: (P, N). All math in fp32; output cast back to x.dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _group_index(h: int, n_heads: int, ngroups: int) -> int:
    return h // (n_heads // ngroups)


def ssd_reference(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    D: jax.Array,
    *,
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    _, _, G, N = Bm.shape
    heads_per_group = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    # expand groups to heads: (B, S, H, N)
    Bh = jnp.repeat(Bf, heads_per_group, axis=2)
    Ch = jnp.repeat(Cf, heads_per_group, axis=2)

    if initial_state is None:
        state0 = jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
    else:
        state0 = initial_state.astype(jnp.float32)

    def step(state, inputs):
        xt, dtt, bt, ct = inputs  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * Af)[:, :, None, None]  # (B,H,1,1)
        delta = (dtt[:, :, None] * xt)[..., None] * bt[:, :, None, :]
        state = decay * state + delta  # (B,H,P,N)
        yt = jnp.einsum("bhpn,bhn->bhp", state, ct)
        yt = yt + Df[None, :, None] * xt
        return state, yt

    xs = (
        xf.swapaxes(0, 1),      # (S,B,H,P)
        dtf.swapaxes(0, 1),     # (S,B,H)
        Bh.swapaxes(0, 1),      # (S,B,H,N)
        Ch.swapaxes(0, 1),
    )
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = ys.swapaxes(0, 1).astype(x.dtype)  # (B,S,H,P)
    return y, final_state
