from repro.kernels.ssd.ops import ssd, ssd_chunked_jnp, ssd_decode_step  # noqa: F401
