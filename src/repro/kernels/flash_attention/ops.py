"""Jit'd attention entry point.

Dispatch policy:
  * TPU backend            -> Pallas flash kernel (kernel.py)
  * anything else (CPU dry-run, tests) -> memory-bounded chunked jnp path

The chunked path scans over query blocks so the (Sq, Skv) score matrix is
never fully materialized — this is what lets the 32k-prefill dry-run cells
fit the per-device HBM budget even without the Pallas kernel in the lowered
HLO (Pallas TPU kernels cannot lower on the CPU dry-run backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import NEG_INF, attention_reference

# Score-block element budget for the chunked path (chunk × Skv elements,
# before batch/head dims; bounds the transient fp32 score tensor so the
# 32k-prefill dry-run cells stay within per-device HBM).
_CHUNK_BUDGET = 1 << 21

# Analysis-mode switch (launch/dryrun.py): the chunked path hides its FLOPs
# inside a lax.scan body that XLA cost analysis counts only once; forcing
# the dense reference makes the lowered module's cost exact.  Never set in
# production paths.
FORCE_REFERENCE = False


def _pick_q_chunk(sq: int, skv: int) -> int:
    if sq <= 128:
        return sq
    c = max(1, _CHUNK_BUDGET // max(skv, 1))
    c = min(c, 1024, sq)
    # largest power of two <= c that divides sq
    while c > 1 and sq % c != 0:
        c //= 2
    return max(c, 1)


def _chunked_attention(
    q, k, v, q_pos, kv_pos, *, causal, window, softcap, scale
):
    B, Sq, Hq, Dh = q.shape
    chunk = _pick_q_chunk(Sq, k.shape[1])
    if chunk == Sq or FORCE_REFERENCE:
        return attention_reference(
            q, k, v, q_pos, kv_pos,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )
    n = Sq // chunk
    qs = q.reshape(B, n, chunk, Hq, Dh).swapaxes(0, 1)
    qp = q_pos.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        qc, qpc = xs
        out = attention_reference(
            qc, k, v, qpc, kv_pos,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )
        return carry, out

    _, outs = jax.lax.scan(body, None, (qs, qp))
    return outs.swapaxes(0, 1).reshape(B, Sq, Hq, Dh)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "backend"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    backend: str = "auto",
) -> jax.Array:
    """Position-masked GQA attention. See ref.py for semantics."""
    use_pallas = False
    if backend == "pallas":
        use_pallas = True
    elif backend == "auto":
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        from repro.kernels.flash_attention.kernel import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, q_pos, kv_pos,
            causal=causal, window=window, softcap=softcap, scale=scale,
        )
    return _chunked_attention(
        q, k, v, q_pos, kv_pos,
        causal=causal, window=window, softcap=softcap, scale=scale,
    )


__all__ = ["flash_attention", "attention_reference", "NEG_INF"]
