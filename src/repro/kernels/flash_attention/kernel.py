"""Pallas TPU flash attention (GQA, position-masked, online softmax).

Tiling: grid = (B, Hkv, nq, nk) with the kv dimension innermost and
sequential ("arbitrary"); everything else is parallel.  Per grid step the
kernel holds in VMEM:

  q    (BQ, G, Dh)   one query block for all G = Hq//Hkv heads of the group
  k,v  (BK, Dh)      one kv block of the group's single kv head
  acc  (BQ*G, Dh) f32 scratch — online-softmax numerator
  m, l (BQ*G, 1)  f32 scratch — running max / denominator

BQ = BK = 128 keeps every matmul MXU-shaped ((BQ*G,Dh)x(Dh,BK) and
(BQ*G,BK)x(BK,Dh)) and the working set well under VMEM (~(2*BQ*G*Dh +
2*BK*Dh + BQ*G*BK) * 4B ≈ 1.3 MB for G=8, Dh=128).

The mask is position-driven (see ref.py): kv_pos == -1 marks empty cache
slots, `causal` compares absolute positions, `window` bounds their
distance.  Blocks that are fully masked skip both matmuls via pl.when —
with the standard training layout (q_pos = kv_pos = arange) this prunes the
upper-triangular half of the grid's FLOPs at run time.

Wrapper pads Sq/Skv to block multiples (padded kv slots get kv_pos = -1 so
they are masked; padded q rows are sliced off) and pads G to a multiple of
8 sublanes when needed by duplicating heads (sliced off on return).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# accept either so the kernels run on both sides of the rename
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

NEG_INF = -1e30

DEFAULT_BQ = 128
DEFAULT_BK = 128


def _attn_kernel(
    q_pos_ref,    # (1, BQ) int32
    kv_pos_ref,   # (1, BK) int32
    q_ref,        # (1, BQ, 1, G, Dh)
    k_ref,        # (1, BK, 1, Dh)
    v_ref,        # (1, BK, 1, Dh)
    o_ref,        # (1, BQ, 1, G, Dh)
    acc_ref,      # (BQ*G, Dh) f32 scratch
    m_ref,        # (BQ*G, 1) f32 scratch
    l_ref,        # (BQ*G, 1) f32 scratch
    *,
    causal: bool,
    window: int | None,
    softcap: float | None,
    scale: float,
    nk: int,
):
    ik = pl.program_id(3)
    BQ, G, Dh = q_ref.shape[1], q_ref.shape[3], q_ref.shape[4]
    BK = k_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qp = q_pos_ref[0, :]                 # (BQ,)
    kp = kv_pos_ref[0, :]                # (BK,)
    mask = (kp >= 0)[None, :]            # (1, BK)
    mask = jnp.broadcast_to(mask, (BQ, BK))
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window

    @pl.when(jnp.any(mask))
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32).reshape(BQ * G, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (BK, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (BQ*G, BK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mG = jnp.broadcast_to(
            mask[:, None, :], (BQ, G, BK)
        ).reshape(BQ * G, BK)
        s = jnp.where(mG, s, NEG_INF)

        m_prev = m_ref[...]                              # (BQ*G, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        m_new = jnp.maximum(m_new, NEG_INF / 2)          # fully-masked guard
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mG, p, 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l).reshape(BQ, G, Dh)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


def _pad_to(x: jax.Array, axis: int, mult: int, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)

    BQ = min(block_q, max(Sq, 8))
    BK = min(block_k, max(Skv, 8))

    # (B, Sq, Hkv, G, Dh): group-major head layout is contiguous in Hq
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    qg = _pad_to(qg, 1, BQ)
    kp_ = _pad_to(k, 1, BK)
    vp_ = _pad_to(v, 1, BK)
    qpos = _pad_to(q_pos.astype(jnp.int32), 1, BQ)
    kpos = _pad_to(kv_pos.astype(jnp.int32), 1, BK, value=-1)
    Sqp, Skp = qg.shape[1], kp_.shape[1]
    nq, nk = Sqp // BQ, Skp // BK

    kernel = functools.partial(
        _attn_kernel,
        causal=causal, window=window, softcap=softcap, scale=scale, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, BQ), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, BK), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, BQ, 1, G, Dh),
                         lambda b, h, iq, ik: (b, iq, h, 0, 0)),
            pl.BlockSpec((1, BK, 1, Dh), lambda b, h, iq, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, BK, 1, Dh), lambda b, h, iq, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, BQ, 1, G, Dh), lambda b, h, iq, ik: (b, iq, h, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, Sqp, Hkv, G, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ * G, Dh), jnp.float32),
            pltpu.VMEM((BQ * G, 1), jnp.float32),
            pltpu.VMEM((BQ * G, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qpos, kpos, qg, kp_, vp_)
    out = out[:, :Sq].reshape(B, Sq, Hq, Dh)
    return out
