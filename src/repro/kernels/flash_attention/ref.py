"""Pure-jnp dense oracle for flash attention.

Semantics shared by ops.py (chunked jnp) and kernel.py (Pallas TPU):

  q:      (B, Sq, Hq, Dh)
  k, v:   (B, Skv, Hkv, Dh)   with Hq % Hkv == 0 (GQA)
  q_pos:  (B, Sq)  int32 absolute positions of the query tokens
  kv_pos: (B, Skv) int32 absolute positions of cached kv tokens; -1 = empty

Mask rule (all position-driven, which uniformly covers training/causal,
sliding-window, decode-with-rolling-buffer and cross-attention):

  valid(b, i, j) =  kv_pos[b,j] >= 0
                  & (not causal  or kv_pos[b,j] <= q_pos[b,i])
                  & (window is None or q_pos[b,i] - kv_pos[b,j] < window)

Softmax is computed in fp32 over the valid set; fully-masked rows return 0.
Optional logit soft-capping: logits = cap * tanh(logits / cap).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool,
    window: int | None,
) -> jax.Array:
    """Boolean mask (B, Sq, Skv); True = attend."""
    qp = q_pos[:, :, None].astype(jnp.int32)
    kp = kv_pos[:, None, :].astype(jnp.int32)
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if window is not None:
        valid &= (qp - kp) < window
    return valid


def attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)

    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, Dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    mask = attention_mask(q_pos, kv_pos, causal=causal, window=window)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)

    m = jnp.max(logits, axis=-1, keepdims=True)
    # Guard fully-masked rows: their max is NEG_INF; shift to 0 to avoid NaN.
    m = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(logits - m)
    p = jnp.where(mask[:, None, None, :, :], p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)

    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, Hq, Dh).astype(q.dtype)
