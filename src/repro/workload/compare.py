"""Policy-comparison harness: one trace, many provisioning configurations.

The paper's Fig 2/3 compare demand (idle/running jobs) against supply
(provisioned cores) over time for a given provisioning setup; the
interesting engineering question is how that picture CHANGES with the
knobs — routing policy (fill-first vs cheapest-first vs
spot-with-fallback) and NAP headroom (elastic node caps).  `compare()`
replays the SAME trace through each `PolicySpec`'s federation and emits a
JSON document with, per policy:

  * Fig 2/3-style series: idle/running jobs, provisioned cores, live
    nodes, cost rate, idle-cohort count (downsampled timelines)
  * job outcomes: completions, wait-time mean/percentiles, preemptions,
    goodput, core/GPU-hours
  * provisioning totals: pods submitted, cost, per-backend split

plus cross-policy CONSERVATION checks: every policy must complete every
replayed job and deliver the trace's exact core/GPU-hours — policies may
move work in time and across providers, but demand is conserved.  A
violation means a simulator bug, not a policy difference.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Sequence

from repro.core import Simulation, load_ini
from repro.core.metrics import CompletedStats, timeline
from repro.workload.replay import replay_flock, replay_trace
from repro.workload.trace import Trace, split_trace

SERIES_KEYS = ("idle_jobs", "running_jobs", "provisioned_cores",
               "live_nodes", "cost_rate", "idle_cohorts")
SCHEDD_SERIES_KEYS = ("idle_jobs", "running_jobs", "deficit")

# the standard 3-provider federation the CLI and examples compare on:
# donated on-prem base + billed elastic cloud + cheap reclaimable spot
FEDERATION_INI = """\
[provision]
submit_interval_s=60
idle_timeout_s=600
startup_delay_s=30
max_pods_per_group=2000
max_total_pods=4000
routing_policy={routing}

[k8s]
priority_class=opportunistic

[backend:onprem]
kind=static
nodes={onprem_nodes}
capacity_dict=cpu:64,gpu:4,memory:512,disk:1024

[backend:cloud]
kind=autoscale
capacity_dict=cpu:64,gpu:4,memory:512,disk:1024
max_nodes={cloud_max_nodes}
node_hourly_cost=2.5
provision_delay_s=90
scale_down_delay_s=300

[backend:spot]
kind=autoscale
spot=true
capacity_dict=cpu:64,gpu:4,memory:512,disk:1024
max_nodes={spot_max_nodes}
node_hourly_cost=0.8
provision_delay_s=90
scale_down_delay_s=300
"""


@dataclasses.dataclass
class PolicySpec:
    """One provisioning configuration to replay the trace under."""

    name: str
    ini: str
    tick_s: float = 30.0
    negotiate_interval_s: float = 60.0
    metrics_interval_s: float = 300.0
    seed: int = 0

    def build(self, **kw) -> Simulation:
        """Extra keyword arguments (e.g. ``schedds=``, ``fairshare=``)
        pass straight through to the Simulation constructor."""
        cfg = load_ini(self.ini)
        return Simulation.from_config(
            cfg, tick_s=self.tick_s,
            negotiate_interval_s=self.negotiate_interval_s,
            metrics_interval_s=self.metrics_interval_s,
            seed=self.seed, **kw)


def standard_policy(routing: str, *, headroom: int = 24,
                    onprem_nodes: int = 4, name: str | None = None,
                    **kw) -> PolicySpec:
    """A PolicySpec over the standard federation: `routing` picks the
    deficit split, `headroom` caps BOTH elastic providers' node count
    (the NAP headroom knob)."""
    ini = FEDERATION_INI.format(routing=routing,
                                onprem_nodes=onprem_nodes,
                                cloud_max_nodes=headroom,
                                spot_max_nodes=headroom)
    return PolicySpec(name=name or routing, ini=ini, **kw)


def standard_policies(routings: Sequence[str] = ("fill-first",
                                                 "cheapest-first"),
                      headrooms: Sequence[int] = (24,),
                      **kw) -> list[PolicySpec]:
    """The routing × NAP-headroom grid.  With one headroom the policy is
    named after the routing alone; with several, `<routing>/nap<N>`."""
    out = []
    for routing in routings:
        for headroom in headrooms:
            name = (routing if len(headrooms) == 1
                    else f"{routing}/nap{headroom}")
            out.append(standard_policy(routing, headroom=headroom,
                                       name=name, **kw))
    return out


def run_policy(trace: Trace | Iterable, spec: PolicySpec, *,
               speed: float = 1.0, coalesce_s: float = 10.0,
               start_s: float = 0.0, until_s: float | None = None,
               max_t: float = 5e6, max_points: int = 200,
               schedds: int = 1, split_by: str = "group",
               fairshare: bool = False,
               telemetry: bool = True) -> dict[str, Any]:
    """Replay one trace through one policy's federation until drained;
    returns the per-policy summary block.

    With ``telemetry=True`` (default) the simulation runs with the
    cycle profiler on and the block gains a ``phases`` section — the
    negotiation wall time attributed to build/match/apply/reconcile,
    cycle counts by kind, and jit compile count — so a policy's cost
    in *solver* time is visible next to its cost in dollars.

    ``schedds=N`` runs the multi-schedd flocking scenario: the trace is
    split per schedd by its ``split_by`` label (`split_trace`), each
    sub-trace streams into its own queue on the shared event loop, and
    the block gains a per-schedd section (job outcomes + Fig 2/3-style
    idle/running/deficit series per submit host).  The pool-level
    totals are the cross-schedd merge, so the conservation checks hold
    unchanged.  ``fairshare=True`` negotiates with the hierarchical
    fair-share accountant instead of plain flocking order."""
    if schedds < 1:
        raise ValueError(f"schedds must be >= 1, got {schedds}")
    flocking = schedds > 1 or fairshare
    if flocking:
        if not isinstance(trace, Trace):
            trace = Trace.from_records(trace)
        parts = split_trace(trace, by=split_by, n_schedds=schedds)
        sim = spec.build(schedds=list(parts),
                         fairshare=True if fairshare else None,
                         telemetry=telemetry)
        replayers = replay_flock(
            sim, parts, speed=speed, coalesce_s=coalesce_s,
            start_s=start_s, until_s=until_s, compact_completed=True)
    else:
        sim = spec.build(telemetry=telemetry)
        replayers = {"schedd": replay_trace(
            sim, trace, speed=speed, coalesce_s=coalesce_s,
            start_s=start_s, until_s=until_s, compact_completed=True)}
    t0 = time.time()
    sim.run_until_drained(max_t=max_t)
    wall_s = time.time() - t0
    if not sim.drained():
        idle = sum(q.n_idle() for q in sim.queues)
        running = sum(q.n_running() for q in sim.queues)
        raise RuntimeError(
            f"policy {spec.name!r} failed to drain by t={max_t} "
            f"({idle} idle, {running} running)")
    done = CompletedStats()
    for rep in replayers.values():
        assert rep.stats.completed is not None
        done.merge(rep.stats.completed)
    s = sim.summary()
    out = {
        "policy": spec.name,
        "wall_s": round(wall_s, 3),
        "makespan_s": round(sim.now, 3),
        "jobs": done.summary(),
        "replay": {
            "submitted": sum(r.stats.submitted
                             for r in replayers.values()),
            "truncated": sum(r.stats.truncated
                             for r in replayers.values()),
            "batches": sum(r.stats.batches for r in replayers.values()),
            "max_batch": max(r.stats.max_batch
                             for r in replayers.values()),
        },
        "pods_submitted": s["pods_submitted"],
        "cost_total": round(s["cost_total"], 4),
        "gpu_utilization": round(s["gpu_utilization"], 4),
        "backends": s["backends"],
        "series": timeline(sim.recorder, SERIES_KEYS,
                           max_points=max_points),
        # raw totals for the conservation check (pre-rounding)
        "_core_seconds": done.core_seconds,
        "_gpu_seconds": done.gpu_seconds,
    }
    prof = sim.telemetry.profiler
    if prof is not None:
        out["phases"] = prof.phase_totals()
    if flocking:
        out["schedds"] = _per_schedd_block(sim, replayers, max_points)
        users = _per_user_block(sim)
        if users:
            out["users"] = users
        if fairshare and "fairshare" in s:
            out["fairshare"] = s["fairshare"]
    return out


def _per_schedd_block(sim: Simulation, replayers: dict,
                      max_points: int) -> dict[str, Any]:
    """Per-submit-host outcomes + Fig 2/3-style series."""
    out: dict[str, Any] = {}
    for name, rep in replayers.items():
        keys = tuple(f"{k}@schedd:{name}" for k in SCHEDD_SERIES_KEYS)
        series = timeline(sim.recorder, keys, max_points=max_points)
        out[name] = {
            "jobs": rep.stats.completed.summary(),
            "replay": {"submitted": rep.stats.submitted,
                       "truncated": rep.stats.truncated},
            "series": {k: series[f"{k}@schedd:{name}"]
                       for k in SCHEDD_SERIES_KEYS},
        }
    return out


def _per_user_block(sim: Simulation) -> dict[str, Any]:
    """Per-submitter fair-share gauges, summarized: peak starvation age
    and mean running slots over the run (full series stay in the
    recorder for callers that want them)."""
    out: dict[str, Any] = {}
    for user in sim.recorder.users_recorded():
        running = sim.recorder.user_values("running_jobs", user)
        entry = {
            "max_starvation_age_s": round(
                max(sim.recorder.user_values("starvation_age_s", user),
                    default=0.0), 3),
            "mean_running_jobs": round(
                sum(running) / len(running) if running else 0.0, 3),
        }
        eup = sim.recorder.user_values("effective_priority", user)
        if eup:
            entry["last_effective_priority"] = round(eup[-1], 6)
        out[user] = entry
    return out


def _conservation(trace_stats: dict[str, Any],
                  runs: list[dict[str, Any]],
                  truncated: bool) -> dict[str, Any]:
    """Per-policy and cross-policy demand conservation.  When the replay
    window truncates the trace, totals are compared across policies only
    (each policy saw the same window, whatever it was)."""
    jobs = [r["jobs"]["n"] for r in runs]
    cores = [r.pop("_core_seconds") for r in runs]
    gpus = [r.pop("_gpu_seconds") for r in runs]
    rel = 1e-6
    close = (lambda a, b:
             abs(a - b) <= rel * max(1.0, abs(a), abs(b)))
    out: dict[str, Any] = {
        "jobs_completed": jobs,
        "core_hours": [round(c / 3600.0, 4) for c in cores],
        "gpu_hours": [round(g / 3600.0, 4) for g in gpus],
        "policies_agree": (len({*jobs}) <= 1
                           and all(close(c, cores[0]) for c in cores)
                           and all(close(g, gpus[0]) for g in gpus)),
    }
    if not truncated:
        out["trace_jobs"] = trace_stats["n"]
        out["trace_core_hours"] = round(
            trace_stats["core_seconds"] / 3600.0, 4)
        out["matches_trace"] = (
            all(n == trace_stats["n"] for n in jobs)
            and all(close(c, trace_stats["core_seconds"]) for c in cores)
            and all(close(g, trace_stats["gpu_seconds"]) for g in gpus))
    out["ok"] = bool(out["policies_agree"]
                     and out.get("matches_trace", True))
    return out


def compare(trace: Trace, policies: Sequence[PolicySpec], *,
            speed: float = 1.0, coalesce_s: float = 10.0,
            start_s: float = 0.0, until_s: float | None = None,
            max_t: float = 5e6, max_points: int = 200,
            schedds: int = 1, split_by: str = "group",
            fairshare: bool = False,
            telemetry: bool = True) -> dict[str, Any]:
    """Run one trace across every policy; returns the JSON-ready
    comparison document (trace stats, per-policy summaries+series,
    conservation verdict).  ``schedds=N`` replays the trace split per
    schedd (`split_by` label) through each policy's federation — the
    conservation checks then verify the CROSS-SCHEDD totals against the
    trace, demand being conserved however it is partitioned."""
    if not policies:
        raise ValueError("need at least one PolicySpec")
    names = [p.name for p in policies]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate policy names: {names}")
    trace.validate()
    trace_stats = trace.stats()           # one O(n) pass, reused below
    runs = [
        run_policy(trace, spec, speed=speed, coalesce_s=coalesce_s,
                   start_s=start_s, until_s=until_s, max_t=max_t,
                   max_points=max_points, schedds=schedds,
                   split_by=split_by, fairshare=fairshare,
                   telemetry=telemetry)
        for spec in policies
    ]
    truncated = (start_s > 0.0 or until_s is not None)
    conservation = _conservation(trace_stats, runs, truncated)
    return {
        "trace": {**trace.meta, **trace_stats},
        "replay": {"speed": speed, "coalesce_s": coalesce_s,
                   "start_s": start_s, "until_s": until_s,
                   "schedds": schedds, "split_by": split_by,
                   "fairshare": fairshare},
        "policies": {r["policy"]: r for r in runs},
        "conservation": conservation,
    }


def comparison_table(doc: dict[str, Any]) -> str:
    """Human-readable summary of a compare() document.  When the runs
    carried the cycle profiler, two phase-attribution columns follow:
    negotiation wall (build+match+apply) and reconcile wall."""
    phased = any("phases" in r for r in doc["policies"].values())
    head = (f"{'policy':<24s} {'jobs':>7s} {'p95 wait':>9s} "
            f"{'makespan':>9s} {'pods':>6s} {'cost $':>9s}")
    if phased:
        head += f" {'neg ms':>8s} {'recon ms':>9s}"
    rows = [head]
    for name, r in doc["policies"].items():
        row = (f"{name:<24s} {r['jobs']['n']:>7d} "
               f"{r['jobs']['p95_wait_s']:>8.0f}s "
               f"{r['makespan_s']:>8.0f}s {r['pods_submitted']:>6d} "
               f"{r['cost_total']:>9.2f}")
        ph = r.get("phases")
        if phased and ph is not None:
            neg_ms = 1e3 * (ph["build_s"] + ph["match_s"]
                            + ph["apply_s"])
            row += (f" {neg_ms:>8.1f} {1e3 * ph['reconcile_s']:>9.1f}")
        rows.append(row)
    c = doc["conservation"]
    rows.append(f"conservation: ok={c['ok']} "
                f"(jobs={c['jobs_completed']}, "
                f"core-hours={c['core_hours']})")
    return "\n".join(rows)
