"""Workload CLI: generate traces, replay them, compare policies.

    # 10k-job OSG-shaped day -> JSONL (CSV by extension)
    python -m repro.workload generate --preset diurnal --jobs 10000 \
        --seed 7 --out day.jsonl

    # stream it through one policy's federation, print the summary JSON
    python -m repro.workload replay day.jsonl --policy cheapest-first

    # same trace, several policies + NAP headrooms, Fig 2/3-style JSON
    python -m repro.workload compare day.jsonl \
        --policies fill-first,cheapest-first --out cmp.json

    # one-shot: generate in-memory and compare (the acceptance path)
    python -m repro.workload compare --generate diurnal --jobs 10000 \
        --seed 7 --policies fill-first,cheapest-first --budget-s 60

    # multi-schedd flocking: `compare --schedds N` splits ONE trace
    # internally (3 schedds, fair-share negotiation) ...
    python -m repro.workload compare day.jsonl --schedds 3 --fairshare \
        --policies fill-first,cheapest-first --out cmp.json

    # ... while `generate --split-by` writes per-schedd trace FILES
    # (day.schedd00.jsonl ...) for external consumers
    python -m repro.workload generate --jobs 10000 --split-by group \
        --schedds 3 --out day.jsonl

Exit codes: 0 ok; 1 bad usage/trace; 2 budget exceeded or conservation
check failed (CI treats both as regressions).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.workload.compare import (
    compare, comparison_table, run_policy, standard_policies,
    standard_policy,
)
from repro.workload.generators import DAY_S, generate_preset
from repro.workload.replay import replay_trace
from repro.workload.trace import Trace, TraceError, split_trace


def _split_out_path(base: str, name: str) -> str:
    root, dot, ext = base.rpartition(".")
    return f"{root}.{name}.{ext}" if dot else f"{base}.{name}"


def _cmd_generate(args) -> int:
    trace = generate_preset(args.preset, args.jobs, seed=args.seed,
                            duration_s=args.duration_s)
    if args.split_by:
        # per-schedd traces straight from the generator: one file per
        # label (or per schedd bucket with --schedds N)
        if not args.out:
            print("generate: --split-by needs --out (one file per "
                  "schedd)", file=sys.stderr)
            return 1
        parts = split_trace(trace, by=args.split_by,
                            n_schedds=args.schedds)
        for name, part in parts.items():
            path = _split_out_path(args.out, name)
            part.save(path)
            print(f"wrote {len(part)} records to {path}")
        return 0
    if args.out:
        trace.save(args.out)
        print(f"wrote {len(trace)} records to {args.out} "
              f"({json.dumps(trace.stats())})")
    else:
        sys.stdout.write(trace.to_jsonl())
    return 0


def _cmd_replay(args) -> int:
    if len(args.headroom) != 1:
        print("replay: takes exactly one --headroom (compare sweeps "
              "several)", file=sys.stderr)
        return 1
    trace = Trace.load(args.trace)
    spec = standard_policy(args.policy, headroom=args.headroom[0])
    if args.schedds > 1 or args.fairshare:
        # multi-schedd flocking replay: run_policy handles the split,
        # the concurrent per-queue streams, and the per-schedd block
        doc = run_policy(
            trace, spec, speed=args.speed, coalesce_s=args.coalesce_s,
            start_s=args.start_s, until_s=args.until_s,
            max_t=args.max_t, schedds=args.schedds,
            split_by=args.split_by or "group",
            fairshare=args.fairshare)
        doc.pop("_core_seconds", None)
        doc.pop("_gpu_seconds", None)
        doc = {"trace": {**trace.meta, **trace.stats()}, **doc}
        out = json.dumps(doc, indent=1)
        if args.out:
            with open(args.out, "w") as f:
                f.write(out + "\n")
        print(out)
        return 0
    sim = spec.build()
    replayer = replay_trace(
        sim, trace, speed=args.speed, coalesce_s=args.coalesce_s,
        start_s=args.start_s, until_s=args.until_s,
        compact_completed=True)
    t0 = time.time()
    sim.run_until_drained(max_t=args.max_t)
    if not sim.queue.drained():
        print(f"FAIL: not drained by --max-t {args.max_t} "
              f"({sim.queue.n_idle()} idle, {sim.queue.n_running()} "
              f"running)", file=sys.stderr)
        return 2
    doc = {
        "trace": {**trace.meta, **trace.stats()},
        "policy": spec.name,
        "wall_s": round(time.time() - t0, 3),
        "makespan_s": round(sim.now, 3),
        "jobs": replayer.stats.completed.summary(),
        "replay": {"submitted": replayer.stats.submitted,
                   "truncated": replayer.stats.truncated,
                   "batches": replayer.stats.batches},
        "cost_total": round(sim.summary()["cost_total"], 4),
    }
    out = json.dumps(doc, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(out + "\n")
    print(out)
    return 0


def _cmd_compare(args) -> int:
    if args.generate and args.trace:
        print("compare: TRACE file and --generate are mutually exclusive",
              file=sys.stderr)
        return 1
    if args.generate:
        trace = generate_preset(args.generate, args.jobs, seed=args.seed,
                                duration_s=args.duration_s)
    elif args.trace:
        trace = Trace.load(args.trace)
    else:
        print("compare: need a TRACE file or --generate PRESET",
              file=sys.stderr)
        return 1
    routings = [p.strip() for p in args.policies.split(",") if p.strip()]
    policies = standard_policies(routings, headrooms=args.headroom)
    t0 = time.time()
    doc = compare(trace, policies, speed=args.speed,
                  coalesce_s=args.coalesce_s, start_s=args.start_s,
                  until_s=args.until_s, max_t=args.max_t,
                  schedds=args.schedds,
                  split_by=args.split_by or "group",
                  fairshare=args.fairshare)
    wall = time.time() - t0
    doc["wall_s_total"] = round(wall, 3)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote comparison to {args.out}")
    print(comparison_table(doc))
    print(f"total wall {wall:.1f}s")
    if not doc["conservation"]["ok"]:
        print("FAIL: conservation check failed", file=sys.stderr)
        return 2
    if args.budget_s is not None and wall > args.budget_s:
        print(f"FAIL: {wall:.1f}s > budget {args.budget_s}s",
              file=sys.stderr)
        return 2
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.workload",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("generate", help="synthesize a trace")
    g.add_argument("--preset", default="diurnal",
                   choices=("diurnal", "poisson", "uniform-burst"))
    g.add_argument("--jobs", type=int, default=10_000)
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--duration-s", type=float, default=DAY_S)
    g.add_argument("--out", default=None,
                   help=".jsonl or .csv (stdout JSONL when omitted)")
    g.add_argument("--split-by", default=None, choices=("group", "user"),
                   help="write per-schedd traces (one file per label, "
                        "or per bucket with --schedds N)")
    g.add_argument("--schedds", type=int, default=None,
                   help="with --split-by: pack labels onto N schedds")
    g.set_defaults(fn=_cmd_generate)

    def _replay_opts(p):
        p.add_argument("--speed", type=float, default=1.0,
                       help="time-warp: compress arrivals N x")
        p.add_argument("--coalesce-s", type=float, default=10.0,
                       help="batch arrivals within this sim-time span")
        p.add_argument("--start-s", type=float, default=0.0)
        p.add_argument("--until-s", type=float, default=None)
        p.add_argument("--max-t", type=float, default=5e6)
        p.add_argument("--headroom", type=int, default=24, nargs="*",
                       help="elastic backends' max_nodes (NAP headroom)")
        p.add_argument("--schedds", type=int, default=1,
                       help="flocking: split the trace per schedd and "
                            "replay concurrently into one pool")
        p.add_argument("--split-by", default=None,
                       choices=("group", "user"),
                       help="per-schedd split label (default group)")
        p.add_argument("--fairshare", action="store_true",
                       help="hierarchical fair-share negotiation "
                            "(per-schedd quotas, per-user priority)")
        p.add_argument("--out", default=None)

    r = sub.add_parser("replay", help="stream a trace through one policy")
    r.add_argument("trace")
    r.add_argument("--policy", default="cheapest-first")
    _replay_opts(r)
    r.set_defaults(fn=_cmd_replay)

    c = sub.add_parser("compare",
                       help="one trace across several policies")
    c.add_argument("trace", nargs="?", default=None)
    c.add_argument("--generate", default=None, metavar="PRESET",
                   choices=("diurnal", "poisson", "uniform-burst"),
                   help="synthesize instead of reading a file")
    c.add_argument("--jobs", type=int, default=10_000)
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--duration-s", type=float, default=DAY_S)
    c.add_argument("--policies", default="fill-first,cheapest-first")
    c.add_argument("--budget-s", type=float, default=None,
                   help="fail (exit 2) if total wall time exceeds this")
    _replay_opts(c)
    c.set_defaults(fn=_cmd_compare)

    args = ap.parse_args(argv)
    if isinstance(getattr(args, "headroom", None), int):
        args.headroom = [args.headroom]
    elif getattr(args, "headroom", None) in (None, []):
        args.headroom = [24]
    try:
        return args.fn(args)
    except TraceError as e:
        print(f"trace error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":
    raise SystemExit(main())
