"""Workload subsystem: trace schema, synthetic generators, streaming
replay, and the policy-comparison harness.

This package is the single source of DEMAND for simulations, benchmarks,
and examples — the control plane under test lives in `repro.core`; what
flows through it is defined here.  CLI: ``python -m repro.workload
generate|replay|compare`` (see __main__.py).
"""
from repro.workload.trace import (
    FIELDS, Trace, TraceError, TraceRecord, iter_jsonl, open_trace_stream,
    split_records, split_trace,
)
from repro.workload.generators import (
    DAY_S, JobKind, OSG_KINDS, PRESETS, arrival_times, diurnal_day,
    diurnal_profile, generate_preset, lognormal_runtimes, pareto_runtimes,
    poisson_arrivals, synthesize, uniform_burst, zipf_users,
)
from repro.workload.replay import (
    ReplayStats, TraceReplayer, replay_flock, replay_trace,
    submit_trace_upfront,
)
from repro.workload.compare import (
    FEDERATION_INI, PolicySpec, compare, comparison_table, run_policy,
    standard_policies, standard_policy,
)

__all__ = [
    "FIELDS", "Trace", "TraceError", "TraceRecord", "iter_jsonl",
    "open_trace_stream", "split_records", "split_trace", "replay_flock",
    "DAY_S", "JobKind", "OSG_KINDS", "PRESETS", "arrival_times",
    "diurnal_day", "diurnal_profile", "generate_preset",
    "lognormal_runtimes", "pareto_runtimes", "poisson_arrivals",
    "synthesize", "uniform_burst", "zipf_users",
    "ReplayStats", "TraceReplayer", "replay_trace",
    "submit_trace_upfront",
    "FEDERATION_INI", "PolicySpec", "compare", "comparison_table",
    "run_policy", "standard_policies", "standard_policy",
]
