"""Streaming trace replay: arrivals become scheduled events, lazily.

The PR 3 event engine makes 100k-job campaigns cheap to SIMULATE; this
module makes them cheap to FEED.  A `TraceReplayer` walks an
arrival-ordered record stream (a `Trace`, a generator, or a JSONL file
reader) and schedules ONE pending feeder event at a time on the
simulation's event loop: when it fires, every record due by `now` is
converted to a `Job` and submitted, and the feeder re-arms itself at the
next record's warped arrival time.  At no point does the replayer hold
more than one read-ahead record — `Job` objects exist only from their
arrival to their completion, and with `compact_completed=True` not even
completed jobs accumulate (the queue streams them into a
`CompletedStats` aggregator instead of `completed_log`).

Knobs:
  * `speed`       — time-warp: arrivals are compressed N× (runtimes are
                    untouched; warping demand, not service, is what a
                    what-if "same day, twice the submission rate" means)
  * `start_s` / `until_s` — truncation window in TRACE time; replay
                    re-zeroes the window start onto `at` in sim time
  * `coalesce_s`  — batch arrivals within this sim-time span into one
                    event (arrivals land up to coalesce_s LATE, never
                    early).  0 replays every arrival at its exact
                    timestamp; coarser values trade timing fidelity for
                    fewer continuous-state integrations at 100k scale.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator

from repro.core.jobqueue import Job
from repro.core.metrics import CompletedStats
from repro.workload.trace import Trace, TraceError, TraceRecord


@dataclasses.dataclass
class ReplayStats:
    submitted: int = 0
    truncated: int = 0            # records dropped by the window
    batches: int = 0              # feeder firings
    max_batch: int = 0            # largest single-event submission
    first_arrival_s: float = -1.0  # sim-time of the first submission
    last_arrival_s: float = -1.0
    completed: CompletedStats | None = None


class TraceReplayer:
    """Feeds one trace into one simulation.  Single-use: the underlying
    record stream is consumed as the simulation advances."""

    def __init__(
        self,
        sim,
        records: Trace | Iterable[TraceRecord],
        *,
        speed: float = 1.0,
        start_s: float = 0.0,
        until_s: float | None = None,
        coalesce_s: float = 0.0,
        at: float | None = None,
        max_batch: int = 50_000,
        job_factory: Callable[[TraceRecord], Job] | None = None,
        compact_completed: bool = False,
        queue=None,
    ):
        if speed <= 0:
            raise ValueError(f"speed must be positive, got {speed}")
        if coalesce_s < 0:
            raise ValueError(f"coalesce_s must be >= 0, got {coalesce_s}")
        if until_s is not None and until_s <= start_s:
            raise ValueError(
                f"empty window: until_s={until_s} <= start_s={start_s}")
        self.sim = sim
        self.speed = speed
        self.start_s = start_s
        self.until_s = until_s
        self.coalesce_s = coalesce_s
        self.at = sim.now if at is None else at
        self.max_batch = max_batch
        self.job_factory = job_factory or TraceRecord.to_job
        # target schedd: under flocking each replayer feeds ITS queue —
        # several replayers share one event loop, one per submit host
        self.queue = queue if queue is not None else sim.queue
        self.stats = ReplayStats()
        if compact_completed:
            self.stats.completed = CompletedStats()
            self.queue.keep_completed = False
            self.queue.add_complete_hook(self.stats.completed.observe)
        self._records = self._windowed(
            iter(records.records) if isinstance(records, Trace)
            else iter(records))
        self._pushback: TraceRecord | None = None
        self._exhausted = False
        self._arm()

    # -- time mapping --------------------------------------------------------
    def _sim_time(self, rec: TraceRecord) -> float:
        return self.at + (rec.arrival_s - self.start_s) / self.speed

    def _windowed(self, it: Iterator[TraceRecord]
                  ) -> Iterator[TraceRecord]:
        for rec in it:
            if rec.arrival_s < self.start_s:
                self.stats.truncated += 1
                continue
            if self.until_s is not None and rec.arrival_s >= self.until_s:
                # arrival-ordered: everything left is outside the window;
                # drain (without keeping) so `truncated` counts exactly
                self.stats.truncated += 1 + sum(1 for _ in it)
                break
            yield rec

    def _next_record(self) -> TraceRecord | None:
        if self._pushback is not None:
            rec, self._pushback = self._pushback, None
            return rec
        return next(self._records, None)

    # -- the feeder chain ----------------------------------------------------
    def _arm(self):
        """Schedule the next feeder at the (coalesce-quantized) sim time
        of the next record.  Exactly one feeder is pending at any time,
        so `run_until_drained`'s external-event accounting sees the
        replay as live until the stream is exhausted."""
        rec = self._next_record()
        if rec is None:
            self._exhausted = True
            return
        self._pushback = rec
        t = self._sim_time(rec) + self.coalesce_s
        self.sim.at(t, self._feed, name="trace-replay")

    def _feed(self, sim, now: float):
        batch = 0
        while batch < self.max_batch:
            rec = self._next_record()
            if rec is None:
                self._exhausted = True
                break
            if self._sim_time(rec) > now + 1e-9:
                self._pushback = rec
                break
            job = self.job_factory(rec)
            self.queue.submit(job, now)
            if self.stats.first_arrival_s < 0:
                self.stats.first_arrival_s = now
            self.stats.last_arrival_s = now
            self.stats.submitted += 1
            batch += 1
        self.stats.batches += 1
        self.stats.max_batch = max(self.stats.max_batch, batch)
        if self._pushback is not None or not self._exhausted:
            self._arm()

    @property
    def exhausted(self) -> bool:
        return self._exhausted and self._pushback is None


def replay_trace(sim, records, **kw) -> TraceReplayer:
    """Install a streaming replay on `sim`; returns the replayer whose
    `.stats` fill in as the simulation runs.  Drive the simulation with
    `sim.run_until_drained(...)` as usual."""
    return TraceReplayer(sim, records, **kw)


def replay_flock(sim, traces: dict, **kw) -> dict[str, TraceReplayer]:
    """Install one streaming replayer PER SCHEDD on a multi-queue
    simulation: `traces` maps schedd name -> trace (what `split_trace`
    returns, keyed to the sim's `schedds=` names).  Every replayer
    self-arms on the one shared event loop, so the traces stream
    concurrently — each feeding its own queue — and `run_until_drained`
    sees the union as live until every stream is exhausted.  Extra
    keyword arguments (speed, coalesce_s, compact_completed, ...) apply
    to every replayer.  Returns {schedd name: replayer}; empty traces
    still get a (trivially-exhausted) replayer so the result is
    keyed like the input."""
    out: dict[str, TraceReplayer] = {}
    for name, trace in traces.items():
        out[name] = TraceReplayer(sim, trace, queue=sim.queue_named(name),
                                  **kw)
    return out


def submit_trace_upfront(sim, trace: Trace | Iterable[TraceRecord], *,
                         speed: float = 1.0) -> int:
    """Non-streaming oracle: materialize every job and schedule each
    arrival individually (exact times, O(n) memory).  Differential tests
    compare this against the streaming replayer."""
    n = 0
    records = trace.records if isinstance(trace, Trace) else list(trace)
    for rec in records:
        if rec.runtime_s <= 0:
            raise TraceError(f"bad record {rec!r}")
        sim.submit_jobs(rec.arrival_s / speed, [rec.to_job()])
        n += 1
    return n
