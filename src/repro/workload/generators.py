"""Seeded synthetic workload generators: OSG-shaped traces at any scale.

The OSG follow-up paper (arXiv:2308.11733) characterizes the demand the
provisioner must track: Poisson-like arrivals modulated by a diurnal
cycle, heavy-tailed runtimes (log-normal body, Pareto tail), a small set
of requirement shapes (single-core dominates, with multicore / high-mem /
GPU minorities), and correlated bursts where one user dumps thousands of
near-identical jobs at once.  These generators reproduce each ingredient
separately and compose them into campaigns, so we can produce realistic
traces at any scale without shipping data.

Everything is driven by one `numpy` Generator seeded by the caller:
the same seed yields a byte-identical serialized trace (trace.py's
determinism contract), different seeds yield different traces — the
property tests pin both.

Arrival sampling draws exactly `n` arrivals from the normalized rate
profile via inverse-CDF (a Poisson process conditioned on its count), so
`--jobs 10000` means 10000 records, not "about 10000".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.workload.trace import Trace, TraceRecord

DAY_S = 86400.0


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

def diurnal_profile(amplitude: float = 0.6, period_s: float = DAY_S,
                    phase_s: float = 0.75 * DAY_S) -> Callable:
    """Day/night demand modulation: rate(t) ∝ 1 + amplitude·sin(...),
    peaking mid-"working day" for the default phase.  amplitude in
    [0, 1) keeps the rate strictly positive."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")

    def rate(t):
        return 1.0 + amplitude * np.sin(
            2.0 * np.pi * (t - phase_s) / period_s)

    return rate


def arrival_times(rng: np.random.Generator, n: int, duration_s: float,
                  profile: Callable | None = None,
                  grid: int = 2048) -> np.ndarray:
    """Exactly `n` sorted arrival times on [0, duration_s) drawn from the
    density ∝ profile(t) (uniform when None) — a Poisson process
    conditioned on its total count, sampled by inverse-CDF over a
    discretized rate integral."""
    if n <= 0:
        return np.empty(0)
    u = np.sort(rng.random(n))
    if profile is None:
        return u * duration_s
    ts = np.linspace(0.0, duration_s, grid + 1)
    rates = np.maximum(np.asarray([profile(t) for t in ts]), 1e-12)
    cdf = np.concatenate([[0.0], np.cumsum(
        0.5 * (rates[1:] + rates[:-1]) * np.diff(ts))])
    cdf /= cdf[-1]
    return np.interp(u, cdf, ts)


def poisson_arrivals(rng: np.random.Generator, rate_per_s: float,
                     duration_s: float, t0: float = 0.0) -> np.ndarray:
    """Open-ended homogeneous Poisson process: exponential inter-arrivals
    at `rate_per_s` until `duration_s` (count is random)."""
    if rate_per_s <= 0:
        raise ValueError(f"rate must be positive, got {rate_per_s}")
    n_guess = max(16, int(rate_per_s * duration_s * 1.25) + 16)
    out: list[float] = []
    t = t0
    while True:
        gaps = rng.exponential(1.0 / rate_per_s, size=n_guess)
        for g in gaps:
            t += g
            if t >= t0 + duration_s:
                return np.asarray(out)
            out.append(t)


# ---------------------------------------------------------------------------
# Runtime models (heavy-tailed)
# ---------------------------------------------------------------------------

def lognormal_runtimes(rng: np.random.Generator, n: int, median_s: float,
                       sigma: float, min_s: float = 1.0) -> np.ndarray:
    return np.maximum(min_s,
                      median_s * np.exp(sigma * rng.standard_normal(n)))


def pareto_runtimes(rng: np.random.Generator, n: int, min_s: float,
                    alpha: float, cap_s: float | None = None) -> np.ndarray:
    out = min_s * (1.0 + rng.pareto(alpha, size=n))
    return np.minimum(out, cap_s) if cap_s is not None else out


# ---------------------------------------------------------------------------
# Requirement mix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class JobKind:
    """One requirement shape in a mix, with its own runtime model.
    `runtime_dist` is 'lognormal' (median/sigma) or 'pareto'
    (min/alpha, capped); `attrs`/`requirements` ride into the job ad so
    each kind forms its own provisioning group and idle cohorts."""

    name: str
    weight: float = 1.0
    cpus: int = 1
    gpus: int = 0
    memory_gb: float = 2.0
    disk_gb: float = 8.0
    requirements: str = ""
    attrs: tuple[tuple[str, str], ...] = ()
    runtime_dist: str = "lognormal"
    runtime_median_s: float = 1800.0
    runtime_sigma: float = 1.0
    runtime_min_s: float = 30.0
    runtime_alpha: float = 1.6
    runtime_cap_s: float = 6.0 * 3600.0

    def sample_runtimes(self, rng: np.random.Generator,
                        n: int) -> np.ndarray:
        if self.runtime_dist == "lognormal":
            return lognormal_runtimes(rng, n, self.runtime_median_s,
                                      self.runtime_sigma,
                                      min_s=self.runtime_min_s)
        if self.runtime_dist == "pareto":
            return pareto_runtimes(rng, n, self.runtime_min_s,
                                   self.runtime_alpha,
                                   cap_s=self.runtime_cap_s)
        raise ValueError(f"unknown runtime_dist {self.runtime_dist!r}")


# the OSG-shaped default mix: single-core dominates; multicore, high-mem,
# GPU, and a Pareto-tailed scavenger class make up the rest (2308.11733)
OSG_KINDS: tuple[JobKind, ...] = (
    JobKind("cpu-short", weight=0.50, cpus=1, memory_gb=2,
            runtime_median_s=1200.0, runtime_sigma=1.1),
    JobKind("cpu-multicore", weight=0.18, cpus=8, memory_gb=16,
            runtime_median_s=3600.0, runtime_sigma=0.8),
    JobKind("cpu-highmem", weight=0.10, cpus=4, memory_gb=32,
            requirements="memory >= 32",
            runtime_median_s=2700.0, runtime_sigma=0.9),
    JobKind("scavenger", weight=0.12, cpus=1, memory_gb=2,
            runtime_dist="pareto", runtime_min_s=120.0, runtime_alpha=1.5),
    JobKind("gpu", weight=0.10, cpus=4, gpus=1, memory_gb=16,
            attrs=(("arch", "gpu"),),
            requirements="arch == 'gpu'",
            runtime_median_s=5400.0, runtime_sigma=0.7),
)


def sample_kinds(rng: np.random.Generator, kinds: Sequence[JobKind],
                 n: int) -> np.ndarray:
    w = np.asarray([max(k.weight, 0.0) for k in kinds])
    if w.sum() <= 0:
        raise ValueError("kind weights sum to zero")
    return rng.choice(len(kinds), size=n, p=w / w.sum())


def zipf_users(rng: np.random.Generator, n: int, n_users: int,
               s: float = 1.1) -> np.ndarray:
    """User indices with a Zipf-ish popularity profile — a few heavy
    submitters dominate, matching OSG accounting data."""
    ranks = np.arange(1, n_users + 1, dtype=np.float64)
    p = ranks ** (-s)
    return rng.choice(n_users, size=n, p=p / p.sum())


# ---------------------------------------------------------------------------
# Campaign composition
# ---------------------------------------------------------------------------

def synthesize(
    n_jobs: int,
    duration_s: float = DAY_S,
    *,
    seed: int = 0,
    kinds: Sequence[JobKind] = OSG_KINDS,
    profile: Callable | None = None,
    n_users: int = 24,
    burst_frac: float = 0.25,
    n_bursts: int = 8,
    burst_width_s: float = 600.0,
    name: str = "synthetic",
) -> Trace:
    """Compose a campaign: profile-modulated base arrivals with a sampled
    kind/user mix, plus `burst_frac` of jobs delivered as correlated
    user bursts (one user, one kind, one tight arrival cluster each —
    the pattern that stresses cohort-granular provisioning).  Fully
    determined by `seed`."""
    if n_jobs <= 0:
        raise ValueError(f"n_jobs must be positive, got {n_jobs}")
    rng = np.random.default_rng(seed)
    n_burst_total = int(n_jobs * burst_frac) if n_bursts > 0 else 0
    n_base = n_jobs - n_burst_total

    rows: list[tuple[float, int, str]] = []   # (arrival, kind idx, user)

    base_t = arrival_times(rng, n_base, duration_s, profile)
    base_kind = sample_kinds(rng, kinds, n_base)
    base_user = zipf_users(rng, n_base, n_users)
    rows.extend(
        (float(t), int(k), f"user{u:02d}")
        for t, k, u in zip(base_t, base_kind, base_user))

    if n_burst_total > 0:
        sizes = rng.multinomial(
            n_burst_total, np.full(n_bursts, 1.0 / n_bursts))
        centers = arrival_times(rng, n_bursts, duration_s, profile)
        for b, (size, center) in enumerate(zip(sizes, centers)):
            if size <= 0:
                continue
            kind = int(sample_kinds(rng, kinds, 1)[0])
            user = f"user{int(rng.integers(0, n_users)):02d}"
            ts = np.clip(
                center + burst_width_s * rng.standard_normal(size),
                0.0, max(duration_s - 1e-3, 0.0))
            rows.extend((float(t), kind, user) for t in ts)

    rows.sort(key=lambda r: r[0])
    order_kinds = np.asarray([r[1] for r in rows])

    # per-kind runtime sampling in one vectorized draw each, scattered
    # back in arrival order (keeps the stream deterministic AND cheap)
    runtimes = np.empty(len(rows))
    for ki, kind in enumerate(kinds):
        idx = np.nonzero(order_kinds == ki)[0]
        if len(idx):
            runtimes[idx] = kind.sample_runtimes(rng, len(idx))

    records = []
    for (t, ki, user), rt in zip(rows, runtimes):
        kind = kinds[ki]
        records.append(TraceRecord(
            arrival_s=round(t, 3),
            runtime_s=round(float(rt), 3),
            cpus=kind.cpus,
            gpus=kind.gpus,
            memory_gb=kind.memory_gb,
            disk_gb=kind.disk_gb,
            requirements=kind.requirements,
            group=kind.name,
            user=user,
            attrs=dict(kind.attrs),
        ))

    meta = {
        "name": name,
        "seed": seed,
        "n_jobs": n_jobs,
        "duration_s": duration_s,
        "kinds": [k.name for k in kinds],
        "n_users": n_users,
        "burst_frac": burst_frac,
        "n_bursts": n_bursts,
    }
    return Trace.from_records(records, meta=meta)


def diurnal_day(n_jobs: int, *, seed: int = 0,
                duration_s: float = DAY_S, amplitude: float = 0.6,
                **kw) -> Trace:
    """An OSG-shaped day: diurnal arrivals, OSG kind mix, user bursts."""
    return synthesize(n_jobs, duration_s, seed=seed,
                      profile=diurnal_profile(amplitude=amplitude),
                      name="diurnal", **kw)


def uniform_burst(n_jobs: int, *, seed: int = 0, runtime_s: float = 600.0,
                  at_s: float = 0.0, cpus: int = 1,
                  gpus: int = 0) -> Trace:
    """The repo's old hand-rolled scenario as a trace: every job
    identical, all at once — the single-cohort baseline."""
    del seed  # deterministic by construction; kept for a uniform API
    kind_name = f"burst-{cpus}c{gpus}g"
    records = [TraceRecord(arrival_s=at_s, runtime_s=runtime_s, cpus=cpus,
                           gpus=gpus, memory_gb=4.0, group=kind_name)
               for _ in range(n_jobs)]
    return Trace.from_records(
        records, meta={"name": "uniform_burst", "n_jobs": n_jobs,
                       "runtime_s": runtime_s})


PRESETS: dict[str, Callable[..., Trace]] = {
    "diurnal": diurnal_day,
    "poisson": lambda n_jobs, **kw: synthesize(
        n_jobs, profile=None, name="poisson", **kw),
    "uniform-burst": lambda n_jobs, **kw: uniform_burst(
        n_jobs, **{k: v for k, v in kw.items() if k in ("seed",)}),
}


def generate_preset(preset: str, n_jobs: int, *, seed: int = 0,
                    duration_s: float = DAY_S) -> Trace:
    try:
        builder = PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown preset {preset!r}; "
                         f"known: {sorted(PRESETS)}") from None
    # each preset lambda keeps only the kwargs it understands
    # (uniform-burst has no duration: every arrival is at t=0)
    return builder(n_jobs, seed=seed, duration_s=duration_s)
