"""Workload trace schema: the single source of demand for the simulator.

The paper evaluates demand-driven provisioning against real Open Science
Grid demand (Fig. 2/3); its follow-up (arXiv:2308.11733) characterizes
that demand as bursty, heterogeneous, and heavy-tailed.  A `Trace` is the
repo's portable representation of such demand: an arrival-ordered list of
`TraceRecord`s — arrival time, runtime, resource request, a ClassAd
Requirements expression, and group/user labels — with JSONL and CSV
round-trip, validation, and a lossless mapping onto `core.jobqueue.Job`.

Determinism contract: serialization uses a fixed field order and Python's
shortest-round-trip float repr, so the same `Trace` always produces
byte-identical JSONL/CSV, and parse → re-serialize is the identity.  The
synthetic generators (generators.py) rely on this for their
same-seed-same-bytes guarantee.

Cohort formation: two records with the same request, labels, and
Requirements string map to jobs in the same idle COHORT of the indexed
JobQueue — the negotiator and provisioner evaluate matchmaking once per
cohort, so a trace's requirement MIX (not its length) sets the
control-plane cost.  `Trace.cohort_mix()` previews that structure without
building any `Job`.
"""
from __future__ import annotations

import csv
import dataclasses
import io
import json
import os
from typing import Any, Iterable, Iterator

from repro.core.classad import ClassAdExpr
from repro.core.jobqueue import Job, canonical_ad


class TraceError(ValueError):
    """A record or file violates the trace schema."""


# serialization order is part of the byte-identity contract
FIELDS = ("arrival_s", "runtime_s", "cpus", "gpus", "memory_gb", "disk_gb",
          "requirements", "group", "user", "attrs")

_META_KEY = "__trace_meta__"

# Requirements strings compile to ClassAdExpr once per distinct source —
# traces have few distinct expressions, never one per record
_REQ_CACHE_MAX = 4096
_req_cache: dict[str, ClassAdExpr | None] = {}


def _compiled_requirements(src: str) -> ClassAdExpr | None:
    src = (src or "").strip()
    if not src:
        return None
    expr = _req_cache.get(src)
    if expr is None:
        if len(_req_cache) >= _REQ_CACHE_MAX:
            _req_cache.clear()
        expr = _req_cache[src] = ClassAdExpr(src)
    return expr


@dataclasses.dataclass
class TraceRecord:
    """One job arrival.  `attrs` carries extra advertised attributes
    (e.g. ``arch``) that ride into the job ad verbatim."""

    arrival_s: float
    runtime_s: float
    cpus: int = 1
    gpus: int = 0
    memory_gb: float = 4.0
    disk_gb: float = 8.0
    requirements: str = ""
    group: str = "default"
    user: str = "user00"
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    def validate(self):
        if not (self.arrival_s >= 0.0 and self.arrival_s == self.arrival_s):
            raise TraceError(f"arrival_s must be finite >= 0, "
                             f"got {self.arrival_s!r}")
        if not self.runtime_s > 0.0:
            raise TraceError(f"runtime_s must be > 0, got {self.runtime_s!r}")
        if self.cpus < 1:
            raise TraceError(f"cpus must be >= 1, got {self.cpus!r}")
        if self.gpus < 0 or self.memory_gb <= 0 or self.disk_gb < 0:
            raise TraceError(
                f"bad resource request (gpus={self.gpus!r}, "
                f"memory_gb={self.memory_gb!r}, disk_gb={self.disk_gb!r})")
        try:
            _compiled_requirements(self.requirements)
        except ValueError as e:
            raise TraceError(f"bad Requirements {self.requirements!r}: {e}")

    # -- job mapping ---------------------------------------------------------
    def job_ad(self) -> dict[str, Any]:
        ad: dict[str, Any] = {
            "request_cpus": self.cpus,
            "request_gpus": self.gpus,
            "request_memory": self.memory_gb,
            "request_disk": self.disk_gb,
            "accounting_group": self.group,
            "user": self.user,
        }
        ad.update(self.attrs)
        return ad

    def to_job(self) -> Job:
        return Job(ad=self.job_ad(), runtime_s=self.runtime_s,
                   requirements=_compiled_requirements(self.requirements))

    def cohort_key(self) -> tuple:
        """The idle-cohort key `to_job()` lands in, without building the
        Job or compiling the expression (mirrors cohort_key_of)."""
        return ((self.requirements or "").strip(),
                canonical_ad(self.job_ad()))

    # -- serialization -------------------------------------------------------
    def to_obj(self) -> dict[str, Any]:
        return {
            "arrival_s": float(self.arrival_s),
            "runtime_s": float(self.runtime_s),
            "cpus": int(self.cpus),
            "gpus": int(self.gpus),
            "memory_gb": float(self.memory_gb),
            "disk_gb": float(self.disk_gb),
            "requirements": self.requirements,
            "group": self.group,
            "user": self.user,
            "attrs": dict(sorted(self.attrs.items())),
        }

    @classmethod
    def from_obj(cls, obj: dict[str, Any]) -> "TraceRecord":
        try:
            return cls(
                arrival_s=float(obj["arrival_s"]),
                runtime_s=float(obj["runtime_s"]),
                cpus=int(obj.get("cpus", 1)),
                gpus=int(obj.get("gpus", 0)),
                memory_gb=float(obj.get("memory_gb", 4.0)),
                disk_gb=float(obj.get("disk_gb", 8.0)),
                requirements=str(obj.get("requirements", "")),
                group=str(obj.get("group", "default")),
                user=str(obj.get("user", "user00")),
                attrs=dict(obj.get("attrs", {}) or {}),
            )
        except (KeyError, TypeError, ValueError) as e:
            raise TraceError(f"bad trace record {obj!r}: {e}") from None


@dataclasses.dataclass
class Trace:
    """An arrival-ordered workload trace plus generator metadata."""

    records: list[TraceRecord] = dataclasses.field(default_factory=list)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self) -> Iterator[TraceRecord]:
        return iter(self.records)

    def validate(self) -> "Trace":
        prev = -1.0
        for i, rec in enumerate(self.records):
            rec.validate()
            if rec.arrival_s < prev:
                raise TraceError(
                    f"record {i} arrives at {rec.arrival_s} after "
                    f"{prev} — traces must be arrival-ordered")
            prev = rec.arrival_s
        return self

    # -- demand totals (conservation checks) ---------------------------------
    def duration_s(self) -> float:
        return self.records[-1].arrival_s if self.records else 0.0

    def total_core_seconds(self) -> float:
        return sum(r.cpus * r.runtime_s for r in self.records)

    def total_gpu_seconds(self) -> float:
        return sum(r.gpus * r.runtime_s for r in self.records)

    def cohort_mix(self) -> dict[tuple, int]:
        """{idle-cohort key: arrivals} — the matchmaking-equivalence
        structure this trace will impose on the JobQueue."""
        mix: dict[tuple, int] = {}
        for r in self.records:
            key = r.cohort_key()
            mix[key] = mix.get(key, 0) + 1
        return mix

    def stats(self) -> dict[str, Any]:
        # "last_arrival_s", not "duration_s": the latter is the
        # generator's CONFIGURED window and lives in meta — the two must
        # not collide when summaries merge meta with stats
        return {
            "n": len(self.records),
            "last_arrival_s": self.duration_s(),
            "core_seconds": self.total_core_seconds(),
            "gpu_seconds": self.total_gpu_seconds(),
            "cohorts": len(self.cohort_mix()),
            "users": len({r.user for r in self.records}),
            "groups": len({r.group for r in self.records}),
        }

    # -- JSONL ---------------------------------------------------------------
    def to_jsonl(self) -> str:
        lines = []
        if self.meta:
            lines.append(json.dumps({_META_KEY: self.meta},
                                    sort_keys=True))
        for rec in self.records:
            lines.append(json.dumps(rec.to_obj()))
        return "\n".join(lines) + "\n"

    @classmethod
    def from_jsonl(cls, text: str) -> "Trace":
        # iter_jsonl validates each record and the ordering as it goes,
        # so skip the redundant whole-trace re-validation pass
        return cls(records=list(iter_jsonl(io.StringIO(text))),
                   meta=_peek_meta(text))

    # -- CSV (meta is not carried — JSONL is the canonical format) -----------
    def to_csv(self) -> str:
        buf = io.StringIO()
        w = csv.writer(buf, lineterminator="\n")
        w.writerow(FIELDS)
        for rec in self.records:
            obj = rec.to_obj()
            w.writerow([
                repr(obj["arrival_s"]), repr(obj["runtime_s"]),
                obj["cpus"], obj["gpus"],
                repr(obj["memory_gb"]), repr(obj["disk_gb"]),
                obj["requirements"], obj["group"], obj["user"],
                json.dumps(obj["attrs"], sort_keys=True),
            ])
        return buf.getvalue()

    @classmethod
    def from_csv(cls, text: str) -> "Trace":
        rd = csv.reader(io.StringIO(text))
        header = next(rd, None)
        if header is None or tuple(header) != FIELDS:
            raise TraceError(f"bad CSV header {header!r}; expected {FIELDS}")
        records = []
        for row in rd:
            if not row:
                continue
            if len(row) != len(FIELDS):
                raise TraceError(f"bad CSV row {row!r}")
            obj = dict(zip(FIELDS, row))
            try:
                obj["attrs"] = json.loads(obj["attrs"] or "{}")
            except json.JSONDecodeError as e:
                raise TraceError(f"bad attrs column {row!r}: {e}") from None
            records.append(TraceRecord.from_obj(obj))
        return cls(records=records).validate()

    # -- files ---------------------------------------------------------------
    @classmethod
    def from_records(cls, records: Iterable[TraceRecord],
                     meta: dict[str, Any] | None = None) -> "Trace":
        return cls(records=list(records), meta=dict(meta or {})).validate()

    def save(self, path: str) -> str:
        """Write JSONL (default) or CSV, chosen by extension."""
        text = self.to_csv() if path.endswith(".csv") else self.to_jsonl()
        with open(path, "w") as f:
            f.write(text)
        return path

    @classmethod
    def load(cls, path: str) -> "Trace":
        if not os.path.exists(path):
            raise TraceError(f"no such trace file: {path}")
        with open(path) as f:
            text = f.read()
        if path.endswith(".csv"):
            return cls.from_csv(text)
        return cls.from_jsonl(text)


def split_records(records: Iterable[TraceRecord], by: str = "group"
                  ) -> dict[str, list[TraceRecord]]:
    """Partition records by their `group` or `user` label, preserving
    arrival order inside each partition."""
    if by not in ("group", "user"):
        raise TraceError(f"split key must be 'group' or 'user', got {by!r}")
    out: dict[str, list[TraceRecord]] = {}
    for rec in records:
        out.setdefault(getattr(rec, by), []).append(rec)
    return out


def split_trace(trace: Trace, *, by: str = "group",
                n_schedds: int | None = None) -> dict[str, Trace]:
    """Split one trace into per-schedd traces — the multi-schedd
    flocking scenario's demand: each community (group label, or user
    with ``by="user"``) submits through its own schedd into the shared
    pool.

    With ``n_schedds=None`` every label becomes its own schedd (named
    after the label).  With ``n_schedds=N`` labels are packed onto N
    schedds named ``schedd00..`` by deterministic greedy balancing:
    labels in descending record count onto the least-loaded schedd, so
    the same trace always splits the same way and no schedd is left
    empty while labels remain.  Arrival order is preserved per schedd
    (a subsequence of an ordered trace is ordered), and the partition
    is exact — cross-schedd totals equal the parent trace's, which the
    compare harness' conservation checks verify."""
    parts = split_records(trace.records, by=by)
    if not parts:
        raise TraceError("cannot split an empty trace")

    def sub(name: str, recs: list[TraceRecord]) -> Trace:
        meta = {**trace.meta, "schedd": name, "split_by": by}
        return Trace(records=recs, meta=meta)

    if n_schedds is None:
        return {label: sub(label, recs)
                for label, recs in sorted(parts.items())}
    if n_schedds < 1:
        raise TraceError(f"n_schedds must be >= 1, got {n_schedds}")
    names = [f"schedd{i:02d}" for i in range(n_schedds)]
    schedd_of: dict[str, str] = {}
    load = {n: 0 for n in names}
    for label, recs in sorted(parts.items(),
                              key=lambda kv: (-len(kv[1]), kv[0])):
        tgt = min(names, key=lambda n: (load[n], n))
        schedd_of[label] = tgt
        load[tgt] += len(recs)
    merged: dict[str, list[TraceRecord]] = {n: [] for n in names}
    for rec in trace.records:       # one pass keeps arrival order
        merged[schedd_of[getattr(rec, by)]].append(rec)
    return {name: sub(name, merged[name]) for name in names}


def _peek_meta(text: str) -> dict[str, Any]:
    for line in io.StringIO(text):
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        return dict(obj.get(_META_KEY, {})) if _META_KEY in obj else {}
    return {}


def iter_jsonl(lines: Iterable[str]) -> Iterator[TraceRecord]:
    """Stream records from JSONL lines without materializing a Trace —
    the replayer's input for file-backed campaigns (constant memory).
    Validates each record and the arrival ordering as it goes."""
    prev = -1.0
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            obj = json.loads(line)
        except json.JSONDecodeError as e:
            raise TraceError(f"line {i + 1}: invalid JSON: {e}") from None
        if _META_KEY in obj:
            continue
        rec = TraceRecord.from_obj(obj)
        rec.validate()
        if rec.arrival_s < prev:
            raise TraceError(
                f"line {i + 1}: arrival {rec.arrival_s} < previous {prev} "
                f"— traces must be arrival-ordered")
        prev = rec.arrival_s
        yield rec


def open_trace_stream(path: str) -> Iterator[TraceRecord]:
    """Lazily stream a JSONL trace file (CSV loads eagerly — it has a
    header to check and no meta line to skip)."""
    if path.endswith(".csv"):
        with open(path) as f:
            return iter(Trace.from_csv(f.read()).records)

    def gen() -> Iterator[TraceRecord]:
        with open(path) as f:
            yield from iter_jsonl(f)

    return gen()
