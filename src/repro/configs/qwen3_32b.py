"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=25_600,
    vocab_size=151_936,
    rope=True,
    rope_theta=1_000_000.0,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=40_960,
)
