"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Scout: MoE on every layer (16 experts + 1 shared), same iRoPE/chunked
attention backbone as Maverick.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202_048,
    rope=True,
    rope_theta=500_000.0,
    attn_window=8_192,
    global_attn_every=4,
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8_192,
        every=1,                # MoE every layer (Scout)
        n_shared_experts=1,
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=524_288,
)
