"""Architecture registry: ``--arch <id>`` resolution, reduced smoke
configs, and ShapeDtypeStruct input specs for the dry-run.

FULL configs are only ever touched abstractly (ShapeDtypeStruct — no
allocation); smoke tests run ``reduced_config`` versions of the same
family on CPU.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeCell, applicable
from repro.models.config import (
    EncoderConfig, FrontendConfig, ModelConfig, MoEConfig, SSMConfig,
)

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "whisper-medium": "whisper_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "qwen2-1.5b": "qwen2_1_5b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-8b": "granite_8b",
    "qwen3-32b": "qwen3_32b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-1.3b": "mamba2_1_3b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(name: str, *, n_layers: int | None = None) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: same layer pattern /
    attention flavor / MoE+SSM structure, small widths."""
    cfg = get_config(name)
    period = cfg.period
    layers = n_layers or max(period, 2)
    if layers % period:
        layers = period * max(1, layers // period)
    d_model = 64
    changes: dict = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=16,
        d_ff=0 if cfg.family == "ssm" else 128,
        vocab_size=512,
        max_seq_len=512,
        attn_window=16 if cfg.attn_window is not None else None,
        param_dtype="float32",
        activation_dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4,
            top_k=cfg.moe.top_k,
            d_ff_expert=128,
            every=cfg.moe.every,
            n_shared_experts=cfg.moe.n_shared_experts,
            capacity_factor=2.0,
        )
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32,
            ngroups=cfg.ssm.ngroups,
        )
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(n_layers=2, n_frames=24)
    if cfg.frontend is not None:
        changes["frontend"] = FrontendConfig(n_prefix=8, d_input=32)
    return dataclasses.replace(cfg, **changes)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct, never allocates) for every (arch × shape)
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """VLM cells reserve the patch prefix inside the assigned seq_len."""
    if cfg.frontend is not None:
        return max(seq_len - cfg.frontend.n_prefix, 1)
    return seq_len


def train_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    St = _text_len(cfg, S)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, St), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, St), jnp.int32),
    }
    if cfg.encoder is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32
        )
    if cfg.frontend is not None:
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend.n_prefix, cfg.frontend.d_input), jnp.float32
        )
    return specs


def prefill_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    specs = train_input_specs(cfg, cell)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """serve_step inputs: one new token against a seq_len cache."""
    from repro.models import model as model_lib

    B, S = cell.global_batch, cell.seq_len
    return {
        "tokens_t": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "cache": model_lib.init_cache(cfg, B, S, abstract=True),
        "lengths": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    if cell.kind == "train":
        return train_input_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_input_specs(cfg, cell)
    if cell.kind == "decode":
        return decode_input_specs(cfg, cell)
    raise ValueError(cell.kind)


def all_cells():
    """Yield (arch, cell, runs, skip_reason) for all 40 assigned cells."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for cell in SHAPES.values():
            runs, reason = applicable(cfg, cell)
            yield arch, cell, runs, reason


__all__ = [
    "ARCH_NAMES", "SHAPES", "ShapeCell", "get_config", "reduced_config",
    "input_specs", "train_input_specs", "prefill_input_specs",
    "decode_input_specs", "all_cells", "applicable",
]
