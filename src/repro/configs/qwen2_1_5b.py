"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias, tied embeddings. [arXiv:2407.10671; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab_size=151_936,
    rope=True,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=32_768,
)
