"""mamba2-1.3b [ssm] — 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

Pure Mamba-2 stack: each block is in_proj -> causal conv -> SSD scan ->
gated RMS norm -> out_proj, no separate FFN.  n_heads/d_head below are the
(unused) attention fields; the SSM geometry is d_inner = 2*2048 = 4096,
64 heads of head_dim 64, d_state 128.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv_heads=1,
    d_head=64,
    d_ff=0,
    vocab_size=50_280,
    rope=False,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256,
                  ngroups=1),
    norm="rmsnorm",
    act="silu",
    gated_mlp=False,
    tie_embeddings=True,
    max_seq_len=524_288,
)
