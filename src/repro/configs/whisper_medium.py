"""whisper-medium [audio] — enc-dec, 24L decoder (+24L encoder)
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. [arXiv:2212.04356]

Conv frontend is a STUB per the assignment: input_specs provides 1500
precomputed frame embeddings (batch, 1500, d_model).  Whisper flavor:
LayerNorm, GELU non-gated MLP, absolute sinusoidal positions (no RoPE),
QKV bias, tied embeddings, decoder cross-attends to the encoder output.
"""
from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51_865,
    rope=False,
    qkv_bias=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
    max_seq_len=32_768,
)
