"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Llama4 specifics modeled: interleaved chunked attention (8k window) with
every 4th layer global + NoPE (iRoPE), MoE on alternating layers with one
shared expert, top-1 routing.  bf16 optimizer moments (the 400B total
params must fit 256 × 16 GB with state; see DESIGN.md §9).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202_048,
    rope=True,
    rope_theta=500_000.0,
    attn_window=8_192,          # chunked attention
    global_attn_every=4,        # every 4th layer global (NoPE there: iRoPE)
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        d_ff_expert=8_192,
        every=2,                # MoE on alternating layers (Maverick)
        n_shared_experts=1,
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=524_288,
    optimizer_state_dtype="bfloat16",
)
