"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave. [arXiv:2403.19887]

Period-8 block: layers 0..6 are Mamba mixers, layer 7 is attention; MoE
replaces the dense FFN on every other layer (every=2).  Hardware
adaptation (DESIGN.md): Jamba's Mamba-1 layers are implemented with the
Mamba-2 SSD formulation (chunked-MXU-friendly); state geometry follows the
SSD paper rather than Jamba's d_state=16.
"""
from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab_size=65_536,
    rope=False,                 # jamba uses no positional encoding
    attn_every=8,               # 1:7 attn:mamba interleave
    moe=MoEConfig(
        n_experts=16,
        top_k=2,
        d_ff_expert=14_336,
        every=2,
        capacity_factor=1.25,
    ),
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=524_288,
)
