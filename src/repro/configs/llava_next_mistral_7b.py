"""llava-next-mistral-7b [vlm] — mistral-7b backbone: 32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=32000; anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The modality frontend is a STUB per the assignment: input_specs provides
precomputed CLIP-L patch embeddings (batch, 576, 1024) — the base-res
24×24 anyres grid — and a learned projector maps them into the token
sequence (model.py prepends them; loss applies to text positions only).
"""
from repro.models.config import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab_size=32_000,
    rope=True,
    rope_theta=10_000.0,
    frontend=FrontendConfig(n_prefix=576, d_input=1024),
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    max_seq_len=32_768,
)
