"""starcoder2-7b [dense] — 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152, RoPE. [arXiv:2402.19173; hf]

StarCoder2 flavor: LayerNorm (with bias), non-gated GELU MLP, QKV bias.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_head=128,
    d_ff=18_432,
    vocab_size=49_152,
    rope=True,
    rope_theta=100_000.0,
    qkv_bias=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    max_seq_len=32_768,
)
