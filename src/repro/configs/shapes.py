"""The assigned input-shape cells and their applicability rules.

LM transformer shapes (seq_len × global_batch):
  train_4k     4,096 × 256   training        -> lowers train_step
  prefill_32k  32,768 × 32   inference       -> lowers prefill
  decode_32k   32,768 × 128  inference       -> lowers serve_step (1 token,
                                               KV cache of seq_len)
  long_500k    524,288 × 1   long-context    -> serve_step; SUB-QUADRATIC
                                               archs only (skip + note in
                                               DESIGN.md for the rest)
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skipped). Encoder-only archs would skip decode
    cells, but none are assigned (whisper is enc-dec and decodes)."""
    if cell.name == "long_500k" and not cfg.is_subquadratic():
        return False, (
            f"{cfg.name}: pure full-attention arch — 500k-token decode is "
            "quadratic-cost/unbounded-KV; skipped per assignment"
        )
    if cell.name == "long_500k" and cfg.encoder is not None:
        return False, (
            f"{cfg.name}: enc-dec decoder context (448 tokens for whisper) "
            "is far below 500k; skipped per assignment"
        )
    return True, ""
