"""Simulated Kubernetes cluster: nodes, pods, priority scheduling, preemption.

This is the environment the paper's provisioner drives.  Faithful to the
mechanisms the paper relies on:

  * pods request {cpu, gpu, memory, disk}; the scheduler bin-packs them
    onto nodes (best-fit by leftover gpu, then cpu)
  * priorityClass (Fig 1: `priority_class=opportunistic`): higher-priority
    pending pods may PREEMPT lower-priority running pods (§5 — batch pods
    run low-priority so service workloads evict them, not vice versa)
  * tolerations / node selectors (Fig 1): a pod only lands on nodes whose
    taints are all tolerated and whose labels satisfy the node affinity
  * node-level failures / spot reclaims (§5): all pods on the node die
  * TPU extension (hardware adaptation): a node models a pod-slice host
    group with `chips`; worker pods request whole sub-slices

The cluster is deliberately control-plane-only: pod "work" happens in
worker.py (HTCondor startd side).  Everything advances via tick(now).

Scale: pods are indexed by phase (PENDING/RUNNING dicts) and running pods
additionally by node, so `pending_pods()`, `running_pods()`, and node
drain are O(result) instead of O(all pods ever).  The scheduler is
event-driven via a dirty flag: a pass only runs when something that could
change placement happened (pod created/stopped, node added/removed) — a
pool with only unplaceable pending pods costs nothing per tick.  Node
busy-resource-seconds integrate lazily at every usage change, so a pod
reclaimed mid-tick is accounted to its exact stop time.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable

PRIORITY = {"system": 1000, "production": 100, "default": 50,
            "opportunistic": 10}


class PodPhase(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    SUCCEEDED = "succeeded"
    FAILED = "failed"      # includes preempted / node-lost


@dataclasses.dataclass
class Node:
    name: str
    capacity: dict[str, float]          # cpu, gpu, memory, disk, chips
    labels: dict[str, str] = dataclasses.field(default_factory=dict)
    taints: tuple[str, ...] = ()
    created_at: float = 0.0
    # accounting
    busy_integral: dict[str, float] = dataclasses.field(
        default_factory=dict)   # resource-seconds in use
    alive_s: float = 0.0

    def allocatable(self, pods: list["Pod"], *,
                    used: dict[str, float] | None = None
                    ) -> dict[str, float]:
        if used is None:
            used = {}
            for p in pods:
                if p.node == self.name and p.phase == PodPhase.RUNNING:
                    for k, v in p.request.items():
                        used[k] = used.get(k, 0) + v
        return {k: self.capacity.get(k, 0) - used.get(k, 0)
                for k in set(self.capacity) | set(used)}


@dataclasses.dataclass
class Pod:
    name: str
    request: dict[str, float]
    priority_class: str = "default"
    tolerations: tuple[str, ...] = ()
    node_selector: dict[str, Any] = dataclasses.field(default_factory=dict)
    labels: dict[str, str] = dataclasses.field(default_factory=dict)
    on_start: Callable[["Pod", float], None] | None = None
    on_stop: Callable[["Pod", float, str], None] | None = None

    phase: PodPhase = PodPhase.PENDING
    node: str | None = None
    created_at: float = 0.0
    started_at: float = -1.0
    stopped_at: float = -1.0
    stop_reason: str = ""

    @property
    def priority(self) -> int:
        return PRIORITY.get(self.priority_class, 50)


# -- node / pod (de)serialization --------------------------------------------
def node_state(n: Node) -> dict:
    return {
        "name": n.name,
        "capacity": dict(n.capacity),
        "labels": dict(n.labels),
        "taints": list(n.taints),
        "created_at": n.created_at,
        "busy_integral": dict(n.busy_integral),
        "alive_s": n.alive_s,
    }


def node_from_state(s: dict) -> Node:
    return Node(
        name=s["name"],
        capacity=dict(s["capacity"]),
        labels=dict(s.get("labels", {})),
        taints=tuple(s.get("taints", ())),
        created_at=float(s.get("created_at", 0.0)),
        busy_integral=dict(s.get("busy_integral", {})),
        alive_s=float(s.get("alive_s", 0.0)),
    )


def pod_state(p: Pod) -> dict:
    """JSON-safe snapshot.  `on_start`/`on_stop` closures are NOT
    serialized — the provisioner re-wires its own pods on restore
    (`Provisioner.rewire_pods`); foreign pods come back callback-less."""
    return {
        "name": p.name,
        "request": dict(p.request),
        "priority_class": p.priority_class,
        "tolerations": list(p.tolerations),
        "node_selector": {k: (list(v) if isinstance(v, (list, tuple, set))
                              else v)
                          for k, v in p.node_selector.items()},
        "labels": dict(p.labels),
        "phase": p.phase.value,
        "node": p.node,
        "created_at": p.created_at,
        "started_at": p.started_at,
        "stopped_at": p.stopped_at,
        "stop_reason": p.stop_reason,
    }


def pod_from_state(s: dict) -> Pod:
    return Pod(
        name=s["name"],
        request=dict(s["request"]),
        priority_class=s.get("priority_class", "default"),
        tolerations=tuple(s.get("tolerations", ())),
        node_selector={k: (tuple(v) if isinstance(v, list) else v)
                       for k, v in s.get("node_selector", {}).items()},
        labels=dict(s.get("labels", {})),
        phase=PodPhase(s["phase"]),
        node=s.get("node"),
        created_at=float(s.get("created_at", 0.0)),
        started_at=float(s.get("started_at", -1.0)),
        stopped_at=float(s.get("stopped_at", -1.0)),
        stop_reason=s.get("stop_reason", ""),
    )


class KubeCluster:
    def __init__(self, nodes: list[Node] | None = None, *,
                 enable_preemption: bool = True, name: str = "default"):
        self.name = name                    # owning backend (federation)
        self.nodes: dict[str, Node] = {n.name: n for n in (nodes or [])}
        self.pods: dict[str, Pod] = {}
        self.enable_preemption = enable_preemption
        self._ids = itertools.count()
        self.now = 0.0
        self.events: list[tuple[float, str, str]] = []  # (t, kind, detail)
        # incremental per-node usage cache (O(1) allocatable checks)
        self._used: dict[str, dict[str, float]] = {}
        # phase/node indexes (O(result) listings at 100k-pod scale)
        self._pending: dict[str, Pod] = {}
        self._running: dict[str, Pod] = {}
        self._node_pods: dict[str, dict[str, Pod]] = {}
        # lazy busy-integral accounting: last time each node was integrated
        self._acct_t: dict[str, float] = {n: 0.0 for n in self.nodes}
        # scheduler dirty flag: pass runs only when placement could change
        self._dirty = True

    def _account_node(self, name: str, t: float):
        """Integrate a node's alive time AND busy resource-seconds up to
        `t` with the CURRENT usage — called before any usage change, so a
        mid-tick pod stop is accounted at its exact timestamp and
        utilization (busy/alive) can never exceed 1."""
        node = self.nodes.get(name)
        if node is None:
            return
        t0 = self._acct_t.get(name, node.created_at)
        if t > t0:
            node.alive_s += t - t0
            for k, v in self._used.get(name, {}).items():
                if v:
                    node.busy_integral[k] = (
                        node.busy_integral.get(k, 0) + v * (t - t0))
        self._acct_t[name] = max(t0, t)

    def _use(self, node: str, request: dict, sign: float, now: float):
        self._account_node(node, now)
        u = self._used.setdefault(node, {})
        for k, v in request.items():
            u[k] = u.get(k, 0) + sign * v

    def node_used(self, node: str) -> dict[str, float]:
        return dict(self._used.get(node, {}))

    # -- API used by the provisioner (namespaced service account) ----------
    def create_pod(self, pod: Pod, now: float) -> str:
        pod.name = pod.name or f"pod-{next(self._ids)}"
        pod.created_at = now
        self.pods[pod.name] = pod
        if pod.phase == PodPhase.PENDING:
            self._pending[pod.name] = pod
            self._dirty = True
        return pod.name

    def delete_pod(self, name: str, now: float, reason: str = "deleted"):
        pod = self.pods.get(name)
        if pod is None:
            return
        self._stop_pod(pod, now, reason)
        self.pods.pop(name, None)

    def pending_pods(self, selector: Callable[[Pod], bool] | None = None
                     ) -> list[Pod]:
        out = list(self._pending.values())
        return [p for p in out if selector(p)] if selector else out

    def running_pods(self, selector: Callable[[Pod], bool] | None = None
                     ) -> list[Pod]:
        out = list(self._running.values())
        return [p for p in out if selector(p)] if selector else out

    def pods_on_node(self, name: str) -> list[Pod]:
        """RUNNING pods on one node (O(result); node drain, autoscaler)."""
        return list(self._node_pods.get(name, {}).values())

    # -- node lifecycle (autoscaler / failures) ------------------------------
    def add_node(self, node: Node, now: float):
        node.created_at = now
        self.nodes[node.name] = node
        self._acct_t[node.name] = now
        self._dirty = True
        self.events.append((now, "node_add", node.name))

    def remove_node(self, name: str, now: float, reason: str = "scale_down"):
        for pod in self.pods_on_node(name):
            self._stop_pod(pod, now, f"node_{reason}")
        self._account_node(name, now)
        self.nodes.pop(name, None)
        self._used.pop(name, None)
        self._node_pods.pop(name, None)
        self._acct_t.pop(name, None)
        self._dirty = True
        self.events.append((now, "node_remove", f"{name}:{reason}"))

    def fail_node(self, name: str, now: float):
        """Spot reclaim / hardware failure (§5): pods die with the node."""
        self.remove_node(name, now, reason="failure")

    # -- scheduling ----------------------------------------------------------
    def _fits(self, pod: Pod, node: Node, free: dict[str, float]) -> bool:
        for taint in node.taints:
            if taint not in pod.tolerations:
                return False
        for k, want in pod.node_selector.items():
            have = node.labels.get(k)
            if isinstance(want, (list, tuple, set)):
                if have not in want:
                    return False
            elif have != want:
                return False
        return all(free.get(k, 0) >= v for k, v in pod.request.items())

    def _stop_pod(self, pod: Pod, now: float, reason: str):
        if pod.phase == PodPhase.RUNNING:
            if pod.node is not None:
                self._use(pod.node, pod.request, -1.0, now)
                node_idx = self._node_pods.get(pod.node)
                if node_idx is not None:
                    node_idx.pop(pod.name, None)
            if pod.on_stop is not None:
                pod.on_stop(pod, now, reason)
        if pod.phase in (PodPhase.RUNNING, PodPhase.PENDING):
            self._pending.pop(pod.name, None)
            self._running.pop(pod.name, None)
            pod.phase = (PodPhase.FAILED if reason != "completed"
                         else PodPhase.SUCCEEDED)
            pod.stopped_at = now
            pod.stop_reason = reason
            self._dirty = True

    def succeed_pod(self, name: str, now: float):
        """Worker self-termination (C2) reports success."""
        pod = self.pods.get(name)
        if pod is not None:
            self._stop_pod(pod, now, "completed")
            self.pods.pop(name, None)

    @staticmethod
    def _placement_shape(pod: Pod) -> tuple:
        """Everything placement depends on besides free capacity.  Within
        one pass, capacity only shrinks between preemption events, so once
        a shape fails, identical later pods fail too."""
        return (
            pod.priority,
            tuple(sorted(pod.request.items())),
            pod.tolerations,
            tuple(sorted((k, str(v)) for k, v in
                         pod.node_selector.items())),
        )

    def schedule(self, now: float):
        """One scheduling pass: place pending pods (highest priority first,
        FIFO within class); preempt lower-priority pods when allowed.
        Skipped entirely when nothing changed since the last pass.

        A backlog of identical pending pods (the provisioner's common
        case: one group, hundreds queued) costs ONE failed
        place+preempt attempt per pass, not one per pod: shapes that
        failed are skipped for the rest of the pass.  A preemption that
        frees more than its beneficiary consumes re-dirties the cluster,
        so skipped pods get their chance next pass."""
        if not self._pending or not self._dirty:
            return
        self._dirty = False
        pending = sorted(
            self.pending_pods(), key=lambda p: (-p.priority, p.created_at)
        )
        failed: set[tuple] = set()
        for pod in pending:
            shape = self._placement_shape(pod)
            if shape in failed:
                continue
            placed = self._try_place(pod, now)
            if not placed and self.enable_preemption:
                placed = self._try_preempt(pod, now)
            if not placed:
                failed.add(shape)

    def _try_place(self, pod: Pod, now: float) -> bool:
        best: tuple[float, float, Node] | None = None
        for node in self.nodes.values():
            free = node.allocatable((), used=self.node_used(node.name))
            if self._fits(pod, node, free):
                # best-fit: least leftover gpu (then cpu) after placement
                left_gpu = free.get("gpu", 0) - pod.request.get("gpu", 0)
                left_cpu = free.get("cpu", 0) - pod.request.get("cpu", 0)
                key = (left_gpu, left_cpu)
                if best is None or key < (best[0], best[1]):
                    best = (*key, node)
        if best is None:
            return False
        node = best[2]
        pod.phase = PodPhase.RUNNING
        pod.node = node.name
        self._pending.pop(pod.name, None)
        self._running[pod.name] = pod
        self._node_pods.setdefault(node.name, {})[pod.name] = pod
        self._use(node.name, pod.request, +1.0, now)
        pod.started_at = now
        if pod.on_start is not None:
            pod.on_start(pod, now)
        return True

    def _try_preempt(self, pod: Pod, now: float) -> bool:
        """Evict the cheapest set of strictly-lower-priority pods on some
        node that would make room (k8s preemption, simplified)."""
        for node in self.nodes.values():
            victims = [
                p for p in self.pods_on_node(node.name)
                if p.priority < pod.priority
            ]
            if not victims:
                continue
            free = node.allocatable((), used=self.node_used(node.name))
            if any(t not in pod.tolerations for t in node.taints):
                continue
            sel_ok = all(
                (node.labels.get(k) in v if isinstance(v, (list, tuple, set))
                 else node.labels.get(k) == v)
                for k, v in pod.node_selector.items()
            )
            if not sel_ok:
                continue
            victims.sort(key=lambda p: (p.priority, -p.started_at))
            chosen = []
            for v in victims:
                if all(free.get(k, 0) >= r
                       for k, r in pod.request.items()):
                    break
                chosen.append(v)
                for k, r in v.request.items():
                    free[k] = free.get(k, 0) + r
            if all(free.get(k, 0) >= r for k, r in pod.request.items()):
                for v in chosen:
                    self._stop_pod(v, now, "preempted")
                    self.events.append((now, "preempt", v.name))
                return self._try_place(pod, now)
        return False

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe snapshot.  Index ORDERS are serialized explicitly:
        best-fit placement iterates `nodes` in insertion order, the
        pending sort breaks (priority, created_at) ties on `_pending`
        insertion order, and preemption victim ties follow `_node_pods`
        order — recomputing any of them could diverge a restored run.
        The `events` debug log is NOT serialized (unbounded, and nothing
        in the control flow reads it)."""
        nid = next(self._ids)
        self._ids = itertools.count(nid)   # non-destructive peek
        return {
            "name": self.name,
            "now": self.now,
            "dirty": self._dirty,
            "next_id": nid,
            "nodes": [node_state(n) for n in self.nodes.values()],
            "acct_t": dict(self._acct_t),
            "used": {k: dict(v) for k, v in self._used.items()},
            "pods": [pod_state(p) for p in self.pods.values()],
            "pending": list(self._pending.keys()),
            "running": list(self._running.keys()),
            "node_pods": {n: list(d.keys())
                          for n, d in self._node_pods.items()},
        }

    def load_state(self, state: dict) -> None:
        self.now = float(state.get("now", 0.0))
        self._dirty = bool(state.get("dirty", True))
        self._ids = itertools.count(int(state.get("next_id", 0)))
        self.nodes = {}
        for ns in state.get("nodes", []):
            n = node_from_state(ns)
            self.nodes[n.name] = n
        self._acct_t = {k: float(v)
                        for k, v in state.get("acct_t", {}).items()}
        self._used = {k: dict(v) for k, v in state.get("used", {}).items()}
        self.pods = {}
        for ps in state.get("pods", []):
            p = pod_from_state(ps)
            self.pods[p.name] = p
        self._pending = {n: self.pods[n] for n in state.get("pending", [])}
        self._running = {n: self.pods[n] for n in state.get("running", [])}
        self._node_pods = {
            node: {n: self.pods[n] for n in names}
            for node, names in state.get("node_pods", {}).items()
        }

    # -- accounting -----------------------------------------------------------
    def tick_accounting(self, dt: float, now: float | None = None):
        """Bring every node's lazy alive/busy integrals up to `now`
        (defaults to self.now + dt for tick-loop callers).  Idempotent at
        a fixed `now`, so priming passes and repeated ticks are safe."""
        if now is None:
            now = self.now + dt
        self.now = max(self.now, now)
        for name in self.nodes:
            self._account_node(name, now)

    def utilization(self, resource: str = "gpu") -> float:
        """Fraction of provisioned resource-seconds actually used."""
        cap = sum(
            n.capacity.get(resource, 0) * n.alive_s
            for n in self.nodes.values()
        )
        busy = sum(
            n.busy_integral.get(resource, 0) for n in self.nodes.values()
        )
        return busy / cap if cap > 0 else 0.0

    def resource_seconds(self, resource: str = "gpu") -> tuple[float, float]:
        """(provisioned, busy) resource-seconds — the per-backend harvested
        compute split (Fig 2 analogue per provider)."""
        cap = sum(n.capacity.get(resource, 0) * n.alive_s
                  for n in self.nodes.values())
        busy = sum(n.busy_integral.get(resource, 0)
                   for n in self.nodes.values())
        return cap, busy

    def count_pods(self, **labels: str) -> int:
        """Live pods matching every given label (backend attribution)."""
        n = 0
        for p in itertools.chain(self._pending.values(),
                                 self._running.values()):
            if all(p.labels.get(k) == v for k, v in labels.items()):
                n += 1
        return n
