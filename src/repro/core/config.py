"""Provisioner configuration: the paper's INI file format (§3, Fig 1).

Example (verbatim structure from the paper)::

    [DEFAULT]
    k8s_domain=nrp-nautilus.io

    [k8s]
    tolerations_list=nautilus.io/noceph, nautilus.io/suncave
    node_affinity_dict=^nautilus.io/low-power:true,gpu-type:A100|A40|V100
    priority_class=opportunistic
    envs_dict=USE_SINGULARITY:no,GLIDEIN_Site:SDSC-PRP

Conventions reproduced from the paper's configurator:
  *_list   — comma-separated values
  *_dict   — comma-separated key:value pairs; values may be |-alternatives
             (sets); a leading ^ on a key negates the match (anti-affinity)

The [provision] section adds the scaling knobs (filter, limits, timing) and
[condor] the pool endpoint — in the real deployment the HTCondor secret and
central-manager address arrive via k8s secret/env (§3); here they are just
fields.
"""
from __future__ import annotations

import configparser
import dataclasses
from typing import Any

from repro.core.classad import ClassAdExpr


def _parse_list(s: str) -> tuple[str, ...]:
    return tuple(x.strip() for x in s.split(",") if x.strip())


def _parse_dict(s: str) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for item in s.split(","):
        item = item.strip()
        if not item:
            continue
        key, _, val = item.partition(":")
        key = key.strip()
        alts = tuple(v.strip() for v in val.split("|"))
        out[key] = alts[0] if len(alts) == 1 else alts
    return out


@dataclasses.dataclass
class ProvisionerConfig:
    # [condor]
    central_manager: str = "cm.local"
    token_secret: str = "condor-token"           # k8s secret name (§3)

    # [provision]
    job_filter: str = ""                          # ClassAd expr (C3)
    max_pods_per_group: int = 64
    max_total_pods: int = 256
    submit_interval_s: float = 60.0               # reconciliation period
    idle_timeout_s: float = 300.0                 # worker self-term (C2)
    startup_delay_s: float = 30.0
    group_extra_keys: tuple[str, ...] = ("arch",)

    # [k8s] (Fig 1)
    k8s_domain: str = "nrp-nautilus.io"
    namespace: str = "osg-pool"
    image: str = "centos:htcondor-execute-gpu"    # default execute image
    priority_class: str = "opportunistic"
    tolerations: tuple[str, ...] = ()
    node_affinity: dict[str, Any] = dataclasses.field(default_factory=dict)
    envs: dict[str, str] = dataclasses.field(default_factory=dict)
    storage: dict[str, str] = dataclasses.field(default_factory=dict)

    def filter_expr(self) -> ClassAdExpr:
        return ClassAdExpr(self.job_filter)

    def start_expr(self) -> ClassAdExpr:
        """The pushed-down execute-side START policy (C3): same filter the
        provisioner counts with, evaluated worker-side against the job ad
        (worker ad is MY, job ad is TARGET)."""
        return ClassAdExpr(self.job_filter)


def load_ini(text: str) -> ProvisionerConfig:
    cp = configparser.ConfigParser()
    cp.read_string(text)
    cfg = ProvisionerConfig()

    if cp.has_section("condor") or "condor" in cp:
        sec = cp["condor"]
        cfg.central_manager = sec.get("central_manager", cfg.central_manager)
        cfg.token_secret = sec.get("token_secret", cfg.token_secret)

    if "provision" in cp:
        sec = cp["provision"]
        cfg.job_filter = sec.get("job_filter", cfg.job_filter)
        cfg.max_pods_per_group = sec.getint(
            "max_pods_per_group", cfg.max_pods_per_group)
        cfg.max_total_pods = sec.getint("max_total_pods", cfg.max_total_pods)
        cfg.submit_interval_s = sec.getfloat(
            "submit_interval_s", cfg.submit_interval_s)
        cfg.idle_timeout_s = sec.getfloat("idle_timeout_s", cfg.idle_timeout_s)
        cfg.startup_delay_s = sec.getfloat(
            "startup_delay_s", cfg.startup_delay_s)
        if sec.get("group_extra_keys_list"):
            cfg.group_extra_keys = _parse_list(sec["group_extra_keys_list"])

    if "k8s" in cp:
        sec = cp["k8s"]
        cfg.k8s_domain = sec.get("k8s_domain", cfg.k8s_domain)
        cfg.namespace = sec.get("namespace", cfg.namespace)
        cfg.image = sec.get("image", cfg.image)
        cfg.priority_class = sec.get("priority_class", cfg.priority_class)
        if sec.get("tolerations_list"):
            cfg.tolerations = _parse_list(sec["tolerations_list"])
        if sec.get("node_affinity_dict"):
            cfg.node_affinity = _parse_dict(sec["node_affinity_dict"])
        if sec.get("envs_dict"):
            cfg.envs = {k: str(v) for k, v in
                        _parse_dict(sec["envs_dict"]).items()}
        if sec.get("storage_dict"):
            cfg.storage = {k: str(v) for k, v in
                           _parse_dict(sec["storage_dict"]).items()}
    return cfg


PAPER_EXAMPLE_INI = """\
[DEFAULT]
k8s_domain=nrp-nautilus.io

[k8s]
tolerations_list=nautilus.io/noceph, nautilus.io/suncave
node_affinity_dict=^nautilus.io/low-power:true,gpu-type:A100|A40|V100
priority_class=opportunistic
envs_dict=USE_SINGULARITY:no,GLIDEIN_Site:SDSC-PRP
"""
