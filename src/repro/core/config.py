"""Provisioner configuration: the paper's INI file format (§3, Fig 1).

Example (verbatim structure from the paper)::

    [DEFAULT]
    k8s_domain=nrp-nautilus.io

    [k8s]
    tolerations_list=nautilus.io/noceph, nautilus.io/suncave
    node_affinity_dict=^nautilus.io/low-power:true,gpu-type:A100|A40|V100
    priority_class=opportunistic
    envs_dict=USE_SINGULARITY:no,GLIDEIN_Site:SDSC-PRP

Conventions reproduced from the paper's configurator:
  *_list   — comma-separated values
  *_dict   — comma-separated key:value pairs; values may be |-alternatives
             (sets); a leading ^ on a key negates the match (anti-affinity)

The [provision] section adds the scaling knobs (filter, limits, timing) and
[condor] the pool endpoint — in the real deployment the HTCondor secret and
central-manager address arrive via k8s secret/env (§3); here they are just
fields.

Federation extension (backend API): any number of `[backend:<name>]`
sections declare resource providers — each with its own node template,
limits, priority class, and cost — consumed by
`repro.core.backend.build_backends`.  The paper's Fig-1 single-section
format stays valid: no `[backend:*]` section means one default backend
wrapping whatever cluster the caller supplies.  `[provision]` gains
`routing_policy` (fill-first | cheapest-first | weighted-spread |
spot-with-fallback) to pick how deficits split across backends.
"""
from __future__ import annotations

import configparser
import dataclasses
from typing import Any

from repro.core.classad import ClassAdExpr


def _parse_list(s: str) -> tuple[str, ...]:
    return tuple(x.strip() for x in s.split(",") if x.strip())


def _parse_dict(s: str) -> dict[str, Any]:
    out: dict[str, Any] = {}
    for item in s.split(","):
        item = item.strip()
        if not item:
            continue
        key, _, val = item.partition(":")
        key = key.strip()
        alts = tuple(v.strip() for v in val.split("|"))
        out[key] = alts[0] if len(alts) == 1 else alts
    return out


def _parse_num_dict(s: str) -> dict[str, float]:
    return {k: float(v) for k, v in _parse_dict(s).items()}


def _fmt_dict(d: dict) -> str:
    parts = []
    for k, v in d.items():
        if isinstance(v, (list, tuple, set)):
            v = "|".join(str(x) for x in v)
        elif isinstance(v, float) and v == int(v):
            v = int(v)
        parts.append(f"{k}:{v}")
    return ",".join(parts)


@dataclasses.dataclass
class BackendConfig:
    """One `[backend:<name>]` INI section: a resource provider's node
    template, limits, placement policy, and cost model."""
    name: str = "default"
    kind: str = "static"                     # static | autoscale
    nodes: int = 0                           # static: pool size at t=0
    capacity: dict[str, float] = dataclasses.field(
        default_factory=lambda: {"cpu": 64.0, "gpu": 8.0,
                                 "memory": 512.0, "disk": 1024.0})
    node_labels: dict[str, str] = dataclasses.field(default_factory=dict)
    taints: tuple[str, ...] = ()
    max_nodes: int = 64                      # autoscale: node cap
    max_pods: int = 1_000_000                # provider-level pod cap
    provision_delay_s: float = 90.0
    scale_down_delay_s: float = 600.0
    node_hourly_cost: float = 0.0            # 0 ⇒ sunk / donated (on-prem)
    pod_hourly_cost: float = 0.0             # per-pod surcharge (spot bids)
    priority_class: str = ""                 # "" ⇒ inherit [k8s]
    tolerations: tuple[str, ...] = ()
    node_affinity: dict[str, Any] = dataclasses.field(default_factory=dict)
    spot: bool = False                       # reclaimable capacity
    weight: float = 1.0                      # weighted-spread share


@dataclasses.dataclass
class ProvisionerConfig:
    # [condor]
    central_manager: str = "cm.local"
    token_secret: str = "condor-token"           # k8s secret name (§3)

    # [provision]
    job_filter: str = ""                          # ClassAd expr (C3)
    max_pods_per_group: int = 64
    max_total_pods: int = 256
    submit_interval_s: float = 60.0               # reconciliation period
    idle_timeout_s: float = 300.0                 # worker self-term (C2)
    startup_delay_s: float = 30.0
    group_extra_keys: tuple[str, ...] = ("arch",)
    routing_policy: str = "fill-first"            # backend deficit split
    matchmaker: str = "numpy"                     # negotiation backend
    #   ("numpy" reference | "jax" jitted | "pallas" fused kernel |
    #    "scan" per-job oracle; see core/matchmaker)
    negotiation_batch: int = 1                    # staged cycles per fused
    #   flush (1 = negotiate every cycle immediately; >1 batches K
    #   consecutive cycles through the backend's fused multi-cycle jit)

    # [backend:<name>] sections (empty ⇒ single default backend)
    backends: tuple[BackendConfig, ...] = ()

    # [k8s] (Fig 1)
    k8s_domain: str = "nrp-nautilus.io"
    namespace: str = "osg-pool"
    image: str = "centos:htcondor-execute-gpu"    # default execute image
    priority_class: str = "opportunistic"
    tolerations: tuple[str, ...] = ()
    node_affinity: dict[str, Any] = dataclasses.field(default_factory=dict)
    envs: dict[str, str] = dataclasses.field(default_factory=dict)
    storage: dict[str, str] = dataclasses.field(default_factory=dict)

    def filter_expr(self) -> ClassAdExpr:
        return ClassAdExpr(self.job_filter)

    def start_expr(self) -> ClassAdExpr:
        """The pushed-down execute-side START policy (C3): same filter the
        provisioner counts with, evaluated worker-side against the job ad
        (worker ad is MY, job ad is TARGET)."""
        return ClassAdExpr(self.job_filter)


def load_ini(text: str) -> ProvisionerConfig:
    cp = configparser.ConfigParser()
    cp.read_string(text)
    cfg = ProvisionerConfig()

    if cp.has_section("condor") or "condor" in cp:
        sec = cp["condor"]
        cfg.central_manager = sec.get("central_manager", cfg.central_manager)
        cfg.token_secret = sec.get("token_secret", cfg.token_secret)

    if "provision" in cp:
        sec = cp["provision"]
        cfg.job_filter = sec.get("job_filter", cfg.job_filter)
        cfg.max_pods_per_group = sec.getint(
            "max_pods_per_group", cfg.max_pods_per_group)
        cfg.max_total_pods = sec.getint("max_total_pods", cfg.max_total_pods)
        cfg.submit_interval_s = sec.getfloat(
            "submit_interval_s", cfg.submit_interval_s)
        cfg.idle_timeout_s = sec.getfloat("idle_timeout_s", cfg.idle_timeout_s)
        cfg.startup_delay_s = sec.getfloat(
            "startup_delay_s", cfg.startup_delay_s)
        if sec.get("group_extra_keys_list"):
            cfg.group_extra_keys = _parse_list(sec["group_extra_keys_list"])
        cfg.routing_policy = sec.get("routing_policy", cfg.routing_policy)
        cfg.matchmaker = sec.get("matchmaker", cfg.matchmaker)
        cfg.negotiation_batch = sec.getint(
            "negotiation_batch", cfg.negotiation_batch)

    if "k8s" in cp:
        sec = cp["k8s"]
        cfg.k8s_domain = sec.get("k8s_domain", cfg.k8s_domain)
        cfg.namespace = sec.get("namespace", cfg.namespace)
        cfg.image = sec.get("image", cfg.image)
        cfg.priority_class = sec.get("priority_class", cfg.priority_class)
        if sec.get("tolerations_list"):
            cfg.tolerations = _parse_list(sec["tolerations_list"])
        if sec.get("node_affinity_dict"):
            cfg.node_affinity = _parse_dict(sec["node_affinity_dict"])
        if sec.get("envs_dict"):
            cfg.envs = {k: str(v) for k, v in
                        _parse_dict(sec["envs_dict"]).items()}
        if sec.get("storage_dict"):
            cfg.storage = {k: str(v) for k, v in
                           _parse_dict(sec["storage_dict"]).items()}

    backends = []
    for section in cp.sections():
        if not section.startswith("backend:"):
            continue
        backends.append(_load_backend_section(
            section.split(":", 1)[1], cp[section]))
    cfg.backends = tuple(backends)
    return cfg


def _load_backend_section(name: str, sec) -> BackendConfig:
    bc = BackendConfig(name=name)
    bc.kind = sec.get("kind", bc.kind)
    bc.nodes = sec.getint("nodes", bc.nodes)
    if sec.get("capacity_dict"):
        bc.capacity = _parse_num_dict(sec["capacity_dict"])
    if sec.get("node_labels_dict"):
        bc.node_labels = {k: str(v) for k, v in
                          _parse_dict(sec["node_labels_dict"]).items()}
    if sec.get("taints_list"):
        bc.taints = _parse_list(sec["taints_list"])
    bc.max_nodes = sec.getint("max_nodes", bc.max_nodes)
    bc.max_pods = sec.getint("max_pods", bc.max_pods)
    bc.provision_delay_s = sec.getfloat(
        "provision_delay_s", bc.provision_delay_s)
    bc.scale_down_delay_s = sec.getfloat(
        "scale_down_delay_s", bc.scale_down_delay_s)
    bc.node_hourly_cost = sec.getfloat(
        "node_hourly_cost", bc.node_hourly_cost)
    bc.pod_hourly_cost = sec.getfloat(
        "pod_hourly_cost", bc.pod_hourly_cost)
    bc.priority_class = sec.get("priority_class", bc.priority_class)
    if sec.get("tolerations_list"):
        bc.tolerations = _parse_list(sec["tolerations_list"])
    if sec.get("node_affinity_dict"):
        bc.node_affinity = _parse_dict(sec["node_affinity_dict"])
    bc.spot = sec.getboolean("spot", bc.spot)
    bc.weight = sec.getfloat("weight", bc.weight)
    return bc


def dump_ini(cfg: ProvisionerConfig) -> str:
    """Inverse of `load_ini` — lets a multi-backend deployment be
    captured back to the paper's INI format for audit/diffing.

    Round-trip safe within the paper's configurator conventions: dict
    values must not contain the ``,``/``:`` separators (the format has
    no escaping — same restriction as the paper's own Fig-1 files), and
    an explicitly-empty ``group_extra_keys`` reloads as the default."""
    lines = [
        "[condor]",
        f"central_manager={cfg.central_manager}",
        f"token_secret={cfg.token_secret}",
        "",
        "[provision]",
        f"job_filter={cfg.job_filter}",
        f"max_pods_per_group={cfg.max_pods_per_group}",
        f"max_total_pods={cfg.max_total_pods}",
        f"submit_interval_s={cfg.submit_interval_s}",
        f"idle_timeout_s={cfg.idle_timeout_s}",
        f"startup_delay_s={cfg.startup_delay_s}",
        f"group_extra_keys_list={','.join(cfg.group_extra_keys)}",
        f"routing_policy={cfg.routing_policy}",
        f"matchmaker={cfg.matchmaker}",
        f"negotiation_batch={cfg.negotiation_batch}",
        "",
        "[k8s]",
        f"k8s_domain={cfg.k8s_domain}",
        f"namespace={cfg.namespace}",
        f"image={cfg.image}",
        f"priority_class={cfg.priority_class}",
    ]
    if cfg.tolerations:
        lines.append(f"tolerations_list={','.join(cfg.tolerations)}")
    if cfg.node_affinity:
        lines.append(f"node_affinity_dict={_fmt_dict(cfg.node_affinity)}")
    if cfg.envs:
        lines.append(f"envs_dict={_fmt_dict(cfg.envs)}")
    if cfg.storage:
        lines.append(f"storage_dict={_fmt_dict(cfg.storage)}")
    for bc in cfg.backends:
        lines += [
            "",
            f"[backend:{bc.name}]",
            f"kind={bc.kind}",
            f"nodes={bc.nodes}",
            f"capacity_dict={_fmt_dict(bc.capacity)}",
            f"max_nodes={bc.max_nodes}",
            f"max_pods={bc.max_pods}",
            f"provision_delay_s={bc.provision_delay_s}",
            f"scale_down_delay_s={bc.scale_down_delay_s}",
            f"node_hourly_cost={bc.node_hourly_cost}",
            f"pod_hourly_cost={bc.pod_hourly_cost}",
            f"spot={'true' if bc.spot else 'false'}",
            f"weight={bc.weight}",
        ]
        if bc.node_labels:
            lines.append(f"node_labels_dict={_fmt_dict(bc.node_labels)}")
        if bc.taints:
            lines.append(f"taints_list={','.join(bc.taints)}")
        if bc.priority_class:
            lines.append(f"priority_class={bc.priority_class}")
        if bc.tolerations:
            lines.append(f"tolerations_list={','.join(bc.tolerations)}")
        if bc.node_affinity:
            lines.append(
                f"node_affinity_dict={_fmt_dict(bc.node_affinity)}")
    return "\n".join(lines) + "\n"


PAPER_EXAMPLE_INI = """\
[DEFAULT]
k8s_domain=nrp-nautilus.io

[k8s]
tolerations_list=nautilus.io/noceph, nautilus.io/suncave
node_affinity_dict=^nautilus.io/low-power:true,gpu-type:A100|A40|V100
priority_class=opportunistic
envs_dict=USE_SINGULARITY:no,GLIDEIN_Site:SDSC-PRP
"""
