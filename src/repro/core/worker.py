"""HTCondor execute side: startd workers + the collector/negotiator.

A Worker is the HTCondor execute service living inside a Kubernetes pod.
Lifecycle (paper §2):

  pod PENDING -> pod RUNNING -> startd boots (startup_delay) -> advertises
  to the collector -> claims matching idle jobs (START expr, pushed down
  from the provisioner per C3) -> runs them -> when no matching idle job
  exists for `idle_timeout` seconds, SELF-TERMINATES (C2) -> pod succeeds.

Partitionable-slot semantics: a worker claims as many jobs as fit its
resources simultaneously (cpus/gpus/chips), like a partitionable startd
slot — one pod can serve several 1-GPU jobs on an 8-GPU request.

The collector is the pool registry; `negotiate()` is a single matchmaking
cycle pairing idle jobs with unclaimed worker capacity (symmetric_match:
job.Requirements against the worker ad AND the worker START against the
job ad).

Scale: `negotiate()` is vectorized over the queue's idle COHORTS
(jobqueue.py) — jobs with identical ads share one ClassAd evaluation per
worker, and how many cohort jobs fit each worker comes from a NumPy
free-resource matrix instead of per-job Python loops.  Expression results
for unclaimed workers are memoized in the collector (pure functions of
the two ads), which also makes the C2 idle poll in `advance_workers` a
cohort-count scan.  `negotiate_scan()` keeps the seed's per-job loop as
the differential-test oracle and the benchmark baseline.

Flocking (multi-schedd): `negotiate_cycle()` runs ONE matchmaking cycle
over an ordered list of schedd queues feeding the same pool — capacity
drains through a shared free-resource matrix, plain mode serves queues
strictly in flocking order, and with a fair-share `Accountant`
(core/fairshare.py) the cycle water-fills capacity by per-schedd quota
and per-user effective priority instead.  `preview_matches()` is the
claim-free dry run the provisioner subtracts from idle counts so it
never provisions for jobs the next cycle will match anyway.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import numpy as np

from repro.core.classad import ClassAdExpr, symmetric_match
from repro.core.fairshare import job_cores
from repro.core.jobqueue import (
    Job, JobQueue, JobState, canonical_ad, user_of,
)

RESOURCE_KEYS = ("cpus", "gpus", "memory", "disk", "chips", "hbm_gb")
# offer-ad attributes whose values shrink as a slot fills; expressions
# reading them cannot be block-evaluated once per negotiation cycle
_QUANTITY_ATTRS = frozenset(RESOURCE_KEYS)


def _num(v: Any) -> float:
    return float(v) if isinstance(v, (int, float)) else 0.0


def _job_req_vec(job: Job) -> np.ndarray:
    """Job request over RESOURCE_KEYS, cached on the job (ads are fixed)."""
    v = getattr(job, "_req_vec", None)
    if v is None:
        v = np.array([_num(job.ad.get(f"request_{r}"))
                      for r in RESOURCE_KEYS], dtype=np.float64)
        job._req_vec = v
    return v


@dataclasses.dataclass
class Worker:
    name: str
    ad: dict[str, Any]                       # resources + advertised attrs
    start_expr: ClassAdExpr                  # pushed-down filter (C3)
    idle_timeout: float = 300.0
    startup_delay: float = 30.0
    pod_name: str | None = None
    work_rate: float = 1.0          # <1.0 models a straggling node

    booted_at: float = -1.0                  # when startd became ready
    idle_since: float = -1.0
    claimed: dict[int, Job] = dataclasses.field(default_factory=dict)
    terminated: bool = False
    # accounting
    busy_s: float = 0.0
    alive_s: float = 0.0
    _match_key: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _res_vec: Any = dataclasses.field(default=None, repr=False,
                                      compare=False)
    _used_vec: Any = dataclasses.field(default=None, repr=False,
                                       compare=False)

    def ready(self, now: float) -> bool:
        return self.booted_at >= 0 and now >= self.booted_at and not self.terminated

    # -- incremental resource vectors (hot path of the negotiator) -----------
    def res_vec(self) -> np.ndarray:
        if self._res_vec is None:
            self._res_vec = np.array(
                [_num(self.ad.get(r)) for r in RESOURCE_KEYS],
                dtype=np.float64)
        return self._res_vec

    def free_vec(self) -> np.ndarray:
        if self._used_vec is None:
            return self.res_vec().copy()
        return self.res_vec() - self._used_vec

    def add_claim(self, job: Job):
        self.claimed[job.jid] = job
        if self._used_vec is None:
            self._used_vec = np.zeros(len(RESOURCE_KEYS), dtype=np.float64)
        self._used_vec += _job_req_vec(job)

    def drop_claim(self, jid: int) -> Job | None:
        job = self.claimed.pop(jid, None)
        if job is not None and self._used_vec is not None:
            self._used_vec -= _job_req_vec(job)
        return job

    def clear_claims(self):
        self.claimed.clear()
        self._used_vec = None

    def free_resources(self) -> dict[str, float]:
        free = dict(self.ad)
        for job in self.claimed.values():
            for res in RESOURCE_KEYS:
                want = job.ad.get(f"request_{res}", 0) or 0
                if res in free and isinstance(free[res], (int, float)):
                    free[res] = free[res] - want
        return free

    def offer_ad(self) -> dict[str, Any]:
        """Current (partial-slot) offer: remaining resources + attrs."""
        return self.free_resources()

    def match_key(self) -> tuple:
        """Matchmaking-equivalence key of the FULL slot (ads are fixed at
        provisioning time, so this is computed once).  Uses the same ad
        canonicalization as the job-side cohort_key_of — the two halves
        jointly key the collector's match cache."""
        if self._match_key is None:
            self._match_key = (self.start_expr.src, canonical_ad(self.ad))
        return self._match_key


class Collector:
    """Pool registry + negotiator."""

    MATCH_CACHE_MAX = 100_000    # entries; reset-on-full (pure cache)

    def __init__(self):
        self.workers: dict[str, Worker] = {}
        self._ids = itertools.count()
        # (job cohort, worker slot shape) -> bool; symmetric_match is a
        # pure function of the two ads, so entries never invalidate
        self._match_cache: dict[tuple, bool] = {}
        # C2 idle-poll verdicts per SLOT SHAPE: {match_key: (idle-cohort
        # version, any-match verdict)} — valid until the idle-cohort SET
        # changes; a pool of identical idle workers polls once per
        # version, not once per worker per event
        self._poll_cache: dict[tuple, tuple[int, bool]] = {}

    def advertise(self, worker: Worker):
        self.workers[worker.name] = worker

    def invalidate(self, name: str):
        self.workers.pop(name, None)

    def alive_workers(self, now: float) -> list[Worker]:
        return [w for w in self.workers.values() if w.ready(now)]

    def unclaimed_capacity(self, group_matcher=None) -> int:
        """Workers with zero claims (counted by the provisioner against the
        deficit so it never over-submits; paper §2)."""
        n = 0
        for w in self.workers.values():
            if w.terminated or w.claimed:
                continue
            if group_matcher is None or group_matcher(w.ad):
                n += 1
        return n

    # -- cohort-level matchmaking -------------------------------------------
    def cohort_match(self, rep: Job, worker: Worker) -> bool:
        """Would `worker`'s slot match this cohort? Evaluated against the
        live offer for partially-claimed workers; memoized for unclaimed
        ones (offer == full ad)."""
        if worker.claimed:
            return symmetric_match(rep.ad, worker.offer_ad(),
                                   rep.requirements, worker.start_expr)
        key = (rep.cohort_key, worker.match_key())
        hit = self._match_cache.get(key)
        if hit is None:
            hit = symmetric_match(rep.ad, worker.ad, rep.requirements,
                                  worker.start_expr)
            if len(self._match_cache) >= self.MATCH_CACHE_MAX:
                # pathological per-job cohorts (e.g. trace replay with
                # unique ads): stop the memo growing without bound
                self._match_cache.clear()
            self._match_cache[key] = hit
        return hit

    def any_cohort_matches(self, worker: Worker, queue: JobQueue) -> bool:
        """C2 idle poll: does ANY idle job match this worker? One check
        per cohort, cache-hit for the common (idle worker) case.

        For an UNCLAIMED worker the verdict is a pure function of (slot
        shape, idle-cohort set) — matching uses the full slot ad — so it
        is cached per `worker.match_key()` against `queue.idle_version`:
        however many identical workers sit idle, each cohort-set change
        costs ONE rescan per distinct slot shape, and every other poll
        is a dict hit."""
        version = getattr(queue, "idle_version", None)
        cacheable = version is not None and not worker.claimed
        if cacheable:
            cached = self._poll_cache.get(worker.match_key())
            if cached is not None and cached[0] == version:
                return cached[1]
        hit = False
        for _key, jobs in queue.idle_cohorts():
            rep = next(iter(jobs.values()))
            if self.cohort_match(rep, worker):
                hit = True
                break
        if cacheable:
            if len(self._poll_cache) >= self.MATCH_CACHE_MAX:
                self._poll_cache.clear()
            self._poll_cache[worker.match_key()] = (version, hit)
        return hit

    def negotiate(self, queue: JobQueue, now: float) -> int:
        """One vectorized matchmaking cycle. Returns number of new claims.

        Cohorts are served earliest-submitter-first; per cohort, a NumPy
        mask over the worker free-resource matrix yields how many cohort
        jobs each candidate can absorb, and claims are handed out in
        worker advertisement order (the seed's first-match rule).

        FIFO is COHORT-granular: the cohort holding the oldest idle job
        drains before newer cohorts see capacity, like HTCondor's
        autocluster-batched negotiation.  Under scarce capacity this can
        differ from `negotiate_scan`'s per-job interleaving (a later job
        of the oldest cohort may beat an earlier job of a newer one) —
        the price of evaluating matchmaking once per cohort instead of
        once per job."""
        if not hasattr(queue, "idle_cohorts"):
            # foreign queue exposing only the seed surface: per-job scan
            # (mirrors Provisioner._idle_group_counts' fallback)
            return self.negotiate_scan(queue, now)
        cohorts = [(key, jobs) for key, jobs in queue.idle_cohorts() if jobs]
        if not cohorts:
            return 0
        workers = self.alive_workers(now)
        if not workers:
            return 0
        free = np.stack([w.free_vec() for w in workers])
        cohorts.sort(key=lambda kv: queue.cohort_first_submit(kv[0]))
        return self._match_cohorts(queue, cohorts, workers, free, now)

    def _match_cohorts(self, queue: JobQueue, cohorts: list, workers: list,
                       free: np.ndarray, now: float, *,
                       budget: int | None = None,
                       on_claim=None) -> int:
        """The vectorized claiming loop over pre-sorted cohorts, against
        a SHARED worker free-resource matrix (`free` mutates in place, so
        several schedds in one negotiation cycle see capacity drain as
        earlier ones claim).  `budget` caps new claims (fair-share hands
        out capacity in bounded slices); `on_claim(job)` observes each
        claim (the cycle charges usage from it)."""
        claims = 0
        for key, jobs in cohorts:
            if not jobs:
                continue               # drained by an earlier slice
            if budget is not None and claims >= budget:
                break
            rep = next(iter(jobs.values()))
            want = _job_req_vec(rep)
            pos = want > 0
            if pos.any():
                # +eps before floor: 7.6/0.4 is 18.999...96 in floats and
                # must count as 19 slots (the scan oracle's arithmetic
                # never divides, so it would claim that job)
                fits = np.floor(
                    (free[:, pos] / want[pos]).min(axis=1) + 1e-9)
                fits = np.maximum(fits, 0.0)
            else:
                # a zero-request cohort fits anywhere (bounded by demand)
                fits = np.full(len(workers), float(len(jobs)))
            if fits.sum() <= 0:
                continue
            pending = queue.cohort_jobs_sorted(
                key, None if budget is None else budget - claims)
            # A START/Requirements expression that reads offered QUANTITIES
            # (e.g. 'gpus >= 2') must be re-evaluated against the shrinking
            # offer after every claim — block-claiming is only exact for
            # quantity-blind policies (the common pushed-down filters).
            per_claim_check = bool(
                (rep.requirements.refs if rep.requirements is not None
                 else frozenset()) & _QUANTITY_ATTRS)
            ji = 0
            for wi, w in enumerate(workers):
                if ji >= len(pending):
                    break
                k = int(fits[wi])
                if k <= 0:
                    continue
                if not self.cohort_match(rep, w):
                    continue
                recheck = per_claim_check or bool(
                    w.start_expr.refs & _QUANTITY_ATTRS)
                take = min(k, len(pending) - ji)
                taken = 0
                for job in pending[ji:ji + take]:
                    if recheck and taken > 0 and not self.cohort_match(
                            rep, w):
                        break
                    queue.claim(job.jid, w.name, now)
                    w.add_claim(job)
                    if on_claim is not None:
                        on_claim(job)
                    taken += 1
                w.idle_since = -1.0
                free[wi] -= want * taken
                ji += taken
                claims += taken
        return claims

    def negotiate_scan(self, queue: JobQueue, now: float) -> int:
        """The seed's per-job O(idle × workers) cycle — kept verbatim as
        the tick-engine baseline and the oracle for differential tests."""
        claims = 0
        idle = sorted(queue.idle_jobs(), key=lambda j: j.submitted_at)
        candidates = list(self.alive_workers(now))
        for job in idle:
            if not candidates:
                break
            matched = None
            for w in candidates:
                if symmetric_match(job.ad, w.offer_ad(),
                                   job.requirements, w.start_expr):
                    matched = w
                    break
            if matched is None:
                continue
            queue.claim(job.jid, matched.name, now)
            matched.add_claim(job)
            matched.idle_since = -1.0
            claims += 1
            free = matched.free_resources()
            exhausted = any(
                isinstance(v, (int, float)) and v <= 0
                for k, v in free.items()
                if k in ("cpus", "gpus", "chips") and matched.ad.get(k)
            )
            if exhausted:
                candidates.remove(matched)
        return claims

    # -- flocking: several schedds, one pool ---------------------------------
    def negotiate_cycle(self, queues, now: float, *, accountant=None,
                        quantum: int = 1) -> int:
        """One federated matchmaking cycle over several schedds.

        `queues` is the FLOCKING ORDER — with no accountant, schedds
        drain strictly in that order (earlier submit hosts see capacity
        first, FIFO within each queue), against ONE shared free-resource
        matrix.  A single queue without an accountant is exactly
        `negotiate` — the differential tests pin that equivalence.

        With an `Accountant` (core/fairshare.py) the cycle water-fills
        capacity hierarchically, the way HTCondor's negotiator serves
        submitters: repeatedly pick the most-owed schedd (smallest
        usage/quota), then its best-priority user (smallest effective
        priority = factor × (base + decayed usage)), hand that user at
        most `quantum` claims through the vectorized matcher, charge the
        claimed cores back as virtual usage, and repeat until no
        (schedd, user) can claim anything more.  Serving the argmin and
        charging it equalizes factor×usage across users and usage/quota
        across schedds — the inverse-factor, proportional-quota split
        HTCondor documents.  `quantum` is the fairness granularity (in
        claims) traded against matcher calls per cycle: 1 is exact
        water-filling (a 48-slot pool under 3:1 quotas splits 36:12,
        ±1); coarser chunks truncate the fill ladder early and distort
        small-pool splits."""
        queues = list(queues)
        if len(queues) == 1 and accountant is None:
            return self.negotiate(queues[0], now)
        workers = self.alive_workers(now)
        if not workers:
            return 0
        free = np.stack([w.free_vec() for w in workers])
        total = 0

        if accountant is None:
            for q in queues:
                if not hasattr(q, "idle_cohorts"):
                    n = self.negotiate_scan(q, now)
                    if n:     # scan bypassed the shared matrix: rebuild
                        free = np.stack([w.free_vec() for w in workers])
                    total += n
                    continue
                cohorts = [(k, j) for k, j in q.idle_cohorts() if j]
                cohorts.sort(key=lambda kv: q.cohort_first_submit(kv[0]))
                total += self._match_cohorts(q, cohorts, workers, free,
                                             now)
            return total

        accountant.reset_cycle()
        names = [getattr(q, "name", f"schedd{i:02d}")
                 for i, q in enumerate(queues)]
        # (schedd idx, user) -> that user's idle cohorts, FIFO-sorted
        active: dict[tuple[int, str], list] = {}
        for si, q in enumerate(queues):
            by_user: dict[str, list] = {}
            for key, jobs in q.idle_cohorts():
                if not jobs:
                    continue
                rep = next(iter(jobs.values()))
                by_user.setdefault(user_of(rep), []).append((key, jobs))
            for user, cohorts in by_user.items():
                cohorts.sort(key=lambda kv: q.cohort_first_submit(kv[0]))
                active[(si, user)] = cohorts
        if not active:
            return 0

        quantum = max(1, int(quantum))
        while active:
            si = min({i for i, _ in active},
                     key=lambda i: (accountant.group_owed(names[i], now),
                                    i))
            user = min((u for i, u in active if i == si),
                       key=lambda u: (
                           accountant.effective_priority(u, now), u))
            cores = [0.0]

            def observe(job, _c=cores):
                _c[0] += job_cores(job)

            got = self._match_cohorts(
                queues[si], active[(si, user)], workers, free, now,
                budget=quantum, on_claim=observe)
            if got:
                accountant.charge_virtual(names[si], user, cores[0])
                total += got
            if got < quantum:
                # demand or matching capacity exhausted for this user —
                # neither can grow within the cycle, so retire the entry
                del active[(si, user)]
        # claims are real running-core rates now; outside-the-cycle
        # priority queries (metrics, owed-share deficits) must not see
        # stale virtual charges on top of them
        accountant.reset_cycle()
        return total

    def preview_matches(self, queues, now: float) -> list[dict]:
        """Dry-run of the next negotiation cycle: how many of each
        cohort's idle jobs CURRENT free capacity would absorb, without
        claiming anything.  Returns one {cohort_key: absorbed} dict per
        queue.  The provisioner computes deficits from the remaining
        (post-negotiation) idle cohorts, so a job about to be matched to
        existing capacity — including partial slots the old unclaimed-
        worker count missed — is not provisioned for again.

        Estimate caveat: quantity-reading START/Requirements expressions
        are evaluated against the live offer, not the virtually-drained
        one, so the preview can over-count absorption for such policies
        by at most one cohort slice per worker."""
        queues = list(queues)
        out: list[dict] = [{} for _ in queues]
        workers = self.alive_workers(now)
        if not workers:
            return out
        entries = []
        for qi, q in enumerate(queues):
            if not hasattr(q, "idle_cohorts"):
                continue          # foreign queue: no preview possible
            for key, jobs in q.idle_cohorts():
                if jobs:
                    entries.append(
                        (q.cohort_first_submit(key), qi, key, jobs))
        if not entries:
            return out
        entries.sort(key=lambda e: (e[0], e[1]))
        free = np.stack([w.free_vec() for w in workers])
        for _first, qi, key, jobs in entries:
            rep = next(iter(jobs.values()))
            want = _job_req_vec(rep)
            pos = want > 0
            if pos.any():
                fits = np.floor(
                    (free[:, pos] / want[pos]).min(axis=1) + 1e-9)
                fits = np.maximum(fits, 0.0)
            else:
                fits = np.full(len(workers), float(len(jobs)))
            if fits.sum() <= 0:
                continue
            left = len(jobs)
            absorbed = 0
            for wi, w in enumerate(workers):
                if left <= 0:
                    break
                k = int(fits[wi])
                if k <= 0:
                    continue
                if not self.cohort_match(rep, w):
                    continue
                take = min(k, left)
                free[wi] -= want * take
                absorbed += take
                left -= take
            if absorbed:
                out[qi][key] = absorbed
        return out


def advance_workers(
    collector: Collector,
    queue: JobQueue,
    cluster,
    now: float,
    dt: float,
    *,
    scan_matches: bool = False,
    exact_completions: bool = True,
) -> list[str]:
    """Advance all workers over [now, now+dt]: run claimed jobs, complete
    them AT THEIR EXACT FINISH TIME (not quantized to the interval end),
    start the idle-timeout clock, self-terminate (C2).  Returns names of
    workers that self-terminated.

    `scan_matches=True` / `exact_completions=False` together reproduce
    the seed tick loop verbatim (per-job C2 idle poll, completions
    quantized to now+dt, no mid-interval boot credit) — the tick-engine
    baseline; the defaults are the event engine's exact semantics."""
    t1 = now + dt
    terminated = []
    for w in list(collector.workers.values()):
        if exact_completions:
            if w.terminated or w.booted_at < 0 or w.booted_at >= t1:
                continue
            seg0 = max(now, w.booted_at)
            seg = t1 - seg0
            if seg <= 0:
                continue
        else:                      # seed: whole ticks, gated at tick start
            if w.terminated or not w.ready(now):
                continue
            seg0, seg = now, dt
        w.alive_s += seg
        idle_from = seg0         # idleness cannot predate the boot
        if w.claimed:
            busy_until = seg0
            for jid, job in list(w.claimed.items()):
                if job.work_fn is not None:
                    done = job.work_fn(job, seg)
                    t_done = t1
                elif exact_completions:
                    rate = w.work_rate
                    need = (job.remaining_s / rate if rate > 0
                            else float("inf"))
                    if need <= seg + 1e-9:
                        job.remaining_s = 0.0
                        done = True
                        t_done = min(seg0 + need, t1)
                    else:
                        job.remaining_s -= seg * rate
                        done = False
                        t_done = t1
                else:               # seed: progress and finish in dt units
                    job.remaining_s -= dt * w.work_rate
                    done = job.remaining_s <= 1e-9
                    t_done = t1
                if done:
                    # route to the owning schedd: under flocking, one
                    # worker serves jobs from several queues (`queue`
                    # here may be a FlockedQueues view)
                    (job.schedd or queue).complete(jid, t_done)
                    w.drop_claim(jid)
                busy_until = max(busy_until, t_done)
            w.busy_s += (busy_until - seg0 if exact_completions else dt)
            if not w.claimed and exact_completions:
                idle_from = busy_until   # idle clock starts at the EXACT
                #                          last-completion time, not the
                #                          segment start
        if w.claimed:
            w.idle_since = -1.0
            continue
        # idle: does any matching idle job exist? (C2 poll)
        if scan_matches:
            has_match = any(
                symmetric_match(j.ad, w.offer_ad(), j.requirements,
                                w.start_expr)
                for j in queue.idle_jobs()
            )
        else:
            has_match = collector.any_cohort_matches(w, queue)
        if has_match:
            w.idle_since = -1.0  # negotiator will claim next cycle
            continue
        if w.idle_since < 0:
            w.idle_since = idle_from
        elif t1 - w.idle_since >= w.idle_timeout:
            w.terminated = True
            terminated.append(w.name)
            collector.invalidate(w.name)
            if w.pod_name is not None and cluster is not None:
                cluster.succeed_pod(w.pod_name, t1)
    return terminated


def kill_worker(collector: Collector, queue: JobQueue, worker_name: str,
                now: float):
    """Pod/node preemption path (§5): release claimed jobs back to IDLE;
    HTCondor reschedules them transparently."""
    w = collector.workers.get(worker_name)
    if w is None:
        return
    for jid, job in list(w.claimed.items()):
        (job.schedd or queue).release(jid, now, preempted=True)
    w.clear_claims()
    w.terminated = True
    collector.invalidate(worker_name)
