"""HTCondor execute side: startd workers + the collector/negotiator.

A Worker is the HTCondor execute service living inside a Kubernetes pod.
Lifecycle (paper §2):

  pod PENDING -> pod RUNNING -> startd boots (startup_delay) -> advertises
  to the collector -> claims matching idle jobs (START expr, pushed down
  from the provisioner per C3) -> runs them -> when no matching idle job
  exists for `idle_timeout` seconds, SELF-TERMINATES (C2) -> pod succeeds.

Partitionable-slot semantics: a worker claims as many jobs as fit its
resources simultaneously (cpus/gpus/chips), like a partitionable startd
slot — one pod can serve several 1-GPU jobs on an 8-GPU request.

The collector is the pool registry; `negotiate()` is a single matchmaking
cycle pairing idle jobs with unclaimed worker capacity (symmetric_match:
job.Requirements against the worker ad AND the worker START against the
job ad).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

from repro.core.classad import ClassAdExpr, symmetric_match
from repro.core.jobqueue import Job, JobQueue, JobState


@dataclasses.dataclass
class Worker:
    name: str
    ad: dict[str, Any]                       # resources + advertised attrs
    start_expr: ClassAdExpr                  # pushed-down filter (C3)
    idle_timeout: float = 300.0
    startup_delay: float = 30.0
    pod_name: str | None = None
    work_rate: float = 1.0          # <1.0 models a straggling node

    booted_at: float = -1.0                  # when startd became ready
    idle_since: float = -1.0
    claimed: dict[int, Job] = dataclasses.field(default_factory=dict)
    terminated: bool = False
    # accounting
    busy_s: float = 0.0
    alive_s: float = 0.0

    def ready(self, now: float) -> bool:
        return self.booted_at >= 0 and now >= self.booted_at and not self.terminated

    def free_resources(self) -> dict[str, float]:
        free = dict(self.ad)
        for job in self.claimed.values():
            for res in ("cpus", "gpus", "memory", "disk", "chips", "hbm_gb"):
                want = job.ad.get(f"request_{res}", 0) or 0
                if res in free and isinstance(free[res], (int, float)):
                    free[res] = free[res] - want
        return free

    def offer_ad(self) -> dict[str, Any]:
        """Current (partial-slot) offer: remaining resources + attrs."""
        return self.free_resources()


class Collector:
    """Pool registry + negotiator."""

    def __init__(self):
        self.workers: dict[str, Worker] = {}
        self._ids = itertools.count()

    def advertise(self, worker: Worker):
        self.workers[worker.name] = worker

    def invalidate(self, name: str):
        self.workers.pop(name, None)

    def alive_workers(self, now: float) -> list[Worker]:
        return [w for w in self.workers.values() if w.ready(now)]

    def unclaimed_capacity(self, group_matcher=None) -> int:
        """Workers with zero claims (counted by the provisioner against the
        deficit so it never over-submits; paper §2)."""
        n = 0
        for w in self.workers.values():
            if w.terminated or w.claimed:
                continue
            if group_matcher is None or group_matcher(w.ad):
                n += 1
        return n

    def negotiate(self, queue: JobQueue, now: float) -> int:
        """One matchmaking cycle. Returns number of new claims.

        Workers with no free capacity drop out of the candidate list as
        they fill — keeps a full-pool cycle O(idle × free_workers)."""
        claims = 0
        idle = sorted(queue.idle_jobs(), key=lambda j: j.submitted_at)
        candidates = list(self.alive_workers(now))
        for job in idle:
            if not candidates:
                break
            matched = None
            for w in candidates:
                if symmetric_match(job.ad, w.offer_ad(),
                                   job.requirements, w.start_expr):
                    matched = w
                    break
            if matched is None:
                continue
            queue.claim(job.jid, matched.name, now)
            matched.claimed[job.jid] = job
            matched.idle_since = -1.0
            claims += 1
            free = matched.free_resources()
            exhausted = any(
                isinstance(v, (int, float)) and v <= 0
                for k, v in free.items()
                if k in ("cpus", "gpus", "chips") and matched.ad.get(k)
            )
            if exhausted:
                candidates.remove(matched)
        return claims


def advance_workers(
    collector: Collector,
    queue: JobQueue,
    cluster,
    now: float,
    dt: float,
) -> list[str]:
    """Advance all workers by dt: run claimed jobs, complete them, start the
    idle-timeout clock, self-terminate (C2).  Returns names of workers that
    self-terminated this tick."""
    terminated = []
    for w in list(collector.workers.values()):
        if w.terminated:
            continue
        if not w.ready(now):
            continue
        w.alive_s += dt
        if w.claimed:
            w.busy_s += dt
        # advance claimed jobs
        for jid, job in list(w.claimed.items()):
            if job.work_fn is not None:
                done = job.work_fn(job, dt)
            else:
                job.remaining_s -= dt * w.work_rate
                done = job.remaining_s <= 1e-9
            if done:
                queue.complete(jid, now + dt)
                w.claimed.pop(jid)
        if w.claimed:
            w.idle_since = -1.0
            continue
        # idle: does any matching idle job exist? (C2 poll)
        has_match = any(
            symmetric_match(j.ad, w.offer_ad(), j.requirements, w.start_expr)
            for j in queue.idle_jobs()
        )
        if has_match:
            w.idle_since = -1.0  # negotiator will claim next cycle
            continue
        if w.idle_since < 0:
            w.idle_since = now
        elif now + dt - w.idle_since >= w.idle_timeout:
            w.terminated = True
            terminated.append(w.name)
            collector.invalidate(w.name)
            if w.pod_name is not None and cluster is not None:
                cluster.succeed_pod(w.pod_name, now + dt)
    return terminated


def kill_worker(collector: Collector, queue: JobQueue, worker_name: str,
                now: float):
    """Pod/node preemption path (§5): release claimed jobs back to IDLE;
    HTCondor reschedules them transparently."""
    w = collector.workers.get(worker_name)
    if w is None:
        return
    for jid in list(w.claimed):
        queue.release(jid, now, preempted=True)
    w.claimed.clear()
    w.terminated = True
    collector.invalidate(worker_name)
