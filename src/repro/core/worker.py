"""HTCondor execute side: startd workers + the collector/negotiator.

A Worker is the HTCondor execute service living inside a Kubernetes pod.
Lifecycle (paper §2):

  pod PENDING -> pod RUNNING -> startd boots (startup_delay) -> advertises
  to the collector -> claims matching idle jobs (START expr, pushed down
  from the provisioner per C3) -> runs them -> when no matching idle job
  exists for `idle_timeout` seconds, SELF-TERMINATES (C2) -> pod succeeds.

Partitionable-slot semantics: a worker claims as many jobs as fit its
resources simultaneously (cpus/gpus/chips), like a partitionable startd
slot — one pod can serve several 1-GPU jobs on an 8-GPU request.

The collector is the pool registry; `run_cycle()` is a single
matchmaking cycle pairing idle jobs with unclaimed worker capacity
(symmetric_match: job.Requirements against the worker ad AND the worker
START against the job ad).

Negotiation architecture (core/matchmaker/): the cycle splits into a
*pure* array core and the stateful glue that stays here.

  * `Collector._build_problem` turns live queues + workers into a
    `MatchProblem` — request/demand/free matrices plus a (cohort ×
    worker) compatibility mask evaluated ONCE per (cohort, slot shape)
    through the bounded LRU memo (`cohort_match` semantics: the mask
    holds full-ad verdicts, and the matchmakers' fits>0 gate supplies
    the live-offer quantity check, so the pair is equivalent to
    evaluating each shrinking offer for quantity-blind expressions).
  * a swappable `Matchmaker` backend solves it — "numpy" (the legacy
    vectorized loop, reference), "jax" (jitted XLA water-fill), "scan"
    (the seed's per-job oracle) — selected via
    `Collector(matchmaker=...)` / `Simulation(matchmaker=...)` / the
    `[provision] matchmaker=` INI key.
  * `Collector._apply_plan` turns the plan back into state: queue
    claims, worker claim vectors, fair-share charges.

Expressions that READ offered quantities (e.g. ``gpus >= 2``) cannot be
block-evaluated once per cycle; cycles containing any such cohort or
worker fall back to the legacy per-claim path (`_match_cohorts`), which
re-evaluates against every shrinking offer — exactness over speed.

Flocking (multi-schedd): `run_cycle(queues, ...)` runs ONE matchmaking
cycle over an ordered list of schedd queues feeding the same pool —
capacity drains through a shared free matrix, plain mode serves queues
strictly in flocking order, and with a fair-share `Accountant`
(core/fairshare.py) the cycle water-fills capacity by per-schedd quota
and per-user effective priority in quantum-sized `match(budget=...)`
slices.  `preview()` is the claim-free dry run the provisioner
subtracts from idle counts so it never provisions for jobs the next
cycle will match anyway.  `negotiate`, `negotiate_scan`, and
`preview_matches` remain as deprecated shims over the new entry points.
"""
from __future__ import annotations

import bisect
import dataclasses
import itertools
import warnings
from collections import OrderedDict
from typing import Any, Callable

import numpy as np

from repro.core.classad import ClassAdExpr, symmetric_match
from repro.core.fairshare import job_cores
from repro.core.jobqueue import (
    Job, JobQueue, JobState, canonical_ad, user_of,
)
from repro.core.matchmaker import (
    MatchPlan, MatchProblem, Matchmaker, cohort_fits, make_matchmaker,
)
from repro.core.matchmaker.base import (
    CycleDelta, match_cycles, sequential_preview_many,
)
from repro.core.matchmaker.base import RESOURCE_KEYS  # noqa: F401
from repro.observability import as_telemetry
#   (re-exported: RESOURCE_KEYS moved to matchmaker.base with the
#   protocol split; long-standing importers keep working)

# offer-ad attributes whose values shrink as a slot fills; expressions
# reading them cannot be block-evaluated once per negotiation cycle
_QUANTITY_ATTRS = frozenset(RESOURCE_KEYS)


def _num(v: Any) -> float:
    return float(v) if isinstance(v, (int, float)) else 0.0


def _job_req_vec(job: Job) -> np.ndarray:
    """Job request over RESOURCE_KEYS, cached on the job (ads are fixed)."""
    v = getattr(job, "_req_vec", None)
    if v is None:
        v = np.array([_num(job.ad.get(f"request_{r}"))
                      for r in RESOURCE_KEYS], dtype=np.float64)
        job._req_vec = v
    return v


class LRUCache:
    """Bounded memo with least-recently-used eviction.

    The collector's ClassAd-eval memos used to reset wholesale when
    full; week-long streaming replays with churning cohorts now evict
    one cold entry at a time instead, and `invalidate` drops entries
    selectively (e.g. every verdict involving one cohort)."""

    def __init__(self, maxsize: int):
        self.maxsize = int(maxsize)
        self._d: OrderedDict = OrderedDict()
        # effectiveness stats, surfaced as repro_classad_cache_* gauges
        # by the telemetry collect hook
        self.hits = 0
        self.misses = 0

    def get(self, key, default=None):
        try:
            value = self._d[key]
        except KeyError:
            self.misses += 1
            return default
        self.hits += 1
        self._d.move_to_end(key)
        return value

    def put(self, key, value):
        d = self._d
        if key in d:
            d.move_to_end(key)
        d[key] = value
        if len(d) > self.maxsize:
            d.popitem(last=False)

    def invalidate(self, match: Callable[[Any], bool] | None = None) -> int:
        """Drop entries whose key satisfies `match` (all, when None).
        Returns how many were dropped."""
        if match is None:
            n = len(self._d)
            self._d.clear()
            return n
        stale = [k for k in self._d if match(k)]
        for k in stale:
            del self._d[k]
        return len(stale)

    def clear(self):
        self._d.clear()

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key) -> bool:
        return key in self._d


@dataclasses.dataclass
class Worker:
    name: str
    ad: dict[str, Any]                       # resources + advertised attrs
    start_expr: ClassAdExpr                  # pushed-down filter (C3)
    idle_timeout: float = 300.0
    startup_delay: float = 30.0
    pod_name: str | None = None
    work_rate: float = 1.0          # <1.0 models a straggling node
    backend: str | None = None      # owning ScalingBackend (span labels)

    booted_at: float = -1.0                  # when startd became ready
    idle_since: float = -1.0
    claimed: dict[int, Job] = dataclasses.field(default_factory=dict)
    terminated: bool = False
    # a draining worker (its backend is being detached) takes NO new
    # claims — the negotiator/preview skip it via alive_workers — and
    # self-terminates as soon as its current claims complete
    draining: bool = False
    # accounting
    busy_s: float = 0.0
    alive_s: float = 0.0
    _match_key: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _res_vec: Any = dataclasses.field(default=None, repr=False,
                                      compare=False)
    _used_vec: Any = dataclasses.field(default=None, repr=False,
                                       compare=False)
    #: claim-set revision — bumped on every add/drop/clear, so "has this
    #: worker's free capacity changed?" is an int compare instead of a
    #: vector rebuild + hash (provisioner preview memo, collector
    #: staging fingerprint)
    free_rev: int = dataclasses.field(default=0, repr=False, compare=False)
    _free_digest: Any = dataclasses.field(default=None, repr=False,
                                          compare=False)

    def ready(self, now: float) -> bool:
        return self.booted_at >= 0 and now >= self.booted_at and not self.terminated

    # -- incremental resource vectors (hot path of the negotiator) -----------
    def res_vec(self) -> np.ndarray:
        if self._res_vec is None:
            self._res_vec = np.array(
                [_num(self.ad.get(r)) for r in RESOURCE_KEYS],
                dtype=np.float64)
        return self._res_vec

    def free_vec(self) -> np.ndarray:
        if self._used_vec is None:
            return self.res_vec().copy()
        return self.res_vec() - self._used_vec

    def add_claim(self, job: Job):
        self.claimed[job.jid] = job
        if self._used_vec is None:
            self._used_vec = np.zeros(len(RESOURCE_KEYS), dtype=np.float64)
        self._used_vec += _job_req_vec(job)
        self.free_rev += 1

    def drop_claim(self, jid: int) -> Job | None:
        job = self.claimed.pop(jid, None)
        if job is not None and self._used_vec is not None:
            self._used_vec -= _job_req_vec(job)
            self.free_rev += 1
        return job

    def clear_claims(self):
        self.claimed.clear()
        self._used_vec = None
        self.free_rev += 1

    def free_digest(self) -> bytes:
        """Byte digest of the free-capacity vector, recomputed only when
        the claim set changed (`free_rev` dirty flag) — the provisioner
        polls this every reconcile for every worker, and an unchanged
        pool must cost an int compare per worker, not a vector rebuild."""
        cached = self._free_digest
        if cached is not None and cached[0] == self.free_rev:
            return cached[1]
        digest = self.free_vec().tobytes()
        self._free_digest = (self.free_rev, digest)
        return digest

    def free_resources(self) -> dict[str, float]:
        free = dict(self.ad)
        for job in self.claimed.values():
            for res in RESOURCE_KEYS:
                want = job.ad.get(f"request_{res}", 0) or 0
                if res in free and isinstance(free[res], (int, float)):
                    free[res] = free[res] - want
        return free

    def offer_ad(self) -> dict[str, Any]:
        """Current (partial-slot) offer: remaining resources + attrs."""
        return self.free_resources()

    def match_key(self) -> tuple:
        """Matchmaking-equivalence key of the FULL slot (ads are fixed at
        provisioning time, so this is computed once).  Uses the same ad
        canonicalization as the job-side cohort_key_of — the two halves
        jointly key the collector's match cache."""
        if self._match_key is None:
            self._match_key = (self.start_expr.src, canonical_ad(self.ad))
        return self._match_key


# -- worker (de)serialization -------------------------------------------------
def worker_state(w: Worker) -> dict:
    """JSON-safe snapshot: the START expression serializes as source
    text, claims as an ORDERED jid list (the claim dict's iteration
    order feeds completion order for same-instant finishes).  The cached
    resource vectors are NOT serialized — `worker_from_state` rebuilds
    `_used_vec` through `add_claim`, summing the same small integral
    requests, so the float result is identical."""
    return {
        "name": w.name,
        "ad": dict(w.ad),
        "start_src": w.start_expr.src,
        "idle_timeout": float(w.idle_timeout),
        "startup_delay": float(w.startup_delay),
        "pod_name": w.pod_name,
        "work_rate": w.work_rate,
        "backend": w.backend,
        "booted_at": w.booted_at,
        "idle_since": w.idle_since,
        "terminated": w.terminated,
        "draining": w.draining,
        "busy_s": w.busy_s,
        "alive_s": w.alive_s,
        "claimed": list(w.claimed.keys()),
    }


def worker_from_state(state: dict, jobs_by_jid: dict[int, Job]) -> Worker:
    w = Worker(
        name=state["name"],
        ad=dict(state["ad"]),
        start_expr=ClassAdExpr(state["start_src"]),
        idle_timeout=float(state.get("idle_timeout", 300.0)),
        startup_delay=float(state.get("startup_delay", 30.0)),
        pod_name=state.get("pod_name"),
        work_rate=float(state.get("work_rate", 1.0)),
        backend=state.get("backend"),
    )
    w.booted_at = float(state.get("booted_at", -1.0))
    w.idle_since = float(state.get("idle_since", -1.0))
    w.terminated = bool(state.get("terminated", False))
    w.draining = bool(state.get("draining", False))
    w.busy_s = float(state.get("busy_s", 0.0))
    w.alive_s = float(state.get("alive_s", 0.0))
    for jid in state.get("claimed", []):
        w.add_claim(jobs_by_jid[int(jid)])
    return w


class Collector:
    """Pool registry + negotiator."""

    MATCH_CACHE_MAX = 100_000    # LRU entries (per-cohort×shape verdicts)

    def __init__(self, matchmaker: str | Matchmaker | None = None, *,
                 negotiation_batch: int = 1, telemetry=None):
        self.workers: dict[str, Worker] = {}
        self._ids = itertools.count()
        self.matchmaker: Matchmaker = make_matchmaker(matchmaker)
        # a pool matchmaker serves previews from the first reconcile on;
        # backends that can pre-compile their canonical preview bucket
        # (jax's 512-lane floor) do it here, at pool startup, instead of
        # inside the first reconcile's preview wall
        warm = getattr(self.matchmaker, "warm_preview", None)
        if warm is not None:
            warm()
        self._scan_oracle: Matchmaker = make_matchmaker("scan")
        # telemetry: the registry half is always live (the introspection
        # counters below moved into it and tests/benchmarks read them);
        # the wall-clock profiler is None unless telemetry is enabled,
        # and every timing site guards on that
        self.telemetry = as_telemetry(telemetry)
        self.profiler = self.telemetry.profiler
        # (job cohort, worker slot shape) -> bool; symmetric_match is a
        # pure function of the two ads, so entries never go stale on
        # their own — the LRU bound handles cohort churn, and
        # `invalidate_cohort` handles callers that mutate ads in place
        self._match_cache = LRUCache(self.MATCH_CACHE_MAX)
        # C2 idle-poll verdicts per SLOT SHAPE: {match_key: (idle-cohort
        # version, any-match verdict)} — valid until the idle-cohort SET
        # changes; a pool of identical idle workers polls once per
        # version, not once per worker per event
        self._poll_cache = LRUCache(self.MATCH_CACHE_MAX)
        # -- fused negotiation staging (stage_cycle / flush_staged) ----------
        #: how many consecutive cycles to accumulate before flushing
        #: through the backend's fused multi-cycle jit (1 = stage
        #: nothing, every cycle runs immediately)
        self.negotiation_batch = max(1, int(negotiation_batch))
        self._staged_times: list[float] = []
        self._staged_queues: list | None = None
        self._staged_fp: tuple | None = None
        # introspection counters, now registry families (tests + bench
        # read them through the compat properties below)
        reg = self.telemetry.registry
        self._c_fused_batches = reg.counter(
            "repro_fused_batches_total",
            "Staged batches run through the fused multi-cycle jit")
        self._c_fused_cycles = reg.counter(
            "repro_fused_cycles_total",
            "Negotiation cycles covered by fused batches")
        self._c_fallbacks = reg.counter(
            "repro_fused_fallbacks_total",
            "Staged batches replayed sequentially, by reason", ("reason",))
        self._c_noop_hits = reg.counter(
            "repro_noop_memo_hits_total",
            "Negotiation cycles skipped by the no-op memo")
        self._c_preview_legacy = reg.counter(
            "repro_preview_legacy_total",
            "Previews forced onto the legacy live-offer walk by "
            "quantity-reading expressions (estimate, not exact — see "
            "Collector.preview)")
        self._noop_memo: tuple | None = None
        # -- live-fusion advancement hook (backlog-driven batching) ----------
        #: when set (the event engine installs `Simulation.
        #: _advance_unchecked`), `flush_staged` interleaves worker
        #: advancement with the staged cycles: before applying the plan
        #: (or replaying the fallback cycle) for staged time t, the pool
        #: is advanced to t — exactly the pre-event advancement the
        #: deferred cycles skipped.  None (the default) keeps the
        #: pre-advanced bench/replay semantics: flushes assume the
        #: caller already advanced the pool past the staged window.
        self.advance_hook = None

    # compat properties over the registry families — the pre-registry
    # int attributes these replaced are part of the test/bench surface
    @property
    def fused_batches(self) -> int:
        return int(self._c_fused_batches.value)

    @property
    def fused_cycles(self) -> int:
        return int(self._c_fused_cycles.value)

    @property
    def staged_fallbacks(self) -> int:
        return int(sum(c.value
                       for c in self._c_fallbacks.children.values()))

    @property
    def noop_hits(self) -> int:
        return int(self._c_noop_hits.value)

    @property
    def preview_legacy(self) -> int:
        return int(self._c_preview_legacy.value)

    def advertise(self, worker: Worker):
        self.workers[worker.name] = worker

    def invalidate(self, name: str):
        self.workers.pop(name, None)

    def invalidate_cohort(self, cohort_key=None) -> int:
        """Explicitly drop memoized ClassAd verdicts: all of them, or
        only entries involving `cohort_key`.  Call on a cohort-version
        bump whose ads were mutated in place (the caches are otherwise
        pure and only ever LRU-evicted).  Returns entries dropped."""
        if cohort_key is None:
            n = self._match_cache.invalidate()
        else:
            n = self._match_cache.invalidate(
                lambda k: k[0] == cohort_key)
        # poll verdicts aggregate over cohorts; any cohort change can
        # flip them regardless of the idle_version guard
        self._poll_cache.invalidate()
        return n

    def alive_workers(self, now: float) -> list[Worker]:
        return [w for w in self.workers.values()
                if w.ready(now) and not w.draining]

    def unclaimed_capacity(self, group_matcher=None) -> int:
        """Workers with zero claims (counted by the provisioner against the
        deficit so it never over-submits; paper §2)."""
        n = 0
        for w in self.workers.values():
            if w.terminated or w.draining or w.claimed:
                continue
            if group_matcher is None or group_matcher(w.ad):
                n += 1
        return n

    # -- cohort-level matchmaking -------------------------------------------
    def cohort_match(self, rep: Job, worker: Worker) -> bool:
        """Would `worker`'s slot match this cohort? Evaluated against the
        live offer for partially-claimed workers; memoized for unclaimed
        ones (offer == full ad)."""
        if worker.claimed:
            return symmetric_match(rep.ad, worker.offer_ad(),
                                   rep.requirements, worker.start_expr)
        return self._shape_match(rep, worker)

    def _shape_match(self, rep: Job, worker: Worker) -> bool:
        """Memoized FULL-AD verdict for (cohort, slot shape) — the
        compatibility-mask entry.  Combined with the matchmakers'
        fits>0 gate this equals the live-offer verdict whenever the
        expressions are quantity-blind (the only cycles routed to the
        array backends)."""
        key = (rep.cohort_key, worker.match_key())
        hit = self._match_cache.get(key)
        if hit is None:
            hit = symmetric_match(rep.ad, worker.ad, rep.requirements,
                                  worker.start_expr)
            self._match_cache.put(key, hit)
        return hit

    def any_cohort_matches(self, worker: Worker, queue: JobQueue) -> bool:
        """C2 idle poll: does ANY idle job match this worker? One check
        per cohort, cache-hit for the common (idle worker) case.

        For an UNCLAIMED worker the verdict is a pure function of (slot
        shape, idle-cohort set) — matching uses the full slot ad — so it
        is cached per `worker.match_key()` against `queue.idle_version`:
        however many identical workers sit idle, each cohort-set change
        costs ONE rescan per distinct slot shape, and every other poll
        is a dict hit."""
        version = getattr(queue, "idle_version", None)
        cacheable = version is not None and not worker.claimed
        if cacheable:
            cached = self._poll_cache.get(worker.match_key())
            if cached is not None and cached[0] == version:
                return cached[1]
        hit = False
        for _key, jobs in queue.idle_cohorts():
            rep = next(iter(jobs.values()))
            if self.cohort_match(rep, worker):
                hit = True
                break
        if cacheable:
            self._poll_cache.put(worker.match_key(), (version, hit))
        return hit

    # -- problem building / plan application (the stateful half) -------------
    def _quantity_sensitive(self, reps, workers) -> bool:
        """Any expression in the cycle reading offered quantities forces
        the legacy per-claim path — block evaluation would miss the
        shrinking-offer rechecks."""
        for w in workers:
            qs = w.__dict__.get("_qsens")
            if qs is None:
                qs = bool(w.start_expr.refs & _QUANTITY_ATTRS)
                w._qsens = qs
            if qs:
                return True
        for rep in reps:
            req = rep.requirements
            if req is not None and (req.refs & _QUANTITY_ATTRS):
                return True
        return False

    def _build_problem(self, rows, workers, *,
                       scan_jobs=None) -> MatchProblem:
        """Assemble the pure arrays from live state.  `rows` is the
        cohort list [(queue idx, cohort key, jobs dict), ...] ALREADY in
        processing order; the compat mask is evaluated once per
        (cohort, distinct slot shape) through the LRU memo, then
        broadcast to worker columns."""
        C, W = len(rows), len(workers)
        R = len(RESOURCE_KEYS)
        keys = []
        reps = []
        requests = np.zeros((C, R), dtype=np.float64)
        demand = np.zeros(C, dtype=np.int64)
        for c, (qi, key, jobs) in enumerate(rows):
            rep = next(iter(jobs.values()))
            keys.append((qi, key))
            reps.append(rep)
            requests[c] = _job_req_vec(rep)
            demand[c] = len(jobs)
        free = np.stack([w.free_vec() for w in workers])
        capacity = np.stack([w.res_vec() for w in workers])
        # distinct slot shapes -> one expression eval per (cohort, shape)
        shape_of = np.zeros(W, dtype=np.int64)
        shape_reps: list[Worker] = []
        shape_idx: dict = {}
        for wi, w in enumerate(workers):
            mk = w.match_key()
            si = shape_idx.get(mk)
            if si is None:
                si = shape_idx[mk] = len(shape_reps)
                shape_reps.append(w)
            shape_of[wi] = si
        compat_s = np.zeros((C, len(shape_reps)), dtype=bool)
        for c, rep in enumerate(reps):
            for si, w in enumerate(shape_reps):
                compat_s[c, si] = self._shape_match(rep, w)
        scan_order = None
        if scan_jobs is not None:
            row_of = {key: c for c, (_qi, key, _j) in enumerate(rows)}
            scan_order = np.array(
                [row_of[j.cohort_key] for j in scan_jobs], dtype=np.int64)
        return MatchProblem(
            keys=keys, requests=requests, demand=demand,
            order=np.arange(C, dtype=np.int64), free=free,
            capacity=capacity, compat=compat_s[:, shape_of],
            scan_order=scan_order)

    def _apply_plan(self, queues, problem: MatchProblem, plan: MatchPlan,
                    workers, now: float, *, on_claim=None) -> int:
        """Turn a pure plan into state: claim each cohort's FIFO jobs to
        its workers in index order.  Free capacity only shrinks within a
        cycle, so a cohort's first-fit worker index is non-decreasing —
        dealing FIFO jobs to index-ordered workers reproduces the exact
        (job, worker) pairs of the legacy claiming walks."""
        claims = 0
        takes = plan.takes
        for c in problem.order:
            row = takes[c]
            total = int(row.sum())
            if total <= 0:
                continue
            qi, key = problem.keys[c]
            q = queues[qi]
            pending = q.cohort_jobs_sorted(key, total)
            ji = 0
            for wi in np.nonzero(row)[0]:
                w = workers[wi]
                for job in pending[ji:ji + int(row[wi])]:
                    q.claim(job.jid, w.name, now)
                    w.add_claim(job)
                    if on_claim is not None:
                        on_claim(job)
                    ji += 1
                w.idle_since = -1.0
            claims += ji
        return claims

    # -- negotiation entry points (the Matchmaker-backed API) ----------------
    def run_cycle(self, queues, now: float, *, accountant=None,
                  quantum: int = 1, max_submit: float | None = None) -> int:
        """One matchmaking cycle; THE canonical negotiation entry point.

        `queues` is a single schedd queue or the flocking-ordered list of
        them.  Without an accountant, queues drain strictly in that
        order (FIFO cohorts within each) against one shared free matrix;
        with an `Accountant` the cycle water-fills hierarchically — most
        owed schedd, then best-priority user, `quantum` claims per slice
        (see core/fairshare.py).  `max_submit` restricts the plain path
        to jobs submitted at or before that time (replay drivers hand
        pre-loaded queues cycle timestamps).  Returns new claims."""
        if hasattr(queues, "claim"):
            queues = [queues]
        else:
            queues = list(queues)
        if accountant is None:
            return self._plain_cycle(queues, now, max_submit=max_submit)
        if max_submit is not None:
            raise ValueError("max_submit is a plain-cycle knob; "
                             "fair-share cycles see the live queue")
        return self._fairshare_cycle(queues, now, accountant, quantum)

    def negotiate_cycle(self, queues, now: float, *, accountant=None,
                        quantum: int = 1) -> int:
        """Alias of `run_cycle` (the pre-protocol flocking name)."""
        return self.run_cycle(queues, now, accountant=accountant,
                              quantum=quantum)

    # -- fused multi-cycle negotiation (staging buffer -> fused jit) ----------
    def _pool_fingerprint(self, now: float) -> tuple:
        """(name, free_rev) of every alive worker — two equal
        fingerprints mean no worker joined, left, booted, drained, or
        changed a claim in between, so staged cycles only differ by job
        arrivals and are fusable."""
        return tuple((w.name, w.free_rev) for w in self.alive_workers(now))

    def stage_cycle(self, queues, now: float) -> int:
        """Stage one plain negotiation cycle at time `now` instead of
        running it; once `negotiation_batch` cycles are staged (or on
        `quiesce()`), the whole batch flushes through the matchmaker's
        fused multi-cycle path in ONE device dispatch.  Returns claims
        made by any flush this call triggered (0 while the batch is
        still filling).

        Only pools the fused jit can serve are staged at all: foreign
        queues, quantity-reading expressions, and fair-share cycles run
        immediately (fair-share goes through `run_cycle` as before).
        Claims land with the STAGED cycle's timestamp, and the flush is
        claim-for-claim identical to running each cycle at its staged
        time — `flush_staged` falls back to a sequential time-cutoff
        replay whenever fusion can't prove that."""
        if hasattr(queues, "claim"):
            queues = [queues]
        else:
            queues = list(queues)
        if (self.negotiation_batch <= 1
                or any(not hasattr(q, "idle_cohorts") for q in queues)):
            return self._plain_cycle(queues, now)
        claims = 0
        if self._staged_times and self._staged_queues != queues:
            claims += self.flush_staged()
        if not self._staged_times:
            self._staged_queues = queues
            self._staged_fp = self._pool_fingerprint(now)
        self._staged_times.append(now)
        if len(self._staged_times) >= self.negotiation_batch:
            claims += self.flush_staged()
        return claims

    def quiesce(self) -> int:
        """Flush any staged cycles NOW.  Every external operation that
        observes or mutates pool state mid-stream (snapshot, backend
        attach/drain, schedd add/drain, flocking-order change) must call
        this first — staged-but-unflushed negotiation is invisible to
        them.  Returns claims made by the flush."""
        return self.flush_staged()

    def flush_staged(self) -> int:
        """Run every staged cycle.  The fused path builds ONE problem
        from the current idle cohorts, splits each cohort's demand into
        per-cycle arrival deltas on the jobs' submit times, and hands the
        K-cycle batch to `match_cycles` — device state stays resident
        across the K cycles and the K plans apply back in staged order
        with their staged timestamps.  Falls back to a sequential
        time-cutoff replay (bit-identical by construction) when the
        batch is not provably fusable: a single staged cycle, workers
        changed mid-batch, quantity-reading expressions, or a cohort
        that fully drains mid-batch and re-arrives (its cross-cohort
        FIFO key would re-seed — see jobqueue._cohort_min)."""
        if not self._staged_times:
            return 0
        times = self._staged_times
        queues = self._staged_queues
        fp0 = self._staged_fp
        self._staged_times = []
        self._staged_queues = None
        self._staged_fp = None

        prof = self.profiler
        t_f0 = prof.now() if prof is not None else 0.0
        workers = self.alive_workers(times[-1])
        rows = deltas = None
        t_m0 = t_a0 = t_f0
        # fallback chain, first failing condition names the reason (the
        # repro_fused_fallbacks_total{reason} series — the profiler's
        # answer to "why didn't this batch fuse?")
        reason = None
        if len(times) < 2:
            reason = "single_cycle"
        elif not workers:
            reason = "no_workers"
        elif self._pool_fingerprint(times[-1]) != fp0:
            reason = "pool_changed"
        if reason is None:
            rows, deltas = self._staged_rows(queues, times)
            if rows is None:
                reason = "no_rows"
        if reason is None:
            reps = [next(iter(j.values())) for _qi, _k, j in rows]
            if self._quantity_sensitive(reps, workers):
                reason = "quantity_exprs"
        if reason is None:
            problem = self._build_problem(rows, workers)
            problem.demand = np.zeros_like(problem.demand)
            t_m0 = prof.now() if prof is not None else 0.0
            plans = match_cycles(self.matchmaker, problem, deltas)
            t_a0 = prof.now() if prof is not None else 0.0
            if self._reseed_hazard(plans, deltas):
                reason = "reseed_hazard"
        if (reason is None and self.advance_hook is not None
                and self._advance_hazard(queues, problem, plans,
                                         workers, times)):
            reason = "completion_hazard"
        hook = self.advance_hook
        if reason is not None:
            self._c_fallbacks.labels(reason).value += 1
            claims = 0
            for t in times:
                if hook is not None:
                    hook(t)
                claims += self._plain_cycle(queues, t, max_submit=t)
            return claims
        self._c_fused_batches.value += 1
        self._c_fused_cycles.value += len(times)
        claims = 0
        for t, plan in zip(times, plans):
            if hook is not None:
                hook(t)
            claims += self._apply_plan(queues, problem, plan, workers, t)
        if prof is not None:
            lc = getattr(self.matchmaker, "last_call", None)
            prof.record_cycle(
                t=times[-1], kind="fused", w_start=t_f0,
                build_s=t_m0 - t_f0, match_s=t_a0 - t_m0,
                apply_s=prof.now() - t_a0, claims=claims,
                backend=getattr(self.matchmaker, "name", ""),
                compiled=None if lc is None else lc.get("compiled"),
                fused_k=len(times))
        return claims

    def _staged_rows(self, queues, times):
        """Union cohort rows (cross-queue FIFO order, as `_plain_cycle`
        sorts them) plus per-cycle arrival deltas: a job submitted at s
        first becomes visible to the earliest staged cycle with
        `times[k] >= s`; jobs submitted after `times[-1]` are invisible
        to the whole batch."""
        entries = []
        for qi, q in enumerate(queues):
            for key, jobs in q.idle_cohorts():
                if jobs:
                    entries.append(
                        (q.cohort_first_submit(key), qi, key, jobs))
        if not entries:
            return None, None
        entries.sort(key=lambda e: (e[0], e[1]))
        rows = [(qi, key, jobs) for _first, qi, key, jobs in entries]
        K, C = len(times), len(rows)
        arrivals = np.zeros((K, C), dtype=np.int64)
        for c, (_qi, _key, jobs) in enumerate(rows):
            for job in jobs.values():
                k = bisect.bisect_left(times, job.submitted_at)
                if k < K:
                    arrivals[k, c] += 1
        return rows, [CycleDelta(arrivals=arrivals[k]) for k in range(K)]

    @staticmethod
    def _reseed_hazard(plans, deltas) -> bool:
        """True when some cohort fully drains in one fused cycle and
        receives arrivals in a LATER one — the sequential path would
        re-seed its cross-cohort FIFO key at re-birth and may process
        the batch in a different order, so such batches replay
        sequentially instead of trusting the fused plans."""
        K = len(plans)
        C = len(deltas[0].arrivals)
        # later[k]: does any cohort entry see arrivals strictly after k?
        later = np.zeros((K, C), dtype=bool)
        for k in range(K - 2, -1, -1):
            later[k] = later[k + 1] | (deltas[k + 1].arrivals > 0)
        d = np.zeros_like(deltas[0].arrivals)
        for k in range(K - 1):
            d = d + deltas[k].arrivals
            drained = (d > 0) & (plans[k].per_cohort() >= d)
            if np.any(drained & later[k]):
                return True
            d = d - plans[k].per_cohort()
        return False

    def _advance_hazard(self, queues, problem, plans, workers,
                        times) -> bool:
        """Live-fusion guard: True when interleaved advancement could
        return capacity (or retire a worker) MID-BATCH — state the fused
        plans, computed for the whole window up front, did not see.
        Checked only when `advance_hook` is set (event-engine mode):

          * a worker whose idle timeout is shorter than the staged span,
            or whose already-running idle clock expires inside it, could
            self-terminate (C2) between two staged cycles;
          * a claim made by a NON-FINAL staged cycle that completes (or
            runs an opaque `work_fn`) before the final staged time would
            free capacity a later fused cycle should have re-matched.

        Pre-existing claims need no walk here: the event engine only
        defers a window after proving none of them can complete inside
        it (`Simulation._defer_ok`), and the flush never advances past
        the last staged time.  Conservative by construction — a hazard
        falls back to the exact sequential replay, it never mis-fuses."""
        margin = 1e-6
        span = times[-1] - times[0]
        for w in workers:
            if w.idle_timeout <= span + margin:
                return True
            if (not w.claimed and w.idle_since >= 0
                    and w.idle_since + w.idle_timeout
                    <= times[-1] + margin):
                return True
        K = len(times)
        if K < 2:
            return False
        C = problem.n_cohorts
        # claims of cycles 0..K-2 consume the cohort FIFO prefix in
        # staged order — walk the exact (job, worker) pairs _apply_plan
        # will create, before creating them
        totals = np.zeros(C, dtype=np.int64)
        for plan in plans[:-1]:
            totals += plan.per_cohort()
        pending: list = [None] * C
        used = np.zeros(C, dtype=np.int64)
        for t, plan in zip(times[:-1], plans[:-1]):
            takes = plan.takes
            for c in problem.order:
                row = takes[c]
                if int(row.sum()) <= 0:
                    continue
                if pending[c] is None:
                    qi, key = problem.keys[c]
                    pending[c] = queues[qi].cohort_jobs_sorted(
                        key, int(totals[c]))
                jobs = pending[c]
                ji = int(used[c])
                for wi in np.nonzero(row)[0]:
                    rate = workers[wi].work_rate
                    for job in jobs[ji:ji + int(row[wi])]:
                        if job.work_fn is not None:
                            return True
                        need = (job.remaining_s / rate if rate > 0
                                else float("inf"))
                        if t + need <= times[-1] + margin:
                            return True
                        ji += 1
                used[c] = ji
        return False

    def _plain_cycle(self, queues, now: float, *,
                     max_submit: float | None = None) -> int:
        """One plain (no fair-share) cycle.  `max_submit` restricts the
        pass to jobs submitted at or before that time — the staged-flush
        fallback replays deferred cycles with the visibility each would
        have had at its own timestamp."""
        workers = self.alive_workers(now)
        if not workers:
            return 0
        if any(not hasattr(q, "idle_cohorts") for q in queues):
            # foreign queues exposing only the seed surface negotiate
            # per-job against live offers; cohort-capable queues before/
            # after them see the drained capacity via fresh free vectors
            total = 0
            for q in queues:
                if hasattr(q, "idle_cohorts"):
                    total += self._plain_cycle([q], now)
                else:
                    total += self.scan_cycle(q, now)
            return total
        # no-op memo: a cycle that claimed NOTHING stays a no-op until
        # the idle set (idle_seq) or some worker's claims/liveness (the
        # pool fingerprint) change — drained-backlog steady states pay
        # two int-tuple compares per cycle instead of a full match
        memo_key = None
        if max_submit is None:
            memo_key = (tuple((id(q), q.idle_seq) for q in queues),
                        self._pool_fingerprint(now))
            if memo_key == self._noop_memo:
                self._c_noop_hits.value += 1
                return 0
        prof = self.profiler
        t_c0 = prof.now() if prof is not None else 0.0
        rows = []
        for qi, q in enumerate(queues):
            cohorts = []
            for k, j in q.idle_cohorts():
                if max_submit is not None:
                    j = {jid: job for jid, job in j.items()
                         if job.submitted_at <= max_submit}
                if j:
                    cohorts.append((k, j))
            cohorts.sort(key=lambda kv: q.cohort_first_submit(kv[0]))
            rows.extend((qi, k, j) for k, j in cohorts)
        if not rows:
            self._noop_memo = memo_key
            return 0
        reps = [next(iter(j.values())) for _qi, _k, j in rows]
        if self._quantity_sensitive(reps, workers):
            free = np.stack([w.free_vec() for w in workers])
            total = 0
            for qi, q in enumerate(queues):
                cohorts = [(k, j) for rqi, k, j in rows if rqi == qi]
                total += self._match_cohorts(q, cohorts, workers, free,
                                             now)
            if total == 0 and memo_key is not None:
                self._noop_memo = memo_key
            if prof is not None:
                prof.record_cycle(
                    t=now, kind="legacy", w_start=t_c0, build_s=0.0,
                    match_s=prof.now() - t_c0, apply_s=0.0,
                    claims=total, backend="legacy")
            return total
        problem = self._build_problem(rows, workers)
        t_m0 = prof.now() if prof is not None else 0.0
        plan = self.matchmaker.match(problem)
        t_a0 = prof.now() if prof is not None else 0.0
        claims = self._apply_plan(queues, problem, plan, workers, now)
        if claims == 0 and memo_key is not None:
            self._noop_memo = memo_key
        if prof is not None:
            lc = getattr(self.matchmaker, "last_call", None)
            prof.record_cycle(
                t=now, kind="plain", w_start=t_c0,
                build_s=t_m0 - t_c0, match_s=t_a0 - t_m0,
                apply_s=prof.now() - t_a0, claims=claims,
                backend=getattr(self.matchmaker, "name", ""),
                compiled=None if lc is None else lc.get("compiled"))
        return claims

    def _fairshare_cycle(self, queues, now: float, accountant,
                         quantum: int) -> int:
        workers = self.alive_workers(now)
        if not workers:
            return 0
        prof = self.profiler
        t_c0 = prof.now() if prof is not None else 0.0
        accountant.reset_cycle()
        names = [getattr(q, "name", f"schedd{i:02d}")
                 for i, q in enumerate(queues)]
        rows = []
        group_of = []                       # (schedd idx, user) per row
        for qi, q in enumerate(queues):
            cohorts = [(k, j) for k, j in q.idle_cohorts() if j]
            cohorts.sort(key=lambda kv: q.cohort_first_submit(kv[0]))
            for k, j in cohorts:
                rows.append((qi, k, j))
                group_of.append((qi, user_of(next(iter(j.values())))))
        if not rows:
            return 0
        reps = [next(iter(j.values())) for _qi, _k, j in rows]
        quantum = max(1, int(quantum))
        total = 0

        if self._quantity_sensitive(reps, workers):
            # legacy per-claim ladder: identical water-fill, with the
            # shrinking-offer expression rechecks the array path can't do
            free = np.stack([w.free_vec() for w in workers])
            active: dict[tuple[int, str], list] = {}
            for (si, user), (qi, k, j) in zip(group_of, rows):
                active.setdefault((si, user), []).append((k, j))
            total = self._fairshare_ladder(
                queues, names, active, workers, free, now, accountant,
                quantum,
                match=lambda q, cohorts, budget, observe: (
                    self._match_cohorts(q, cohorts, workers, free, now,
                                        budget=budget, on_claim=observe)))
            accountant.reset_cycle()
            if prof is not None:
                prof.record_cycle(
                    t=now, kind="legacy", w_start=t_c0, build_s=0.0,
                    match_s=prof.now() - t_c0, apply_s=0.0,
                    claims=total, backend="legacy")
            return total

        problem = self._build_problem(rows, workers)
        t_b1 = prof.now() if prof is not None else 0.0
        match_s = apply_s = 0.0
        group_rows: dict[tuple[int, str], list[int]] = {}
        for c, g in enumerate(group_of):
            group_rows.setdefault(g, []).append(c)
        C = problem.n_cohorts
        while group_rows:
            si = min({i for i, _ in group_rows},
                     key=lambda i: (accountant.group_owed(names[i], now),
                                    i))
            user = min((u for i, u in group_rows if i == si),
                       key=lambda u: (
                           accountant.effective_priority(u, now), u))
            cores = [0.0]

            def observe(job, _c=cores):
                _c[0] += job_cores(job)

            mask = np.zeros(C, dtype=bool)
            mask[group_rows[(si, user)]] = True
            t_s0 = prof.now() if prof is not None else 0.0
            plan = self.matchmaker.match(problem, budget=quantum,
                                         active=mask)
            t_s1 = prof.now() if prof is not None else 0.0
            got = self._apply_plan(queues, problem, plan, workers, now,
                                   on_claim=observe)
            if prof is not None:
                match_s += t_s1 - t_s0
                apply_s += prof.now() - t_s1
            problem.free = plan.free_after
            problem.demand = problem.demand - plan.per_cohort()
            if got:
                accountant.charge_virtual(names[si], user, cores[0])
                total += got
            if got < quantum:
                # demand or matching capacity exhausted for this user —
                # neither can grow within the cycle, so retire the entry
                del group_rows[(si, user)]
        # claims are real running-core rates now; outside-the-cycle
        # priority queries (metrics, owed-share deficits) must not see
        # stale virtual charges on top of them
        accountant.reset_cycle()
        if prof is not None:
            lc = getattr(self.matchmaker, "last_call", None)
            prof.record_cycle(
                t=now, kind="fairshare", w_start=t_c0,
                build_s=t_b1 - t_c0, match_s=match_s, apply_s=apply_s,
                claims=total, backend=getattr(self.matchmaker, "name", ""),
                compiled=None if lc is None else lc.get("compiled"))
        return total

    def _fairshare_ladder(self, queues, names, active, workers, free,
                          now, accountant, quantum, *, match) -> int:
        """The water-fill loop shared by the legacy fallback: argmin
        schedd by owed share, argmin user by effective priority, one
        quantum-capped slice each, retire on exhaustion."""
        total = 0
        while active:
            si = min({i for i, _ in active},
                     key=lambda i: (accountant.group_owed(names[i], now),
                                    i))
            user = min((u for i, u in active if i == si),
                       key=lambda u: (
                           accountant.effective_priority(u, now), u))
            cores = [0.0]

            def observe(job, _c=cores):
                _c[0] += job_cores(job)

            got = match(queues[si], active[(si, user)], quantum, observe)
            if got:
                accountant.charge_virtual(names[si], user, cores[0])
                total += got
            if got < quantum:
                del active[(si, user)]
        return total

    def preview(self, queues, now: float) -> list[dict]:
        """Dry-run of the next negotiation cycle through the pure
        matchmaker: how many of each cohort's idle jobs CURRENT free
        capacity would absorb, without claiming anything.  Returns one
        {cohort_key: absorbed} dict per queue.  The provisioner computes
        deficits from the remaining (post-negotiation) idle cohorts, so
        a job about to be matched to existing capacity — including
        partial slots the old unclaimed-worker count missed — is not
        provisioned for again.

        Estimate caveat (quantity-reading expressions): a START or
        Requirements expression that reads offered quantities forces the
        legacy live-offer walk (`_preview_legacy`, counted by
        `repro_preview_legacy_total`), which evaluates each cohort's
        expression against the worker's LIVE offer instead of the
        virtually-drained one.  The error is bounded at **one cohort
        slice per worker**: for each worker the walk hands out at most
        one `min(fits, remaining)` slice per cohort under a stale
        verdict, and a verdict can only go stale once per worker —
        capacity only shrinks within the dry run — so the over-count
        never exceeds the first mis-admitted slice, `fits(live free)`
        jobs, per worker.  Under-count cannot happen: a job admitted by
        the drained offer is admitted by the live one.
        tests/test_preview_counters.py pins this bound."""
        return self.preview_candidates(queues, now)[0]

    def preview_candidates(self, queues, now: float,
                           frees: list | None = None) -> list[list[dict]]:
        """Batched preview: evaluate N candidate free matrices against
        ONE problem built from the current idle cohorts, in ONE
        matchmaker dispatch where the backend supports it (the jax
        backend's vmapped `preview_many`; others run the sequential
        reference).  ``frees`` is a list of (W, R) candidate matrices
        over `alive_workers(now)` row order — None means one candidate,
        the live free matrix.  Returns one per-queue absorption list
        (the `preview` shape) per candidate.

        The jax fast path keeps the problem's cohort constants
        device-resident across calls keyed on the problem STRUCTURE
        (cohort keys + worker slot shapes), so the per-reconcile cost is
        shipping the free matrix down and Cp ints back — not rebuilding
        and re-uploading the padded problem."""
        if hasattr(queues, "claim"):
            queues = [queues]
        else:
            queues = list(queues)
        # staged-but-unflushed cycles are invisible to a dry run: flush
        # them (with interleaved advancement in live-fusion mode) so the
        # preview sees post-negotiation truth
        if self._staged_times:
            self.flush_staged()
        n_cand = 1 if frees is None else len(frees)
        outs: list[list[dict]] = [[{} for _ in queues]
                                  for _ in range(n_cand)]
        workers = self.alive_workers(now)
        if not workers:
            return outs
        entries = []
        for qi, q in enumerate(queues):
            if not hasattr(q, "idle_cohorts"):
                continue          # foreign queue: no preview possible
            for key, jobs in q.idle_cohorts():
                if jobs:
                    entries.append(
                        (q.cohort_first_submit(key), qi, key, jobs))
        if not entries:
            return outs
        entries.sort(key=lambda e: (e[0], e[1]))
        rows = [(qi, key, jobs) for _first, qi, key, jobs in entries]
        reps = [next(iter(j.values())) for _qi, _k, j in rows]
        if self._quantity_sensitive(reps, workers):
            self._c_preview_legacy.value += 1
            if frees is None:
                return [self._preview_legacy(queues, rows, workers)]
            return [self._preview_legacy(queues, rows, workers, free=f)
                    for f in frees]
        problem = self._build_problem(rows, workers)
        cand = [problem.free] if frees is None else list(frees)
        fused = getattr(self.matchmaker, "preview_many", None)
        if fused is not None:
            # structure token for the backend's device-constant session
            # (worker identity is irrelevant — only slot shapes feed the
            # request/compat constants)
            token = (tuple(problem.keys),
                     tuple(w.match_key() for w in workers))
            pers = fused(problem, cand, session=token)
            prof = self.profiler
            if prof is not None:
                lc = getattr(self.matchmaker, "last_call", None)
                if lc is not None and lc.get("compiled"):
                    prof.note_compile("preview")
        else:
            pers = sequential_preview_many(self.matchmaker, problem,
                                           cand)
        for out, per in zip(outs, pers):
            for c, (qi, key, _jobs) in enumerate(rows):
                if per[c]:
                    out[qi][key] = int(per[c])
        return outs

    def _preview_legacy(self, queues, rows, workers, *,
                        free: np.ndarray | None = None) -> list[dict]:
        """Pre-protocol preview walk, kept for quantity-reading
        expressions (live-offer evals; see the caveat on `preview`)."""
        out: list[dict] = [{} for _ in queues]
        if free is None:
            free = np.stack([w.free_vec() for w in workers])
        else:
            free = np.array(free, dtype=np.float64, copy=True)
        for qi, key, jobs in rows:
            rep = next(iter(jobs.values()))
            want = _job_req_vec(rep)
            fits = cohort_fits(free, want, len(jobs))
            if fits.sum() <= 0:
                continue
            left = len(jobs)
            absorbed = 0
            for wi, w in enumerate(workers):
                if left <= 0:
                    break
                k = int(fits[wi])
                if k <= 0:
                    continue
                if not self.cohort_match(rep, w):
                    continue
                take = min(k, left)
                free[wi] -= want * take
                absorbed += take
                left -= take
            if absorbed:
                out[qi][key] = absorbed
        return out

    def scan_cycle(self, queue: JobQueue, now: float) -> int:
        """The seed's per-job FIFO cycle behind the protocol — the
        tick-engine baseline and the oracle for differential tests.
        Cohort-capable queues with quantity-blind expressions route
        through `ScanMatchmaker` on the pure problem; anything else runs
        the seed loop verbatim against live offers."""
        workers = self.alive_workers(now)
        if not workers:
            return 0
        if not hasattr(queue, "idle_cohorts"):
            return self._scan_legacy(queue, now)
        rows = [(0, k, j) for k, j in queue.idle_cohorts() if j]
        if not rows:
            return 0
        reps = [next(iter(j.values())) for _qi, _k, j in rows]
        if self._quantity_sensitive(reps, workers):
            return self._scan_legacy(queue, now)
        idle = sorted(queue.idle_jobs(), key=lambda j: j.submitted_at)
        problem = self._build_problem(rows, workers, scan_jobs=idle)
        plan = self._scan_oracle.match(problem)
        return self._apply_plan([queue], problem, plan, workers, now)

    def _scan_legacy(self, queue, now: float) -> int:
        """The seed's per-job O(idle × workers) loop, verbatim."""
        claims = 0
        idle = sorted(queue.idle_jobs(), key=lambda j: j.submitted_at)
        candidates = list(self.alive_workers(now))
        for job in idle:
            if not candidates:
                break
            matched = None
            for w in candidates:
                if symmetric_match(job.ad, w.offer_ad(),
                                   job.requirements, w.start_expr):
                    matched = w
                    break
            if matched is None:
                continue
            queue.claim(job.jid, matched.name, now)
            matched.add_claim(job)
            matched.idle_since = -1.0
            claims += 1
            free = matched.free_resources()
            exhausted = any(
                isinstance(v, (int, float)) and v <= 0
                for k, v in free.items()
                if k in ("cpus", "gpus", "chips") and matched.ad.get(k)
            )
            if exhausted:
                candidates.remove(matched)
        return claims

    # -- legacy per-claim claiming loop (quantity-expression fallback) -------
    def _match_cohorts(self, queue: JobQueue, cohorts: list, workers: list,
                       free: np.ndarray, now: float, *,
                       budget: int | None = None,
                       on_claim=None) -> int:
        """The pre-protocol vectorized claiming loop over pre-sorted
        cohorts, against a SHARED worker free-resource matrix (`free`
        mutates in place, so several schedds in one negotiation cycle
        see capacity drain as earlier ones claim).  Kept as the exact
        path for quantity-reading expressions: `budget` caps new claims
        (fair-share hands out capacity in bounded slices); `on_claim(job)`
        observes each claim (the cycle charges usage from it)."""
        claims = 0
        for key, jobs in cohorts:
            if not jobs:
                continue               # drained by an earlier slice
            if budget is not None and claims >= budget:
                break
            rep = next(iter(jobs.values()))
            want = _job_req_vec(rep)
            fits = cohort_fits(free, want, len(jobs))
            if fits.sum() <= 0:
                continue
            pending = queue.cohort_jobs_sorted(
                key, None if budget is None else budget - claims)
            if len(pending) > len(jobs):
                # a staged time-cutoff replay negotiates a submit-time
                # PREFIX of the cohort: the dict handed in is the
                # demand, and FIFO order makes the prefix exactly it
                pending = pending[:len(jobs)]
            # A START/Requirements expression that reads offered QUANTITIES
            # (e.g. 'gpus >= 2') must be re-evaluated against the shrinking
            # offer after every claim — block-claiming is only exact for
            # quantity-blind policies (the common pushed-down filters).
            per_claim_check = bool(
                (rep.requirements.refs if rep.requirements is not None
                 else frozenset()) & _QUANTITY_ATTRS)
            ji = 0
            for wi, w in enumerate(workers):
                if ji >= len(pending):
                    break
                k = int(fits[wi])
                if k <= 0:
                    continue
                if not self.cohort_match(rep, w):
                    continue
                recheck = per_claim_check or bool(
                    w.start_expr.refs & _QUANTITY_ATTRS)
                take = min(k, len(pending) - ji)
                taken = 0
                for job in pending[ji:ji + take]:
                    if recheck and taken > 0 and not self.cohort_match(
                            rep, w):
                        break
                    queue.claim(job.jid, w.name, now)
                    w.add_claim(job)
                    if on_claim is not None:
                        on_claim(job)
                    taken += 1
                w.idle_since = -1.0
                free[wi] -= want * taken
                ji += taken
                claims += taken
        return claims

    # -- deprecated shims ----------------------------------------------------
    def negotiate(self, queue: JobQueue, now: float) -> int:
        """Deprecated: use `run_cycle(queue, now)`."""
        warnings.warn(
            "Collector.negotiate is deprecated; use Collector.run_cycle",
            DeprecationWarning, stacklevel=2)
        return self.run_cycle(queue, now)

    def negotiate_scan(self, queue: JobQueue, now: float) -> int:
        """Deprecated: use `scan_cycle(queue, now)`."""
        warnings.warn(
            "Collector.negotiate_scan is deprecated; use "
            "Collector.scan_cycle", DeprecationWarning, stacklevel=2)
        return self.scan_cycle(queue, now)

    def preview_matches(self, queues, now: float) -> list[dict]:
        """Deprecated: use `preview(queues, now)`."""
        warnings.warn(
            "Collector.preview_matches is deprecated; use "
            "Collector.preview", DeprecationWarning, stacklevel=2)
        return self.preview(queues, now)


def advance_workers(
    collector: Collector,
    queue: JobQueue,
    cluster,
    now: float,
    dt: float,
    *,
    scan_matches: bool = False,
    exact_completions: bool = True,
) -> list[str]:
    """Advance all workers over [now, now+dt]: run claimed jobs, complete
    them AT THEIR EXACT FINISH TIME (not quantized to the interval end),
    start the idle-timeout clock, self-terminate (C2).  Returns names of
    workers that self-terminated.

    `scan_matches=True` / `exact_completions=False` together reproduce
    the seed tick loop verbatim (per-job C2 idle poll, completions
    quantized to now+dt, no mid-interval boot credit) — the tick-engine
    baseline; the defaults are the event engine's exact semantics."""
    t1 = now + dt
    terminated = []
    for w in list(collector.workers.values()):
        if exact_completions:
            if w.terminated or w.booted_at < 0 or w.booted_at >= t1:
                continue
            seg0 = max(now, w.booted_at)
            seg = t1 - seg0
            if seg <= 0:
                continue
        else:                      # seed: whole ticks, gated at tick start
            if w.terminated or not w.ready(now):
                continue
            seg0, seg = now, dt
        w.alive_s += seg
        idle_from = seg0         # idleness cannot predate the boot
        if w.claimed:
            busy_until = seg0
            for jid, job in list(w.claimed.items()):
                if job.work_fn is not None:
                    done = job.work_fn(job, seg)
                    t_done = t1
                elif exact_completions:
                    rate = w.work_rate
                    need = (job.remaining_s / rate if rate > 0
                            else float("inf"))
                    if need <= seg + 1e-9:
                        job.remaining_s = 0.0
                        done = True
                        t_done = min(seg0 + need, t1)
                    else:
                        job.remaining_s -= seg * rate
                        done = False
                        t_done = t1
                else:               # seed: progress and finish in dt units
                    job.remaining_s -= dt * w.work_rate
                    done = job.remaining_s <= 1e-9
                    t_done = t1
                if done:
                    # route to the owning schedd: under flocking, one
                    # worker serves jobs from several queues (`queue`
                    # here may be a FlockedQueues view)
                    (job.schedd or queue).complete(jid, t_done)
                    w.drop_claim(jid)
                busy_until = max(busy_until, t_done)
            w.busy_s += (busy_until - seg0 if exact_completions else dt)
            if not w.claimed and exact_completions:
                idle_from = busy_until   # idle clock starts at the EXACT
                #                          last-completion time, not the
                #                          segment start
        if w.claimed:
            w.idle_since = -1.0
            continue
        if w.draining:
            # backend drain: claims done — retire immediately instead of
            # waiting out idle_timeout (no new claims can arrive anyway)
            w.terminated = True
            terminated.append(w.name)
            collector.invalidate(w.name)
            if w.pod_name is not None and cluster is not None:
                cluster.succeed_pod(w.pod_name, t1)
            continue
        # idle: does any matching idle job exist? (C2 poll)
        if scan_matches:
            has_match = any(
                symmetric_match(j.ad, w.offer_ad(), j.requirements,
                                w.start_expr)
                for j in queue.idle_jobs()
            )
        else:
            has_match = collector.any_cohort_matches(w, queue)
        if has_match:
            w.idle_since = -1.0  # negotiator will claim next cycle
            continue
        if w.idle_since < 0:
            w.idle_since = idle_from
        elif t1 - w.idle_since >= w.idle_timeout:
            w.terminated = True
            terminated.append(w.name)
            collector.invalidate(w.name)
            if w.pod_name is not None and cluster is not None:
                cluster.succeed_pod(w.pod_name, t1)
    return terminated


def kill_worker(collector: Collector, queue: JobQueue, worker_name: str,
                now: float):
    """Pod/node preemption path (§5): release claimed jobs back to IDLE;
    HTCondor reschedules them transparently."""
    w = collector.workers.get(worker_name)
    if w is None:
        return
    for jid, job in list(w.claimed.items()):
        (job.schedd or queue).release(jid, now, preempted=True)
    w.clear_claims()
    w.terminated = True
    collector.invalidate(worker_name)
