"""Discrete-time simulation harness wiring all control-plane components.

One `Simulation` owns: JobQueue (schedd), Collector (pool), KubeCluster,
Provisioner, optional NodeAutoscaler, optional fault injectors, and a
Recorder.  `run(until)` advances in fixed ticks; each tick:

  1. external events (job arrivals, spot reclaims) fire
  2. provisioner reconciles (at its own interval)  — C1/C3/C4
  3. node autoscaler ticks                          — C7
  4. kube scheduler places pods (priorities/preemption) — §5
  5. negotiator matches idle jobs to ready workers
  6. workers advance claimed jobs; self-terminate when idle — C2
  7. metrics are recorded

The same Provisioner/Worker code runs under wall-clock in the examples
(launch/train.py elastic mode) — the simulator only replaces the clock and
the job payloads, not the decision logic (paper-faithfulness hinges on
this separation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.cluster import KubeCluster, Node, PodPhase
from repro.core.config import ProvisionerConfig
from repro.core.jobqueue import Job, JobQueue
from repro.core.metrics import Recorder, summarize_jobs, summarize_workers
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate
from repro.core.provisioner import Provisioner
from repro.core.stragglers import StragglerPolicy
from repro.core.worker import Collector, advance_workers, kill_worker


@dataclasses.dataclass
class TimedEvent:
    at: float
    fn: Callable[["Simulation", float], None]
    name: str = ""


class Simulation:
    def __init__(
        self,
        cfg: ProvisionerConfig,
        *,
        nodes: list[Node] | None = None,
        node_template: NodeTemplate | None = None,
        max_nodes: int = 64,
        tick_s: float = 5.0,
        negotiate_interval_s: float = 15.0,
        seed: int = 0,
        straggler_policy: StragglerPolicy | None = None,
    ):
        self.cfg = cfg
        self.tick_s = tick_s
        self.negotiate_interval_s = negotiate_interval_s
        self.queue = JobQueue()
        self.collector = Collector()
        self.cluster = KubeCluster(nodes or [])
        self.provisioner = Provisioner(
            cfg, self.queue, self.collector, self.cluster
        )
        self.autoscaler = (
            NodeAutoscaler(self.cluster, node_template, max_nodes=max_nodes)
            if node_template is not None else None
        )
        self.straggler_policy = straggler_policy
        self.recorder = Recorder()
        self.events: list[TimedEvent] = []
        self.now = 0.0
        self._last_negotiate = -1e18
        self.rng = np.random.default_rng(seed)
        self.all_workers: list = []  # includes terminated (for accounting)

        # track every worker the provisioner makes
        orig_factory = self.provisioner.worker_factory
        from repro.core.worker import Worker as _W

        def tracking_factory(**kw):
            w = (orig_factory or _W)(**kw)
            self.all_workers.append(w)
            return w

        self.provisioner.worker_factory = tracking_factory

    # -- event helpers -------------------------------------------------------
    def at(self, t: float, fn: Callable[["Simulation", float], None],
           name: str = ""):
        self.events.append(TimedEvent(t, fn, name))

    def submit_jobs(self, t: float, jobs: Iterable[Job]):
        jobs = list(jobs)

        def fire(sim: "Simulation", now: float):
            for j in jobs:
                sim.queue.submit(j, now)

        self.at(t, fire, name=f"submit x{len(jobs)}")

    def inject_node_failure(self, t: float, node_name: str | None = None):
        def fire(sim: "Simulation", now: float):
            names = list(sim.cluster.nodes)
            if not names:
                return
            target = node_name or names[
                int(sim.rng.integers(0, len(names)))
            ]
            sim.cluster.fail_node(target, now)

        self.at(t, fire, name="node_failure")

    def inject_slow_workers(self, t: float, frac: float = 0.3,
                            rate: float = 0.2):
        """Degrade a fraction of BUSY workers to `rate` speed (straggling
        nodes: thermal throttling, failing HBM, noisy neighbours)."""

        def fire(sim: "Simulation", now: float):
            busy = [w for w in sim.collector.workers.values() if w.claimed]
            k = max(1, int(len(busy) * frac)) if busy else 0
            idx = sim.rng.permutation(len(busy))[:k]
            for i in idx:
                busy[i].work_rate = rate

        self.at(t, fire, name="slow_workers")

    def inject_pod_preemption(self, t: float, frac: float = 0.5):
        """Spot-style reclaim of a fraction of running provisioner pods."""

        def fire(sim: "Simulation", now: float):
            pods = sim.cluster.running_pods(
                lambda p: p.labels.get("owner") == "prp-provisioner"
            )
            k = max(1, int(len(pods) * frac)) if pods else 0
            idx = sim.rng.permutation(len(pods))[:k]
            for i in idx:
                sim.cluster.delete_pod(pods[i].name, now, "preempted")

        self.at(t, fire, name="pod_preemption")

    # -- main loop --------------------------------------------------------------
    def step(self):
        now, dt = self.now, self.tick_s

        # 1. external events
        due = [e for e in self.events if e.at <= now]
        self.events = [e for e in self.events if e.at > now]
        for e in sorted(due, key=lambda e: e.at):
            e.fn(self, now)

        # 2. provisioner
        self.provisioner.maybe_reconcile(now)

        # 3. node autoscaler
        if self.autoscaler is not None:
            self.autoscaler.tick(now, dt)

        # 4. kube scheduling + accounting
        self.cluster.schedule(now)
        self.cluster.tick_accounting(dt)

        # 5. negotiation
        if now - self._last_negotiate >= self.negotiate_interval_s:
            self.collector.negotiate(self.queue, now)
            self._last_negotiate = now

        # 6. workers advance
        advance_workers(self.collector, self.queue, self.cluster, now, dt)

        # 6b. straggler mitigation (beyond-paper; see core/stragglers.py)
        if self.straggler_policy is not None:
            self.straggler_policy.tick(self.queue, self.collector,
                                       self.cluster, now)

        # 7. metrics
        self.recorder.record(
            now,
            idle_jobs=self.queue.n_idle(),
            running_jobs=self.queue.n_running(),
            pending_pods=len(self.cluster.pending_pods()),
            running_pods=len(self.cluster.running_pods()),
            ready_workers=len(self.collector.alive_workers(now)),
            busy_workers=sum(
                1 for w in self.collector.workers.values() if w.claimed
            ),
            live_nodes=len(self.cluster.nodes),
        )
        self.now += dt

    def run(self, until: float):
        while self.now < until:
            self.step()

    def run_until_drained(self, max_t: float = 1e6):
        while ((self.events or not self.queue.drained())
               and self.now < max_t):
            self.step()

    # -- summaries -----------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        out["jobs"] = summarize_jobs(self.queue.completed_log, self.now)
        out["workers"] = summarize_workers(self.all_workers)
        out["pods_submitted"] = self.provisioner.stats.submitted
        if self.autoscaler is not None:
            out["nodes"] = {
                "provisioned": self.autoscaler.provisioned_total,
                "deprovisioned": self.autoscaler.deprovisioned_total,
                "waste_fraction": self.autoscaler.waste_fraction(),
            }
        out["gpu_utilization"] = self.cluster.utilization("gpu")
        return out


# ---------------------------------------------------------------------------
# Convenience builders used by benchmarks/examples
# ---------------------------------------------------------------------------

def gpu_job(runtime_s: float, *, gpus: int = 1, cpus: int = 1,
            memory_gb: int = 4, arch: str | None = None,
            checkpoint_interval_s: float | None = None,
            extra_ad: dict | None = None) -> Job:
    ad: dict[str, Any] = {
        "request_cpus": cpus,
        "request_gpus": gpus,
        "request_memory": memory_gb,
        "request_disk": 8,
    }
    if arch is not None:
        ad["arch"] = arch
    if checkpoint_interval_s:
        ad["checkpoint_interval_s"] = checkpoint_interval_s
    ad.update(extra_ad or {})
    return Job(ad=ad, runtime_s=runtime_s)


def onprem_nodes(n: int, *, gpus: int = 8, cpus: int = 64,
                 memory_gb: int = 512, labels: dict | None = None,
                 prefix: str = "onprem") -> list[Node]:
    return [
        Node(
            name=f"{prefix}-{i}",
            capacity={"cpu": cpus, "gpu": gpus, "memory": memory_gb,
                      "disk": 1024},
            labels=dict(labels or {}),
        )
        for i in range(n)
    ]
