"""Event-driven simulation harness wiring all control-plane components.

One `Simulation` owns: JobQueue (schedd), Collector (pool), N
`ScalingBackend`s (each a KubeCluster + optional NodeAutoscaler + cost
model), Provisioner, optional fault injectors, and a Recorder.

The core is a discrete-event `EventLoop` (core/events.py).  Control-plane
activities are periodic callbacks at their EXACT cadence — no tick
quantization, no `last = now` drift:

  priority 0   external events (job arrivals, spot reclaims, failures)
  priority 10  provisioner reconcile, every submit_interval_s — C1/C3/C4
  priority 20  per-backend tick: node autoscaler (C7), kube scheduler
               (priorities/preemption, §5), cost accounting
  priority 30  negotiator matches idle-job cohorts to workers
  priority 40  straggler mitigation (beyond-paper)
  priority 50  metrics sampling (own cadence, decoupled from tick_s)

Between events, continuous state — running jobs, worker busy/alive time —
is integrated lazily: before ANY event fires, `_advance_to(t)` advances
the workers to exactly `t`, so a spot reclaim at t=12.5 sees job progress
up to 12.5 and completions land at their exact finish times (C2 wakeups).

Compatibility: `tick_s`, `step()`, and `run(until)` keep their seed
meaning (a step advances one tick's worth of events).  `engine="tick"`
retains the seed's fixed-tick O(n)-scan loop verbatim — it is the
baseline for benchmarks/bench_event_engine.py and the oracle for
differential tests.

Single-backend compatibility: the seed constructor signature
(`nodes=`, `node_template=`, `max_nodes=`) still works — it is adapted
into a one-element backend list, and `sim.cluster` / `sim.autoscaler`
keep pointing at that backend's internals.  Multi-provider federations
pass `backends=[...]` or use `Simulation.from_config` with a config
declaring `[backend:<name>]` sections.

Multi-schedd flocking: `schedds=N` (or a list of `ScheddSpec`s with
quotas and per-user priority factors) builds N submit-host queues
sharing one pool-unique jid counter, negotiated as ONE cycle in
flocking order (`Collector.run_cycle`); `fairshare=True` (or an
`Accountant`) adds hierarchical fair-share — per-schedd quotas, then
per-user effective priority with usage decay.  The single-queue
construction path is untouched (`sim.queue` keeps meaning the first/
only schedd), matching the backend-adapter compat pattern.

The same Provisioner/Worker code runs under wall-clock in the examples
(launch/train.py elastic mode) — the simulator only replaces the clock and
the job payloads, not the decision logic (paper-faithfulness hinges on
this separation).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.backend import (
    FederatedClusterView, KubeBackend, build_backends, schedule_backend_on,
)
from repro.core.cluster import KubeCluster, Node
from repro.core.config import ProvisionerConfig
from repro.core.events import EventLoop
from repro.core.fairshare import Accountant, ScheddSpec, make_schedd_specs
from repro.core.jobqueue import FlockedQueues, Job, JobQueue
from repro.core.metrics import (
    Recorder, summarize_backends, summarize_jobs, summarize_workers,
)
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate
from repro.core.provisioner import Provisioner
from repro.core.stragglers import StragglerPolicy
from repro.core.worker import Collector, advance_workers

# same-timestamp ordering, mirroring the seed's intra-tick sequence
P_EXTERNAL = 0
P_RECONCILE = 10
P_BACKEND = 20
P_NEGOTIATE = 30
P_STRAGGLER = 40
P_METRICS = 50


@dataclasses.dataclass
class TimedEvent:
    at: float
    fn: Callable[["Simulation", float], None]
    name: str = ""


class Simulation:
    def __init__(
        self,
        cfg: ProvisionerConfig,
        *,
        nodes: list[Node] | None = None,
        node_template: NodeTemplate | None = None,
        max_nodes: int = 64,
        backends: list | None = None,
        tick_s: float = 5.0,
        negotiate_interval_s: float = 15.0,
        metrics_interval_s: float | None = None,
        seed: int = 0,
        straggler_policy: StragglerPolicy | None = None,
        engine: str = "event",
        schedds: int | list | None = None,
        fairshare: Accountant | bool | None = None,
        negotiate_quantum: int = 1,
        matchmaker=None,
    ):
        if engine not in ("event", "tick"):
            raise ValueError(f"unknown engine {engine!r}")
        self.engine = engine
        self.cfg = cfg
        self.tick_s = tick_s
        self.negotiate_interval_s = negotiate_interval_s
        self.metrics_interval_s = metrics_interval_s or tick_s

        # one schedd (the seed signature) or a flocking federation of
        # them — `schedds=N` / `schedds=[ScheddSpec(...), ...]` makes N
        # queues sharing one pool-unique jid counter; `fairshare=True`
        # (or an Accountant) turns on hierarchical fair-share in the
        # negotiation cycle
        self.flocking = schedds is not None or fairshare is not None
        self.negotiate_quantum = negotiate_quantum
        if fairshare and engine == "tick":
            # the tick engine's scan_cycle is the seed oracle and
            # knows nothing of the accountant — silently dropping the
            # configured fair-share would be worse than refusing
            raise ValueError(
                "fairshare requires engine='event' (the tick baseline "
                "negotiates per-job FIFO scans in flocking order only)")
        if self.flocking:
            self.schedd_specs = make_schedd_specs(
                schedds if schedds is not None else 1)
            ids = itertools.count()
            self.queues = [JobQueue(name=s.name, ids=ids)
                           for s in self.schedd_specs]
            if fairshare is True:
                fairshare = Accountant()
            self.accountant = fairshare or None
            if self.accountant is not None:
                for spec, q in zip(self.schedd_specs, self.queues):
                    self.accountant.set_quota(spec.name, spec.quota)
                    for user, f in spec.priority_factors.items():
                        self.accountant.set_priority_factor(user, f)
                    self.accountant.attach_queue(spec.name, q)
            self.pool_queue = FlockedQueues(self.queues)
        else:
            self.schedd_specs = [ScheddSpec(name="schedd")]
            self.queues = [JobQueue()]
            self.accountant = None
            self.pool_queue = self.queues[0]
        self.queue = self.queues[0]
        # negotiation backend: the explicit arg wins, else the INI
        # `[provision] matchmaker=` key (core/matchmaker — "numpy"
        # reference, "jax" jitted, "scan" oracle, or an instance)
        if matchmaker is None:
            matchmaker = getattr(cfg, "matchmaker", None)
        self.collector = Collector(matchmaker=matchmaker)
        if backends is None:
            # single-backend compatibility adapter (seed signature)
            cluster = KubeCluster(nodes or [])
            autoscaler = (
                NodeAutoscaler(cluster, node_template, max_nodes=max_nodes)
                if node_template is not None else None
            )
            backends = [KubeBackend("default", cluster, autoscaler)]
        self.backends = list(backends)
        self.cluster = self.backends[0].cluster
        self.autoscaler = self.backends[0].autoscaler
        self.cluster_view = FederatedClusterView(self.backends)
        self.provisioner = Provisioner(
            cfg, self.queues, self.collector, self.backends,
            schedd_quotas={s.name: s.quota for s in self.schedd_specs},
        )
        self.straggler_policy = straggler_policy
        self.recorder = Recorder()
        self.events: list[TimedEvent] = []      # tick engine's flat list
        self.now = 0.0
        self._last_negotiate = -1e18            # tick engine (drifts; see
        #                                         event engine for the fix)
        self.rng = np.random.default_rng(seed)
        self.all_workers: list = []  # includes terminated (for accounting)

        # track every worker the provisioner makes
        orig_factory = self.provisioner.worker_factory
        from repro.core.worker import Worker as _W

        def tracking_factory(**kw):
            w = (orig_factory or _W)(**kw)
            self.all_workers.append(w)
            return w

        self.provisioner.worker_factory = tracking_factory

        self.loop = EventLoop()
        self._advanced_until = 0.0
        self._external_pending = 0
        if engine == "event":
            self._install_periodics()

    def _install_periodics(self):
        """Exact-cadence control-plane callbacks (the seed polled these
        every tick, accumulating up to tick_s of drift per period)."""
        self.provisioner.schedule_on(self.loop, first=0.0,
                                     priority=P_RECONCILE)
        for backend in self.backends:
            register = getattr(backend, "schedule_on", None)
            if register is not None:
                register(self.loop, self.tick_s, priority=P_BACKEND)
            else:
                # foreign ScalingBackend without the event-loop hook
                schedule_backend_on(backend, self.loop, self.tick_s,
                                    priority=P_BACKEND)
        self.loop.every(
            self.negotiate_interval_s, self._negotiate_cb,
            first=0.0, name="negotiate", priority=P_NEGOTIATE)
        if self.straggler_policy is not None:
            self.loop.every(
                self.tick_s, self._straggler_cb,
                first=self.tick_s, name="stragglers", priority=P_STRAGGLER)
        self.loop.every(
            self.metrics_interval_s, self._record_cb,
            first=0.0, name="metrics", priority=P_METRICS)

    # -- periodic callbacks (event engine) -----------------------------------
    def _negotiate_cb(self, now: float):
        self._last_negotiate = now
        if self.flocking:
            self.collector.run_cycle(
                self.queues, now, accountant=self.accountant,
                quantum=self.negotiate_quantum)
        else:
            self.collector.run_cycle(self.queue, now)

    def _straggler_cb(self, now: float):
        self.straggler_policy.tick(self.pool_queue, self.collector,
                                   self.cluster_view, now)

    def _record_cb(self, now: float):
        self.recorder.record(
            now,
            idle_jobs=self.pool_queue.n_idle(),
            running_jobs=self.pool_queue.n_running(),
            pending_pods=len(self.cluster_view.pending_pods()),
            running_pods=len(self.cluster_view.running_pods()),
            ready_workers=len(self.collector.alive_workers(now)),
            busy_workers=sum(
                1 for w in self.collector.workers.values() if w.claimed
            ),
            live_nodes=sum(len(b.cluster.nodes) for b in self.backends),
            idle_cohorts=self.pool_queue.n_idle_cohorts(),
            provisioned_cores=sum(
                n.capacity.get("cpu", 0)
                for b in self.backends for n in b.cluster.nodes.values()
            ),
            cost_rate=sum(b.cost_rate() for b in self.backends),
        )
        if len(self.backends) > 1:
            for b in self.backends:
                self.recorder.record_backend(
                    now, b.name,
                    pending_pods=b.pending(None),
                    live_pods=b.live_pods(),
                    live_nodes=len(b.cluster.nodes),
                    cost_rate=b.cost_rate(),
                )
        if self.flocking:
            self._record_flocking(now)

    def _record_flocking(self, now: float):
        """Per-schedd and per-user fair-share gauges (idle, running,
        effective priority, starvation age) — the Fig 2/3-style series
        split by community that the compare harness surfaces."""
        deficits = self.provisioner.stats.per_schedd_deficit
        # per-user gauges are aggregated across schedds (users are
        # pool-global in the accountant, as in HTCondor)
        idle_u: dict[str, tuple[int, float]] = {}
        running_u: dict[str, int] = {}
        for q in self.queues:
            self.recorder.record_schedd(
                now, q.name,
                idle_jobs=q.n_idle(),
                running_jobs=q.n_running(),
                deficit=deficits.get(q.name, 0),
            )
            for user, (n, age) in q.idle_by_user(now).items():
                pn, page = idle_u.get(user, (0, 0.0))
                idle_u[user] = (pn + n, max(page, age))
            for user, n in q.running_by_user.items():
                running_u[user] = running_u.get(user, 0) + n
        for user in sorted(set(idle_u) | set(running_u)):
            n, age = idle_u.get(user, (0, 0.0))
            gauges = {
                "idle_jobs": n,
                "running_jobs": running_u.get(user, 0),
                "starvation_age_s": age,
            }
            if self.accountant is not None:
                gauges["effective_priority"] = (
                    self.accountant.effective_priority(user, now))
            self.recorder.record_user(now, user, **gauges)

    def _advance_to(self, t: float):
        """Integrate continuous state (running jobs, worker clocks) up to
        exactly `t` — called before every event fires."""
        if t <= self._advanced_until:
            return
        dt = t - self._advanced_until
        advance_workers(self.collector, self.pool_queue, self.cluster_view,
                        self._advanced_until, dt)
        self._advanced_until = t

    @classmethod
    def from_config(cls, cfg: ProvisionerConfig, **kw) -> "Simulation":
        """Build the federation declared by `[backend:<name>]` sections;
        falls back to the single-backend constructor when none exist."""
        if cfg.backends and "backends" not in kw:
            kw["backends"] = build_backends(cfg)
        return cls(cfg, **kw)

    def backend(self, name: str):
        return self.provisioner.backend(name)

    # -- event helpers -------------------------------------------------------
    def at(self, t: float, fn: Callable[["Simulation", float], None],
           name: str = ""):
        """Schedule an external event; under the event engine it fires at
        EXACTLY `t` (the seed fired it at the first tick >= t).  A time
        at or before `now` fires as soon as the clock next advances —
        the seed accepted late events the same way."""
        if self.engine == "tick":
            self.events.append(TimedEvent(t, fn, name))
            return
        self._external_pending += 1

        def fire(now: float):
            self._external_pending -= 1
            fn(self, now)

        self.loop.schedule(max(t, self.loop.now), fire, name=name,
                           priority=P_EXTERNAL)

    def queue_named(self, schedd: str | int | None) -> JobQueue:
        """Resolve a schedd by name or flocking index (None: first)."""
        if schedd is None:
            return self.queue
        if isinstance(schedd, int):
            return self.queues[schedd]
        for q in self.queues:
            if getattr(q, "name", None) == schedd:
                return q
        raise KeyError(f"no schedd named {schedd!r}; "
                       f"have {[q.name for q in self.queues]}")

    def submit_jobs(self, t: float, jobs: Iterable[Job],
                    schedd: str | int | None = None):
        """Submit a batch at time `t`, to one schedd's queue (`schedd`
        names or indexes it; default: the first/only queue).  Lists/
        tuples are counted up front (for the event name); any OTHER
        iterable — a generator, a streaming trace reader — is kept lazy
        and only drawn when the event fires, so scheduling a 100k-job
        campaign materializes zero `Job` objects until its arrival time
        (workload/replay.py spreads the draw across many events).  Lazy
        iterables are consumed exactly once: re-running the simulation
        needs a fresh one."""
        target = self.queue_named(schedd)
        if isinstance(jobs, (list, tuple)):
            batch = list(jobs)

            def fire(sim: "Simulation", now: float):
                for j in batch:
                    target.submit(j, now)

            self.at(t, fire, name=f"submit x{len(batch)}")
            return

        def fire_lazy(sim: "Simulation", now: float):
            for j in jobs:
                target.submit(j, now)

        self.at(t, fire_lazy, name="submit (lazy)")

    def inject_node_failure(self, t: float, node_name: str | None = None,
                            backend: str | None = None):
        def fire(sim: "Simulation", now: float):
            cluster = (sim.backend(backend).cluster if backend is not None
                       else sim.cluster)
            names = list(cluster.nodes)
            if not names:
                return
            target = node_name or names[
                int(sim.rng.integers(0, len(names)))
            ]
            cluster.fail_node(target, now)

        self.at(t, fire, name="node_failure")

    def inject_slow_workers(self, t: float, frac: float = 0.3,
                            rate: float = 0.2):
        """Degrade a fraction of BUSY workers to `rate` speed (straggling
        nodes: thermal throttling, failing HBM, noisy neighbours)."""

        def fire(sim: "Simulation", now: float):
            busy = [w for w in sim.collector.workers.values() if w.claimed]
            k = max(1, int(len(busy) * frac)) if busy else 0
            idx = sim.rng.permutation(len(busy))[:k]
            for i in idx:
                busy[i].work_rate = rate

        self.at(t, fire, name="slow_workers")

    def inject_pod_preemption(self, t: float, frac: float = 0.5,
                              backend: str | None = None):
        """Spot-style reclaim of a fraction of running provisioner pods —
        across the whole federation, or on one named backend."""

        def fire(sim: "Simulation", now: float):
            if backend is not None:
                sim.backend(backend).reclaim(frac, now, sim.rng)
                return
            pods = sim.cluster_view.running_pods(
                lambda p: p.labels.get("owner") == "prp-provisioner"
            )
            k = max(1, int(len(pods) * frac)) if pods else 0
            idx = sim.rng.permutation(len(pods))[:k]
            by_name = {b.name: b for b in sim.backends}
            for i in idx:
                owner = by_name.get(pods[i].labels.get("backend", ""))
                sim.cluster_view.delete_pod(pods[i].name, now, "preempted")
                if owner is not None:
                    owner.stats.pods_reclaimed += 1

        self.at(t, fire, name="pod_preemption")

    # -- main loop --------------------------------------------------------------
    def step(self):
        """Advance one tick's worth of simulated time (compat shim; the
        event engine fires every event in (now, now+tick_s] exactly)."""
        if self.engine == "tick":
            self._step_tick()
        else:
            self.run(self.now + self.tick_s)

    def _step_tick(self):
        """The seed's fixed-tick loop, kept verbatim as the benchmark
        baseline: O(events) scan, per-job negotiation, drifting cadences,
        tick-quantized event firing."""
        now, dt = self.now, self.tick_s

        # 1. external events (fire up to tick_s late; see event engine)
        due = [e for e in self.events if e.at <= now]
        self.events = [e for e in self.events if e.at > now]
        for e in sorted(due, key=lambda e: e.at):
            e.fn(self, now)

        # 2. provisioner
        self.provisioner.maybe_reconcile(now)

        # 3. backends: autoscale, schedule, account (C7 + §5).  The seed
        #    integrated [now, now+dt] forward; with lazy accounting that
        #    means bringing the integrals up to the interval END.
        for backend in self.backends:
            backend.tick(now, dt)
            backend.cluster.tick_accounting(0.0, now + dt)

        # 4. negotiation (last = now accumulates drift when the interval
        #    is not a multiple of tick_s — the event engine fixes this)
        if now - self._last_negotiate >= self.negotiate_interval_s:
            # flocking order, per-queue scans: the tick engine stays the
            # seed's per-job oracle (candidates re-listed per queue so
            # partial capacity carries across schedds via live offers)
            for q in self.queues:
                self.collector.scan_cycle(q, now)
            self._last_negotiate = now

        # 5. workers advance (per-job idle polling, tick-quantized
        #    completions — the seed's exact semantics)
        advance_workers(self.collector, self.pool_queue, self.cluster_view,
                        now, dt, scan_matches=True, exact_completions=False)

        # 5b. straggler mitigation (beyond-paper; see core/stragglers.py)
        if self.straggler_policy is not None:
            self.straggler_policy.tick(self.pool_queue, self.collector,
                                       self.cluster_view, now)

        # 6. metrics
        self._record_cb(now)
        self.now += dt

    def run(self, until: float):
        if self.engine == "tick":
            while self.now < until:
                self._step_tick()
            self._flush_accounting()
            return
        if until <= self.now:
            return
        self.loop.run_until(until, pre=self._advance_to)
        self._advance_to(until)
        self.now = until
        self._flush_accounting()

    def drained(self) -> bool:
        """Every schedd's queue is empty (single-queue: the queue's)."""
        return self.pool_queue.drained()

    def run_until_drained(self, max_t: float = 1e6):
        if self.engine == "tick":
            while ((self.events or not self.drained())
                   and self.now < max_t):
                self._step_tick()
            self._flush_accounting()
            return
        while ((self._external_pending > 0 or not self.drained())
               and self.now < max_t):
            t = self.loop.next_at()
            if t is None or t > max_t:
                self.run(max_t)
                break
            self._advance_to(t)
            self.loop.fire_next()
            self.now = self.loop.now
        self._flush_accounting()

    def _flush_accounting(self):
        """Bring every backend's lazy node integrals AND cost accrual up
        to `self.now` — run()/run_until_drained() can stop between
        backend ticks, and the summary must not read integrals stale by
        a partial tick (or miss the final partial interval's cost)."""
        for b in self.backends:
            b.cluster.tick_accounting(0.0, self.now)
            accrue = getattr(b, "accrue_cost", None)
            if accrue is not None:
                accrue(self.now)

    # -- summaries -----------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        self._flush_accounting()
        out: dict[str, Any] = {}
        completed = (self.queue.completed_log if not self.flocking
                     else [j for q in self.queues
                           for j in q.completed_log])
        out["jobs"] = summarize_jobs(completed, self.now)
        if self.flocking:
            out["schedds"] = {
                q.name: summarize_jobs(q.completed_log, self.now)
                for q in self.queues
            }
            if self.accountant is not None:
                out["fairshare"] = self.accountant.snapshot(self.now)
        out["workers"] = summarize_workers(self.all_workers)
        out["pods_submitted"] = self.provisioner.stats.submitted
        if self.autoscaler is not None:
            out["nodes"] = {
                "provisioned": self.autoscaler.provisioned_total,
                "deprovisioned": self.autoscaler.deprovisioned_total,
                "waste_fraction": self.autoscaler.waste_fraction(),
            }
        cap = busy = 0.0
        for b in self.backends:
            c, u = b.cluster.resource_seconds("gpu")
            cap += c
            busy += u
        out["gpu_utilization"] = busy / cap if cap > 0 else 0.0
        out["cost_total"] = sum(b.stats.cost_total for b in self.backends)
        out["backends"] = summarize_backends(self.backends)
        return out


# ---------------------------------------------------------------------------
# Convenience builders used by benchmarks/examples
# ---------------------------------------------------------------------------

def gpu_job(runtime_s: float, *, gpus: int = 1, cpus: int = 1,
            memory_gb: int = 4, arch: str | None = None,
            checkpoint_interval_s: float | None = None,
            extra_ad: dict | None = None) -> Job:
    ad: dict[str, Any] = {
        "request_cpus": cpus,
        "request_gpus": gpus,
        "request_memory": memory_gb,
        "request_disk": 8,
    }
    if arch is not None:
        ad["arch"] = arch
    if checkpoint_interval_s:
        ad["checkpoint_interval_s"] = checkpoint_interval_s
    ad.update(extra_ad or {})
    return Job(ad=ad, runtime_s=runtime_s)


def onprem_nodes(n: int, *, gpus: int = 8, cpus: int = 64,
                 memory_gb: int = 512, labels: dict | None = None,
                 prefix: str = "onprem") -> list[Node]:
    return [
        Node(
            name=f"{prefix}-{i}",
            capacity={"cpu": cpus, "gpu": gpus, "memory": memory_gb,
                      "disk": 1024},
            labels=dict(labels or {}),
        )
        for i in range(n)
    ]
