"""Event-driven simulation harness wiring all control-plane components.

One `Simulation` owns: JobQueue (schedd), Collector (pool), N
`ScalingBackend`s (each a KubeCluster + optional NodeAutoscaler + cost
model), Provisioner, optional fault injectors, and a Recorder.

The core is a discrete-event `EventLoop` (core/events.py).  Control-plane
activities are periodic callbacks at their EXACT cadence — no tick
quantization, no `last = now` drift:

  priority 0   external events (job arrivals, spot reclaims, failures)
  priority 10  provisioner reconcile, every submit_interval_s — C1/C3/C4
  priority 20  per-backend tick: node autoscaler (C7), kube scheduler
               (priorities/preemption, §5), cost accounting
  priority 30  negotiator matches idle-job cohorts to workers
  priority 40  straggler mitigation (beyond-paper)
  priority 50  metrics sampling (own cadence, decoupled from tick_s)

Between events, continuous state — running jobs, worker busy/alive time —
is integrated lazily: before ANY event fires, `_advance_to(t)` advances
the workers to exactly `t`, so a spot reclaim at t=12.5 sees job progress
up to 12.5 and completions land at their exact finish times (C2 wakeups).

Compatibility: `tick_s`, `step()`, and `run(until)` keep their seed
meaning (a step advances one tick's worth of events).  `engine="tick"`
retains the seed's fixed-tick O(n)-scan loop verbatim — it is the
baseline for benchmarks/bench_event_engine.py and the oracle for
differential tests.

Single-backend compatibility: the seed constructor signature
(`nodes=`, `node_template=`, `max_nodes=`) still works — it is adapted
into a one-element backend list, and `sim.cluster` / `sim.autoscaler`
keep pointing at that backend's internals.  Multi-provider federations
pass `backends=[...]` or use `Simulation.from_config` with a config
declaring `[backend:<name>]` sections.

Multi-schedd flocking: `schedds=N` (or a list of `ScheddSpec`s with
quotas and per-user priority factors) builds N submit-host queues
sharing one pool-unique jid counter, negotiated as ONE cycle in
flocking order (`Collector.run_cycle`); `fairshare=True` (or an
`Accountant`) adds hierarchical fair-share — per-schedd quotas, then
per-user effective priority with usage decay.  The single-queue
construction path is untouched (`sim.queue` keeps meaning the first/
only schedd), matching the backend-adapter compat pattern.

The same Provisioner/Worker code runs under wall-clock in the examples
(launch/train.py elastic mode) — the simulator only replaces the clock and
the job payloads, not the decision logic (paper-faithfulness hinges on
this separation).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.backend import (
    FederatedClusterView, KubeBackend, build_backends,
)
from repro.core.cluster import KubeCluster, Node
from repro.core.config import ProvisionerConfig
from repro.core.events import EventLoop
from repro.core.fairshare import Accountant, ScheddSpec, make_schedd_specs
from repro.core.jobqueue import FlockedQueues, Job, JobQueue
from repro.core.metrics import (
    Recorder, summarize_backends, summarize_jobs, summarize_workers,
)
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate
from repro.core.provisioner import Provisioner
from repro.core.stragglers import StragglerPolicy
from repro.core.worker import (
    Collector, advance_workers, worker_from_state, worker_state,
)
from repro.observability import as_telemetry

# same-timestamp ordering, mirroring the seed's intra-tick sequence
P_EXTERNAL = 0
P_RECONCILE = 10
P_BACKEND = 20
P_NEGOTIATE = 30
P_STRAGGLER = 40
P_METRICS = 50


@dataclasses.dataclass
class TimedEvent:
    at: float
    fn: Callable[["Simulation", float], None]
    name: str = ""


class Simulation:
    def __init__(
        self,
        cfg: ProvisionerConfig,
        *,
        nodes: list[Node] | None = None,
        node_template: NodeTemplate | None = None,
        max_nodes: int = 64,
        backends: list | None = None,
        tick_s: float = 5.0,
        negotiate_interval_s: float = 15.0,
        metrics_interval_s: float | None = None,
        seed: int = 0,
        straggler_policy: StragglerPolicy | None = None,
        engine: str = "event",
        schedds: int | list | None = None,
        fairshare: Accountant | bool | None = None,
        negotiate_quantum: int = 1,
        matchmaker=None,
        negotiation_batch: int | None = None,
        telemetry=None,
    ):
        if engine not in ("event", "tick"):
            raise ValueError(f"unknown engine {engine!r}")
        self.engine = engine
        self.cfg = cfg
        self.tick_s = tick_s
        self.negotiate_interval_s = negotiate_interval_s
        self.metrics_interval_s = metrics_interval_s or tick_s

        # one schedd (the seed signature) or a flocking federation of
        # them — `schedds=N` / `schedds=[ScheddSpec(...), ...]` makes N
        # queues sharing one pool-unique jid counter; `fairshare=True`
        # (or an Accountant) turns on hierarchical fair-share in the
        # negotiation cycle
        self.flocking = schedds is not None or fairshare is not None
        self.negotiate_quantum = negotiate_quantum
        if fairshare and engine == "tick":
            # the tick engine's scan_cycle is the seed oracle and
            # knows nothing of the accountant — silently dropping the
            # configured fair-share would be worse than refusing
            raise ValueError(
                "fairshare requires engine='event' (the tick baseline "
                "negotiates per-job FIFO scans in flocking order only)")
        if self.flocking:
            self.schedd_specs = make_schedd_specs(
                schedds if schedds is not None else 1)
            ids = itertools.count()
            self.queues = [JobQueue(name=s.name, ids=ids)
                           for s in self.schedd_specs]
            if fairshare is True:
                fairshare = Accountant()
            self.accountant = fairshare or None
            if self.accountant is not None:
                for spec, q in zip(self.schedd_specs, self.queues):
                    self.accountant.set_quota(spec.name, spec.quota)
                    for user, f in spec.priority_factors.items():
                        self.accountant.set_priority_factor(user, f)
                    self.accountant.attach_queue(spec.name, q)
            self.pool_queue = FlockedQueues(self.queues)
        else:
            self.schedd_specs = [ScheddSpec(name="schedd")]
            self.queues = [JobQueue()]
            self.accountant = None
            self.pool_queue = self.queues[0]
        self.queue = self.queues[0]
        # negotiation backend: the explicit arg wins, else the INI
        # `[provision] matchmaker=` key (core/matchmaker — "numpy"
        # reference, "jax" jitted, "scan" oracle, or an instance)
        if matchmaker is None:
            matchmaker = getattr(cfg, "matchmaker", None)
        # staged-negotiation capacity: the explicit arg wins, else the
        # INI `[provision] negotiation_batch=` key.  The LIVE engines
        # quiesce every staged cycle immediately (claims feed worker
        # advancement between events, so deferral would break causality)
        # — batch>1 pays off for drivers that legitimately batch, e.g.
        # the streaming service flushing an arrival backlog or the e2e
        # bench (benchmarks/bench_matchmaking.py)
        if negotiation_batch is None:
            negotiation_batch = getattr(cfg, "negotiation_batch", 1)
        # telemetry=True turns on lifecycle spans + the cycle profiler;
        # the metric registry (consolidated counters, pool gauges) is
        # live either way.  Pass a Telemetry instance to share one
        # registry across simulations.
        self.telemetry = as_telemetry(telemetry)
        self.collector = Collector(matchmaker=matchmaker,
                                   negotiation_batch=negotiation_batch,
                                   telemetry=self.telemetry)
        if backends is None:
            # single-backend compatibility adapter (seed signature)
            cluster = KubeCluster(nodes or [])
            autoscaler = (
                NodeAutoscaler(cluster, node_template, max_nodes=max_nodes)
                if node_template is not None else None
            )
            backends = [KubeBackend("default", cluster, autoscaler)]
        self.backends = list(backends)
        # backends drained at runtime move here once empty — kept so
        # their accrued cost / stats stay in summary()
        self.detached_backends: list = []
        self.cluster = self.backends[0].cluster
        self.autoscaler = self.backends[0].autoscaler
        self.cluster_view = FederatedClusterView(self.backends)
        self.provisioner = Provisioner(
            cfg, self.queues, self.collector, self.backends,
            schedd_quotas={s.name: s.quota for s in self.schedd_specs},
        )
        self.straggler_policy = straggler_policy
        self.recorder = Recorder()
        self.events: list[TimedEvent] = []      # tick engine's flat list
        self.now = 0.0
        self._last_negotiate = -1e18            # tick engine (drifts; see
        #                                         event engine for the fix)
        self.rng = np.random.default_rng(seed)
        self.all_workers: list = []  # includes terminated (for accounting)

        # track every worker the provisioner makes
        orig_factory = self.provisioner.worker_factory
        from repro.core.worker import Worker as _W

        def tracking_factory(**kw):
            w = (orig_factory or _W)(**kw)
            self.all_workers.append(w)
            return w

        self.provisioner.worker_factory = tracking_factory

        # span hooks on every queue + scrape-time pool gauges (a no-op
        # shell when telemetry is disabled beyond gauge registration)
        self.telemetry.attach_simulation(self)

        self.loop = EventLoop()
        self._advanced_until = 0.0
        self._external_pending = 0
        # live-fusion deferral horizon: while a negotiation backlog is
        # staged, pre-event advancement is parked up to this time and
        # replayed by flush_staged at the staged timestamps (the
        # collector's advance_hook below).  -inf == nothing deferred.
        self._defer_until = -math.inf
        # every periodic handle is retained by name so runtime
        # reconfiguration (drain_backend) can cancel a backend's timers
        # and restore() can re-install the full set on a fresh loop
        self._timers: dict[str, Any] = {}
        self._backend_timers: dict[str, list] = {}
        if engine == "event":
            self.collector.advance_hook = self._advance_unchecked
            self._install_periodics()

    @staticmethod
    def _next_cadence(t: float, interval: float, first0: float) -> float:
        """First point of the periodic grid ``first0 + k*interval``
        STRICTLY after `t` — restore() re-phases every periodic so a
        resumed run fires them at exactly the timestamps the
        uninterrupted run would have (events at `t` itself already fired
        before a quiescent snapshot)."""
        k = max(0, math.floor((t - first0) / interval + 1e-9) + 1)
        return first0 + k * interval

    def _install_backend_timer(self, backend, *, prime: bool,
                               first: float | None = None):
        """Periodic tick for one backend, with the drain watch built in:
        after each tick, a draining backend with zero live pods is
        detached (claims completed and workers retired — nothing left to
        let finish).  The handles are retained so drain/restore can
        cancel or re-install them."""
        name = backend.name
        handles = []

        def tick(now: float, dt: float, _b=backend):
            _b.tick(now, dt)
            if getattr(_b, "draining", False) and _b.live_pods() == 0:
                self._detach_backend(_b, now)

        if prime:
            # zero-dt priming pass so pods submitted by the first
            # reconcile place immediately (the seed's first tick did)
            handles.append(self.loop.schedule(
                self.loop.now, lambda now: tick(now, 0.0),
                name=f"backend:{name}:prime", priority=P_BACKEND))
        if first is None:
            first = self._next_cadence(self.loop.now, self.tick_s, 0.0)
        handles.append(self.loop.every(
            self.tick_s, lambda now: tick(now, self.tick_s),
            first=first, name=f"backend:{name}", priority=P_BACKEND))
        self._backend_timers[name] = handles

    def _install_periodics(self):
        """Exact-cadence control-plane callbacks (the seed polled these
        every tick, accumulating up to tick_s of drift per period).
        Install ORDER is part of the determinism contract: events landing
        on the same (timestamp, priority) fire in install order, and
        restore() re-installs in this same order."""
        self._timers["reconcile"] = self.provisioner.schedule_on(
            self.loop, first=0.0, priority=P_RECONCILE)
        for backend in self.backends:
            self._install_backend_timer(backend, prime=True)
        self._timers["negotiate"] = self.loop.every(
            self.negotiate_interval_s, self._negotiate_cb,
            first=0.0, name="negotiate", priority=P_NEGOTIATE)
        if self.straggler_policy is not None:
            self._timers["stragglers"] = self.loop.every(
                self.tick_s, self._straggler_cb,
                first=self.tick_s, name="stragglers", priority=P_STRAGGLER)
        self._timers["metrics"] = self.loop.every(
            self.metrics_interval_s, self._record_cb,
            first=0.0, name="metrics", priority=P_METRICS)

    # -- periodic callbacks (event engine) -----------------------------------
    def _negotiate_cb(self, now: float):
        self._last_negotiate = now
        if self.flocking:
            self.collector.run_cycle(
                self.queues, now, accountant=self.accountant,
                quantum=self.negotiate_quantum)
        elif self.collector.negotiation_batch > 1:
            # live backlog fusion: stage this cycle, and DEFER the flush
            # when nothing can observe or change pool state before the
            # next negotiation firing — no event in the window, no
            # completion, no idle-timeout expiry (`_defer_ok`).  The
            # next firing extends the backlog, so negotiation_batch=K
            # engages in live mode; the eventual flush replays worker
            # advancement at the staged timestamps (the collector's
            # advance_hook), keeping claim maps bit-identical to the
            # per-cycle path.  Any veto quiesces in the same instant —
            # exactly the old behavior.
            self.collector.stage_cycle(self.queue, now)
            if self.collector._staged_times and self._defer_ok(now):
                h = self._timers["negotiate"]
                self._defer_until = h.first + (h.k + 1) * h.interval
            else:
                self.collector.quiesce()
                self._defer_until = -math.inf
        else:
            self.collector.run_cycle(self.queue, now)

    def _defer_ok(self, now: float) -> bool:
        """May the staged negotiation backlog stay unflushed until the
        next negotiate firing?  Yes only when the window [now, t_next]
        is provably unobservable:

          * no live event fires before the (t_next, P_NEGOTIATE) slot —
            reconciles, backend ticks, stragglers, metrics, external
            injections, and same-instant followers all veto
            (`EventLoop.has_event_before`);
          * no running claim can complete inside the window (capacity
            return would have to be negotiated), and none runs an
            opaque `work_fn`;
          * no worker's idle timeout can expire inside it (C2
            self-termination is a pool change).

        Completion times are computed from `_advanced_until` — claim
        remaining_s is exact as of the last advancement, which deferral
        itself parks — so the check stays exact across chained
        windows."""
        h = self._timers.get("negotiate")
        if h is None or h.cancelled:
            return False
        t_next = h.first + (h.k + 1) * h.interval
        if self.loop.has_event_before(t_next, P_NEGOTIATE):
            return False
        margin = 1e-6
        horizon = t_next + margin
        base = self._advanced_until
        for w in self.collector.workers.values():
            if w.terminated:
                continue
            if w.idle_timeout <= (t_next - now) + margin:
                return False
            if w.claimed:
                for job in w.claimed.values():
                    if job.work_fn is not None:
                        return False
                    rate = w.work_rate
                    need = (job.remaining_s / rate if rate > 0
                            else math.inf)
                    if base + need <= horizon:
                        return False
            elif (not w.draining and w.idle_since >= 0
                    and w.idle_since + w.idle_timeout <= horizon):
                return False
        return True

    def quiesce_negotiation(self) -> int:
        """Flush any deferred negotiation backlog NOW and bring worker
        advancement back up to the current instant — the boundary call
        every external observer goes through (snapshots, runtime
        reconfiguration, service-driver injections, end of run()).
        Returns claims made by the flush."""
        if self.engine != "event":
            return 0
        claims = self.collector.quiesce()
        self._defer_until = -math.inf
        self._advance_unchecked(self.loop.now)
        return claims

    def _straggler_cb(self, now: float):
        self.straggler_policy.tick(self.pool_queue, self.collector,
                                   self.cluster_view, now)

    def _record_cb(self, now: float):
        self.recorder.record(
            now,
            idle_jobs=self.pool_queue.n_idle(),
            running_jobs=self.pool_queue.n_running(),
            pending_pods=len(self.cluster_view.pending_pods()),
            running_pods=len(self.cluster_view.running_pods()),
            ready_workers=len(self.collector.alive_workers(now)),
            busy_workers=sum(
                1 for w in self.collector.workers.values() if w.claimed
            ),
            live_nodes=sum(len(b.cluster.nodes) for b in self.backends),
            idle_cohorts=self.pool_queue.n_idle_cohorts(),
            provisioned_cores=sum(
                n.capacity.get("cpu", 0)
                for b in self.backends for n in b.cluster.nodes.values()
            ),
            cost_rate=sum(b.cost_rate() for b in self.backends),
        )
        if len(self.backends) > 1:
            for b in self.backends:
                self.recorder.record_backend(
                    now, b.name,
                    pending_pods=b.pending(None),
                    live_pods=b.live_pods(),
                    live_nodes=len(b.cluster.nodes),
                    cost_rate=b.cost_rate(),
                )
        if self.flocking:
            self._record_flocking(now)

    def _record_flocking(self, now: float):
        """Per-schedd and per-user fair-share gauges (idle, running,
        effective priority, starvation age) — the Fig 2/3-style series
        split by community that the compare harness surfaces."""
        deficits = self.provisioner.stats.per_schedd_deficit
        # per-user gauges are aggregated across schedds (users are
        # pool-global in the accountant, as in HTCondor)
        idle_u: dict[str, tuple[int, float]] = {}
        running_u: dict[str, int] = {}
        for q in self.queues:
            self.recorder.record_schedd(
                now, q.name,
                idle_jobs=q.n_idle(),
                running_jobs=q.n_running(),
                deficit=deficits.get(q.name, 0),
            )
            for user, (n, age) in q.idle_by_user(now).items():
                pn, page = idle_u.get(user, (0, 0.0))
                idle_u[user] = (pn + n, max(page, age))
            for user, n in q.running_by_user.items():
                running_u[user] = running_u.get(user, 0) + n
        for user in sorted(set(idle_u) | set(running_u)):
            n, age = idle_u.get(user, (0, 0.0))
            gauges = {
                "idle_jobs": n,
                "running_jobs": running_u.get(user, 0),
                "starvation_age_s": age,
            }
            if self.accountant is not None:
                gauges["effective_priority"] = (
                    self.accountant.effective_priority(user, now))
            self.recorder.record_user(now, user, **gauges)

    def _advance_to(self, t: float):
        """Integrate continuous state (running jobs, worker clocks) up to
        exactly `t` — called before every event fires.  While a
        negotiation backlog is deferred (staged cycles pending and `t`
        inside the armed horizon) advancement is parked: `flush_staged`
        replays it segment-by-segment at the staged timestamps through
        `Collector.advance_hook`, reproducing the per-cycle run's exact
        advancement boundaries."""
        if self.collector._staged_times:
            if t <= self._defer_until + 1e-9:
                return
            # horizon overrun (should not happen: _defer_ok vetoes any
            # event inside the window) — flush before advancing past it
            self.collector.quiesce()
        self._advance_unchecked(t)

    def _advance_unchecked(self, t: float):
        if t <= self._advanced_until:
            return
        dt = t - self._advanced_until
        advance_workers(self.collector, self.pool_queue, self.cluster_view,
                        self._advanced_until, dt)
        self._advanced_until = t

    @classmethod
    def from_config(cls, cfg: ProvisionerConfig, **kw) -> "Simulation":
        """Build the federation declared by `[backend:<name>]` sections;
        falls back to the single-backend constructor when none exist."""
        if cfg.backends and "backends" not in kw:
            kw["backends"] = build_backends(cfg)
        return cls(cfg, **kw)

    def backend(self, name: str):
        return self.provisioner.backend(name)

    # -- runtime reconfiguration (pool service) ------------------------------
    def drain_backend(self, name: str):
        """Gracefully retire a backend without restarting the pool: stop
        routing to it (healthy() goes False), delete its never-placed
        pending pods, and flag its booted workers `draining` so they take
        no new claims and retire the moment their running jobs complete.
        The backend's periodic tick keeps firing until `live_pods()`
        reaches zero, then `_detach_backend` freezes its accounting and
        cancels its timers.  Event engine only."""
        if self.engine != "event":
            raise ValueError("drain_backend requires engine='event'")
        self.quiesce_negotiation()  # staged cycles see the pre-drain pool
        b = self.provisioner.backend(name)      # KeyError on unknown
        b.draining = True
        now = self.loop.now
        owned = lambda p: p.labels.get("owner") == "prp-provisioner"
        for pod in list(b.cluster.pending_pods(owned)):
            # pending pods never placed — nothing is running on them
            b.cluster.delete_pod(pod.name, now, "drain")
        running = {p.name for p in b.cluster.running_pods(owned)}
        for w in self.collector.workers.values():
            if w.pod_name in running:
                w.draining = True
        if b.live_pods() == 0:
            self._detach_backend(b, now)

    def _detach_backend(self, b, now: float):
        """Remove an emptied, draining backend from the live federation:
        flush its accounting to `now` (cost accrual FREEZES here — a
        detached backend bills nothing further), cancel its tick timers,
        and move it to `detached_backends` so summary() still counts its
        accrued cost, node-seconds, and stats."""
        b.cluster.tick_accounting(0.0, now)
        accrue = getattr(b, "accrue_cost", None)
        if accrue is not None:
            accrue(now)
        for h in self._backend_timers.pop(b.name, []):
            self.loop.cancel(h)
        self.backends.remove(b)
        if b in self.provisioner.backends:
            self.provisioner.backends.remove(b)
        if b in self.cluster_view.backends:
            self.cluster_view.backends.remove(b)
        self.detached_backends.append(b)

    def add_backend(self, backend):
        """Attach a new resource provider at runtime.  Its periodic tick
        lands on the same global tick grid as the original backends (next
        multiple of tick_s), preceded by a zero-dt priming pass so the
        next reconcile's pods place immediately.  Cost accrual and node
        alive-time start at attach, not at the epoch."""
        if self.engine != "event":
            raise ValueError("add_backend requires engine='event'")
        self.quiesce_negotiation()
        taken = ({b.name for b in self.backends}
                 | {b.name for b in self.detached_backends})
        if backend.name in taken:
            raise ValueError(f"backend {backend.name!r} already exists")
        rebase = getattr(backend, "rebase", None)
        if rebase is not None:
            rebase(self.loop.now)
        self.backends.append(backend)
        self.provisioner.backends.append(backend)
        self.cluster_view.backends.append(backend)
        self._install_backend_timer(backend, prime=True)

    def add_schedd(self, name: str, *, quota: float = 1.0):
        """Attach a new submit host at runtime (flocking pools only).
        The queue shares the pool-unique jid counter, joins the flocking
        negotiation order LAST, and gets a fair-share quota if an
        accountant is wired."""
        if not self.flocking:
            raise ValueError(
                "add_schedd requires a flocking simulation "
                "(construct with schedds=... or fairshare=...)")
        if any(q.name == name for q in self.queues):
            raise ValueError(f"schedd {name!r} already exists")
        self.quiesce_negotiation()  # flocking order changes below
        q = JobQueue(name=name, ids=self.queues[0]._ids)
        self.queues.append(q)
        self.pool_queue.queues.append(q)
        self.provisioner.attach_queue(q)
        self.provisioner.schedd_quotas[name] = quota
        if self.accountant is not None:
            self.accountant.set_quota(name, quota)
            self.accountant.attach_queue(name, q)
        self.schedd_specs.append(ScheddSpec(name=name, quota=quota))
        self.telemetry.attach_queue(q)
        return q

    def drain_schedd(self, name: str):
        """Stop accepting submissions on one schedd; its queued and
        running jobs keep negotiating and complete normally.  Call
        `detach_schedd` once it has fully drained."""
        self.quiesce_negotiation()
        self.queue_named(name).draining = True

    def detach_schedd(self, name: str):
        """Remove a drained, empty schedd from the federation.  The
        accountant keeps its historical usage (decayed as usual)."""
        q = self.queue_named(name)
        if not q.draining:
            raise ValueError(f"schedd {name!r} is not draining")
        if not q.drained():
            raise ValueError(f"schedd {name!r} still has jobs")
        if len(self.queues) == 1:
            raise ValueError("cannot detach the last schedd")
        self.quiesce_negotiation()
        self.queues.remove(q)
        self.pool_queue.queues.remove(q)
        self.provisioner.detach_queue(q)
        self.provisioner.schedd_quotas.pop(name, None)
        self.schedd_specs = [s for s in self.schedd_specs
                             if s.name != name]
        self.queue = self.queues[0]
        self.provisioner.queue = self.provisioner.queues[0]

    # -- snapshot / resume ---------------------------------------------------
    def state_dict(self, *, allow_pending_external: bool = False) -> dict:
        """Serialize the COMPLETE pool state as a JSON-safe dict, such
        that `restore()` on a freshly constructed, identically configured
        Simulation continues bit-identically to the uninterrupted run.

        Iteration orders are state here (advertise order drives
        advance_workers, node order breaks best-fit ties, cohort order
        drives negotiation FIFO) — every dict below is serialized in its
        live order and rebuilt by insertion, never recomputed or sorted.

        Requires a QUIESCENT instant: every event at `self.now` has
        fired (run()/the service driver guarantee this between timestamp
        groups).  Periodic timers are NOT serialized — restore()
        re-installs them re-phased onto their original grids.  External
        events scheduled via `at()` cannot be serialized (arbitrary
        closures); callers owning such events as data — the pool service
        keeps its pending arrivals as trace records — pass
        `allow_pending_external=True` and re-schedule them after
        restore().  Straggler-policy internal memory is not carried."""
        if self.engine != "event":
            raise ValueError("state_dict requires engine='event'")
        self.quiesce_negotiation()  # staged cycles are not serializable
        if self._external_pending > 0 and not allow_pending_external:
            raise ValueError(
                f"{self._external_pending} external event(s) still "
                "pending — their closures cannot be serialized; either "
                "run past them or pass allow_pending_external=True and "
                "re-schedule them after restore()")
        nxt = self.loop.next_at()
        if nxt is not None and nxt <= self.now:
            raise ValueError(
                f"snapshot requires a quiescent instant: events still "
                f"due at t={nxt} (now={self.now})")
        self._flush_accounting()
        # peek the shared jid counter non-destructively
        next_jid = next(self.queues[0]._ids)
        shared = itertools.count(next_jid)
        for q in self.queues:
            q._ids = shared
        state: dict[str, Any] = {
            "version": 1,
            "t": self.now,
            "flocking": self.flocking,
            "next_jid": next_jid,
            "schedds": [{"name": s.name, "quota": s.quota}
                        for s in self.schedd_specs],
            "queues": [q.state_dict() for q in self.queues],
            "accountant": (self.accountant.state_dict()
                           if self.accountant is not None else None),
            "workers": [worker_state(w) for w in self.all_workers],
            "advertised": list(self.collector.workers.keys()),
            "backends": [b.state_dict() for b in self.backends],
            "detached_backends": [b.state_dict()
                                  for b in self.detached_backends],
            "provisioner": self.provisioner.state_dict(),
            "recorder": {
                "series": {k: [[t, v] for t, v in pts]
                           for k, pts in self.recorder.series.items()},
                "last_sample": self.recorder._last_sample,
                "sample_interval_s": self.recorder.sample_interval_s,
            },
            "rng": self.rng.bit_generator.state,
            "last_negotiate": self._last_negotiate,
        }
        if self.telemetry.enabled:
            # registry values + lifecycle event log (sim-time data);
            # the profiler's wall-clock cycle log intentionally resets
            # on restore (see Telemetry.state_dict).  The key is absent
            # for telemetry-disabled sims, so their snapshots are
            # byte-identical to pre-telemetry ones.
            state["telemetry"] = self.telemetry.state_dict()
        return state

    def restore(self, state: dict):
        """Load a `state_dict()` snapshot into this freshly constructed
        Simulation (same config, same constructor arguments; schedds
        added at runtime before the snapshot are re-created here, but
        runtime-added BACKENDS must be `add_backend`ed by the caller
        first — the pool service does this from its stored config).  A
        fresh EventLoop is started at the snapshot time and every
        periodic is re-installed, in original install order, re-phased
        onto its original cadence grid."""
        if self.engine != "event":
            raise ValueError("restore requires engine='event'")
        if self.now != 0.0 or self.all_workers:
            raise ValueError(
                "restore() requires a freshly constructed Simulation")
        if bool(state["flocking"]) != self.flocking:
            raise ValueError("flocking mismatch between snapshot and sim")

        # schedds: re-create runtime-added ones, then validate order
        specs = state["schedds"]
        for spec in specs[len(self.queues):]:
            self.add_schedd(spec["name"],
                            quota=float(spec.get("quota", 1.0)))
        names = [q.name for q in self.queues]
        if names != [s["name"] for s in specs]:
            raise ValueError(
                f"schedd mismatch: snapshot has "
                f"{[s['name'] for s in specs]}, sim has {names}")

        shared = itertools.count(int(state["next_jid"]))
        for q, qs in zip(self.queues, state["queues"]):
            q._ids = shared
            q.load_state(qs)
        jobs_by_jid = {j.jid: j
                       for q in self.queues for j in q._jobs.values()}

        acc_state = state.get("accountant")
        if (acc_state is None) != (self.accountant is None):
            raise ValueError(
                "accountant presence mismatch between snapshot and sim")
        if acc_state is not None:
            self.accountant.restore(acc_state)

        self.all_workers = [worker_from_state(ws, jobs_by_jid)
                            for ws in state["workers"]]
        by_name = {w.name: w for w in self.all_workers}
        self.collector.workers = {n: by_name[n]
                                  for n in state["advertised"]}

        live = {b.name: b for b in self.backends}
        for bs in state["backends"]:
            b = live.get(bs["name"])
            if b is None:
                raise ValueError(
                    f"snapshot backend {bs['name']!r} not present — "
                    "add_backend() it before restore()")
            b.load_state(bs)
        for ds in state["detached_backends"]:
            b = live.get(ds["name"])
            if b is None:
                raise ValueError(
                    f"snapshot detached backend {ds['name']!r} not "
                    "present — add_backend() it before restore()")
            b.load_state(ds)
            self.backends.remove(b)
            self.provisioner.backends.remove(b)
            self.cluster_view.backends.remove(b)
            self.detached_backends.append(b)
        want = [bs["name"] for bs in state["backends"]]
        have = [b.name for b in self.backends]
        if have != want:
            raise ValueError(
                f"backend order mismatch: snapshot {want}, sim {have}")

        self.provisioner.load_state(state["provisioner"])
        self.provisioner.rewire_pods(by_name)

        rec = state["recorder"]
        self.recorder.series = {
            k: [(float(t), float(v)) for t, v in pts]
            for k, pts in rec["series"].items()}
        self.recorder._last_sample = float(rec["last_sample"])
        if rec.get("sample_interval_s") is not None:
            self.recorder.sample_interval_s = rec["sample_interval_s"]

        self.rng.bit_generator.state = state["rng"]
        self._last_negotiate = float(state["last_negotiate"])

        tel_state = state.get("telemetry")
        if tel_state is not None and self.telemetry.enabled:
            self.telemetry.load_state(tel_state)

        t = float(state["t"])
        self.loop = EventLoop(t)
        self.now = t
        self._advanced_until = t
        self._defer_until = -math.inf   # snapshots are quiescent
        self._external_pending = 0
        self._timers = {}
        self._backend_timers = {}
        self._reinstall_periodics_at(t)
        return self

    def _reinstall_periodics_at(self, t: float):
        """Re-install every periodic on a fresh loop, re-phased onto its
        ORIGINAL grid (reconcile/negotiate/metrics anchored at 0,
        backends on the tick grid, stragglers offset one tick), in the
        same order as `_install_periodics` — same-(t, priority) firing
        order is part of the determinism contract."""
        self._timers["reconcile"] = self.provisioner.schedule_on(
            self.loop,
            first=self._next_cadence(t, self.cfg.submit_interval_s, 0.0),
            priority=P_RECONCILE)
        for backend in self.backends:
            self._install_backend_timer(backend, prime=False)
        self._timers["negotiate"] = self.loop.every(
            self.negotiate_interval_s, self._negotiate_cb,
            first=self._next_cadence(t, self.negotiate_interval_s, 0.0),
            name="negotiate", priority=P_NEGOTIATE)
        if self.straggler_policy is not None:
            self._timers["stragglers"] = self.loop.every(
                self.tick_s, self._straggler_cb,
                first=self._next_cadence(t, self.tick_s, self.tick_s),
                name="stragglers", priority=P_STRAGGLER)
        self._timers["metrics"] = self.loop.every(
            self.metrics_interval_s, self._record_cb,
            first=self._next_cadence(t, self.metrics_interval_s, 0.0),
            name="metrics", priority=P_METRICS)

    # -- event helpers -------------------------------------------------------
    def at(self, t: float, fn: Callable[["Simulation", float], None],
           name: str = ""):
        """Schedule an external event; under the event engine it fires at
        EXACTLY `t` (the seed fired it at the first tick >= t).  A time
        at or before `now` fires as soon as the clock next advances —
        the seed accepted late events the same way."""
        if self.engine == "tick":
            self.events.append(TimedEvent(t, fn, name))
            return
        self._external_pending += 1

        def fire(now: float):
            self._external_pending -= 1
            fn(self, now)

        self.loop.schedule(max(t, self.loop.now), fire, name=name,
                           priority=P_EXTERNAL)

    def queue_named(self, schedd: str | int | None) -> JobQueue:
        """Resolve a schedd by name or flocking index (None: first)."""
        if schedd is None:
            return self.queue
        if isinstance(schedd, int):
            return self.queues[schedd]
        for q in self.queues:
            if getattr(q, "name", None) == schedd:
                return q
        raise KeyError(f"no schedd named {schedd!r}; "
                       f"have {[q.name for q in self.queues]}")

    def submit_jobs(self, t: float, jobs: Iterable[Job],
                    schedd: str | int | None = None):
        """Submit a batch at time `t`, to one schedd's queue (`schedd`
        names or indexes it; default: the first/only queue).  Lists/
        tuples are counted up front (for the event name); any OTHER
        iterable — a generator, a streaming trace reader — is kept lazy
        and only drawn when the event fires, so scheduling a 100k-job
        campaign materializes zero `Job` objects until its arrival time
        (workload/replay.py spreads the draw across many events).  Lazy
        iterables are consumed exactly once: re-running the simulation
        needs a fresh one."""
        target = self.queue_named(schedd)
        if getattr(target, "draining", False):
            raise ValueError(
                f"schedd {target.name!r} is draining and accepts no "
                "new submissions")
        if isinstance(jobs, (list, tuple)):
            batch = list(jobs)

            def fire(sim: "Simulation", now: float):
                for j in batch:
                    target.submit(j, now)

            self.at(t, fire, name=f"submit x{len(batch)}")
            return

        def fire_lazy(sim: "Simulation", now: float):
            for j in jobs:
                target.submit(j, now)

        self.at(t, fire_lazy, name="submit (lazy)")

    def inject_node_failure(self, t: float, node_name: str | None = None,
                            backend: str | None = None):
        def fire(sim: "Simulation", now: float):
            cluster = (sim.backend(backend).cluster if backend is not None
                       else sim.cluster)
            names = list(cluster.nodes)
            if not names:
                return
            target = node_name or names[
                int(sim.rng.integers(0, len(names)))
            ]
            cluster.fail_node(target, now)

        self.at(t, fire, name="node_failure")

    def inject_slow_workers(self, t: float, frac: float = 0.3,
                            rate: float = 0.2):
        """Degrade a fraction of BUSY workers to `rate` speed (straggling
        nodes: thermal throttling, failing HBM, noisy neighbours)."""

        def fire(sim: "Simulation", now: float):
            busy = [w for w in sim.collector.workers.values() if w.claimed]
            k = max(1, int(len(busy) * frac)) if busy else 0
            idx = sim.rng.permutation(len(busy))[:k]
            for i in idx:
                busy[i].work_rate = rate

        self.at(t, fire, name="slow_workers")

    def inject_pod_preemption(self, t: float, frac: float = 0.5,
                              backend: str | None = None):
        """Spot-style reclaim of a fraction of running provisioner pods —
        across the whole federation, or on one named backend."""

        def fire(sim: "Simulation", now: float):
            if backend is not None:
                sim.backend(backend).reclaim(frac, now, sim.rng)
                return
            pods = sim.cluster_view.running_pods(
                lambda p: p.labels.get("owner") == "prp-provisioner"
            )
            k = max(1, int(len(pods) * frac)) if pods else 0
            idx = sim.rng.permutation(len(pods))[:k]
            by_name = {b.name: b for b in sim.backends}
            for i in idx:
                owner = by_name.get(pods[i].labels.get("backend", ""))
                sim.cluster_view.delete_pod(pods[i].name, now, "preempted")
                if owner is not None:
                    owner.stats.pods_reclaimed += 1

        self.at(t, fire, name="pod_preemption")

    # -- main loop --------------------------------------------------------------
    def step(self):
        """Advance one tick's worth of simulated time (compat shim; the
        event engine fires every event in (now, now+tick_s] exactly)."""
        if self.engine == "tick":
            self._step_tick()
        else:
            self.run(self.now + self.tick_s)

    def _step_tick(self):
        """The seed's fixed-tick loop, kept verbatim as the benchmark
        baseline: O(events) scan, per-job negotiation, drifting cadences,
        tick-quantized event firing."""
        now, dt = self.now, self.tick_s

        # 1. external events (fire up to tick_s late; see event engine)
        due = [e for e in self.events if e.at <= now]
        self.events = [e for e in self.events if e.at > now]
        for e in sorted(due, key=lambda e: e.at):
            e.fn(self, now)

        # 2. provisioner
        self.provisioner.maybe_reconcile(now)

        # 3. backends: autoscale, schedule, account (C7 + §5).  The seed
        #    integrated [now, now+dt] forward; with lazy accounting that
        #    means bringing the integrals up to the interval END.
        for backend in self.backends:
            backend.tick(now, dt)
            backend.cluster.tick_accounting(0.0, now + dt)

        # 4. negotiation (last = now accumulates drift when the interval
        #    is not a multiple of tick_s — the event engine fixes this)
        if now - self._last_negotiate >= self.negotiate_interval_s:
            # flocking order, per-queue scans: the tick engine stays the
            # seed's per-job oracle (candidates re-listed per queue so
            # partial capacity carries across schedds via live offers)
            for q in self.queues:
                self.collector.scan_cycle(q, now)
            self._last_negotiate = now

        # 5. workers advance (per-job idle polling, tick-quantized
        #    completions — the seed's exact semantics)
        advance_workers(self.collector, self.pool_queue, self.cluster_view,
                        now, dt, scan_matches=True, exact_completions=False)

        # 5b. straggler mitigation (beyond-paper; see core/stragglers.py)
        if self.straggler_policy is not None:
            self.straggler_policy.tick(self.pool_queue, self.collector,
                                       self.cluster_view, now)

        # 6. metrics
        self._record_cb(now)
        self.now += dt

    def run(self, until: float):
        if self.engine == "tick":
            while self.now < until:
                self._step_tick()
            self._flush_accounting()
            return
        if until <= self.now:
            return
        self.loop.run_until(until, pre=self._advance_to)
        # a deferred negotiation backlog must not outlive the run call:
        # callers observe state between runs
        self.quiesce_negotiation()
        self._advance_unchecked(until)
        self.now = until
        self._flush_accounting()

    def drained(self) -> bool:
        """Every schedd's queue is empty (single-queue: the queue's)."""
        return self.pool_queue.drained()

    def run_until_drained(self, max_t: float = 1e6):
        if self.engine == "tick":
            while ((self.events or not self.drained())
                   and self.now < max_t):
                self._step_tick()
            self._flush_accounting()
            return
        while ((self._external_pending > 0 or not self.drained())
               and self.now < max_t):
            t = self.loop.next_at()
            if t is None or t > max_t:
                self.run(max_t)
                break
            self._advance_to(t)
            self.loop.fire_next()
            self.now = self.loop.now
        self.quiesce_negotiation()
        self._flush_accounting()

    def _flush_accounting(self):
        """Bring every backend's lazy node integrals AND cost accrual up
        to `self.now` — run()/run_until_drained() can stop between
        backend ticks, and the summary must not read integrals stale by
        a partial tick (or miss the final partial interval's cost)."""
        for b in self.backends:
            b.cluster.tick_accounting(0.0, self.now)
            accrue = getattr(b, "accrue_cost", None)
            if accrue is not None:
                accrue(self.now)

    # -- telemetry exporters -------------------------------------------------
    def prometheus_text(self) -> str:
        """Prometheus text exposition of the pool registry (the service
        tier serves this at GET /metrics.prom).  Works with telemetry
        disabled too — pool gauges and consolidated cache counters are
        always live; spans/profiler series appear when enabled."""
        return self.telemetry.prometheus_text()

    def dump_trace(self, path: str) -> int:
        """Write Chrome trace-event JSON (Perfetto / chrome://tracing)
        of lifecycle spans + negotiation/reconcile phases.  Requires
        telemetry=True.  Returns the number of trace events written."""
        return self.telemetry.dump_trace(path)

    # -- summaries -----------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        self._flush_accounting()
        out: dict[str, Any] = {}
        completed = (self.queue.completed_log if not self.flocking
                     else [j for q in self.queues
                           for j in q.completed_log])
        out["jobs"] = summarize_jobs(completed, self.now)
        if self.flocking:
            out["schedds"] = {
                q.name: summarize_jobs(q.completed_log, self.now)
                for q in self.queues
            }
            if self.accountant is not None:
                out["fairshare"] = self.accountant.snapshot(self.now)
        out["workers"] = summarize_workers(self.all_workers)
        out["pods_submitted"] = self.provisioner.stats.submitted
        if self.autoscaler is not None:
            out["nodes"] = {
                "provisioned": self.autoscaler.provisioned_total,
                "deprovisioned": self.autoscaler.deprovisioned_total,
                "waste_fraction": self.autoscaler.waste_fraction(),
            }
        # detached (drained) backends stopped accruing at detach but
        # their history still counts toward utilization and spend
        every = self.backends + self.detached_backends
        cap = busy = 0.0
        for b in every:
            c, u = b.cluster.resource_seconds("gpu")
            cap += c
            busy += u
        out["gpu_utilization"] = busy / cap if cap > 0 else 0.0
        out["cost_total"] = sum(b.stats.cost_total for b in every)
        out["backends"] = summarize_backends(every)
        return out


# ---------------------------------------------------------------------------
# Convenience builders used by benchmarks/examples
# ---------------------------------------------------------------------------

def gpu_job(runtime_s: float, *, gpus: int = 1, cpus: int = 1,
            memory_gb: int = 4, arch: str | None = None,
            checkpoint_interval_s: float | None = None,
            extra_ad: dict | None = None) -> Job:
    ad: dict[str, Any] = {
        "request_cpus": cpus,
        "request_gpus": gpus,
        "request_memory": memory_gb,
        "request_disk": 8,
    }
    if arch is not None:
        ad["arch"] = arch
    if checkpoint_interval_s:
        ad["checkpoint_interval_s"] = checkpoint_interval_s
    ad.update(extra_ad or {})
    return Job(ad=ad, runtime_s=runtime_s)


def onprem_nodes(n: int, *, gpus: int = 8, cpus: int = 64,
                 memory_gb: int = 512, labels: dict | None = None,
                 prefix: str = "onprem") -> list[Node]:
    return [
        Node(
            name=f"{prefix}-{i}",
            capacity={"cpu": cpus, "gpu": gpus, "memory": memory_gb,
                      "disk": 1024},
            labels=dict(labels or {}),
        )
        for i in range(n)
    ]
