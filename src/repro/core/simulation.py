"""Discrete-time simulation harness wiring all control-plane components.

One `Simulation` owns: JobQueue (schedd), Collector (pool), N
`ScalingBackend`s (each a KubeCluster + optional NodeAutoscaler + cost
model), Provisioner, optional fault injectors, and a Recorder.
`run(until)` advances in fixed ticks; each tick:

  1. external events (job arrivals, spot reclaims) fire
  2. provisioner reconciles (at its own interval)  — C1/C3/C4
  3. each backend ticks: node autoscaler (C7), kube scheduler
     (priorities/preemption, §5), cost accounting
  4. negotiator matches idle jobs to ready workers
  5. workers advance claimed jobs; self-terminate when idle — C2
  6. metrics are recorded (aggregate + per-backend series)

Single-backend compatibility: the seed constructor signature
(`nodes=`, `node_template=`, `max_nodes=`) still works — it is adapted
into a one-element backend list, and `sim.cluster` / `sim.autoscaler`
keep pointing at that backend's internals.  Multi-provider federations
pass `backends=[...]` or use `Simulation.from_config` with a config
declaring `[backend:<name>]` sections.

The same Provisioner/Worker code runs under wall-clock in the examples
(launch/train.py elastic mode) — the simulator only replaces the clock and
the job payloads, not the decision logic (paper-faithfulness hinges on
this separation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.backend import (
    FederatedClusterView, KubeBackend, build_backends,
)
from repro.core.cluster import KubeCluster, Node
from repro.core.config import ProvisionerConfig
from repro.core.jobqueue import Job, JobQueue
from repro.core.metrics import (
    Recorder, summarize_backends, summarize_jobs, summarize_workers,
)
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate
from repro.core.provisioner import Provisioner
from repro.core.stragglers import StragglerPolicy
from repro.core.worker import Collector, advance_workers


@dataclasses.dataclass
class TimedEvent:
    at: float
    fn: Callable[["Simulation", float], None]
    name: str = ""


class Simulation:
    def __init__(
        self,
        cfg: ProvisionerConfig,
        *,
        nodes: list[Node] | None = None,
        node_template: NodeTemplate | None = None,
        max_nodes: int = 64,
        backends: list | None = None,
        tick_s: float = 5.0,
        negotiate_interval_s: float = 15.0,
        seed: int = 0,
        straggler_policy: StragglerPolicy | None = None,
    ):
        self.cfg = cfg
        self.tick_s = tick_s
        self.negotiate_interval_s = negotiate_interval_s
        self.queue = JobQueue()
        self.collector = Collector()
        if backends is None:
            # single-backend compatibility adapter (seed signature)
            cluster = KubeCluster(nodes or [])
            autoscaler = (
                NodeAutoscaler(cluster, node_template, max_nodes=max_nodes)
                if node_template is not None else None
            )
            backends = [KubeBackend("default", cluster, autoscaler)]
        self.backends = list(backends)
        self.cluster = self.backends[0].cluster
        self.autoscaler = self.backends[0].autoscaler
        self.cluster_view = FederatedClusterView(self.backends)
        self.provisioner = Provisioner(
            cfg, self.queue, self.collector, self.backends
        )
        self.straggler_policy = straggler_policy
        self.recorder = Recorder()
        self.events: list[TimedEvent] = []
        self.now = 0.0
        self._last_negotiate = -1e18
        self.rng = np.random.default_rng(seed)
        self.all_workers: list = []  # includes terminated (for accounting)

        # track every worker the provisioner makes
        orig_factory = self.provisioner.worker_factory
        from repro.core.worker import Worker as _W

        def tracking_factory(**kw):
            w = (orig_factory or _W)(**kw)
            self.all_workers.append(w)
            return w

        self.provisioner.worker_factory = tracking_factory

    @classmethod
    def from_config(cls, cfg: ProvisionerConfig, **kw) -> "Simulation":
        """Build the federation declared by `[backend:<name>]` sections;
        falls back to the single-backend constructor when none exist."""
        if cfg.backends and "backends" not in kw:
            kw["backends"] = build_backends(cfg)
        return cls(cfg, **kw)

    def backend(self, name: str):
        return self.provisioner.backend(name)

    # -- event helpers -------------------------------------------------------
    def at(self, t: float, fn: Callable[["Simulation", float], None],
           name: str = ""):
        self.events.append(TimedEvent(t, fn, name))

    def submit_jobs(self, t: float, jobs: Iterable[Job]):
        jobs = list(jobs)

        def fire(sim: "Simulation", now: float):
            for j in jobs:
                sim.queue.submit(j, now)

        self.at(t, fire, name=f"submit x{len(jobs)}")

    def inject_node_failure(self, t: float, node_name: str | None = None,
                            backend: str | None = None):
        def fire(sim: "Simulation", now: float):
            cluster = (sim.backend(backend).cluster if backend is not None
                       else sim.cluster)
            names = list(cluster.nodes)
            if not names:
                return
            target = node_name or names[
                int(sim.rng.integers(0, len(names)))
            ]
            cluster.fail_node(target, now)

        self.at(t, fire, name="node_failure")

    def inject_slow_workers(self, t: float, frac: float = 0.3,
                            rate: float = 0.2):
        """Degrade a fraction of BUSY workers to `rate` speed (straggling
        nodes: thermal throttling, failing HBM, noisy neighbours)."""

        def fire(sim: "Simulation", now: float):
            busy = [w for w in sim.collector.workers.values() if w.claimed]
            k = max(1, int(len(busy) * frac)) if busy else 0
            idx = sim.rng.permutation(len(busy))[:k]
            for i in idx:
                busy[i].work_rate = rate

        self.at(t, fire, name="slow_workers")

    def inject_pod_preemption(self, t: float, frac: float = 0.5,
                              backend: str | None = None):
        """Spot-style reclaim of a fraction of running provisioner pods —
        across the whole federation, or on one named backend."""

        def fire(sim: "Simulation", now: float):
            if backend is not None:
                sim.backend(backend).reclaim(frac, now, sim.rng)
                return
            pods = sim.cluster_view.running_pods(
                lambda p: p.labels.get("owner") == "prp-provisioner"
            )
            k = max(1, int(len(pods) * frac)) if pods else 0
            idx = sim.rng.permutation(len(pods))[:k]
            by_name = {b.name: b for b in sim.backends}
            for i in idx:
                owner = by_name.get(pods[i].labels.get("backend", ""))
                sim.cluster_view.delete_pod(pods[i].name, now, "preempted")
                if owner is not None:
                    owner.stats.pods_reclaimed += 1

        self.at(t, fire, name="pod_preemption")

    # -- main loop --------------------------------------------------------------
    def step(self):
        now, dt = self.now, self.tick_s

        # 1. external events
        due = [e for e in self.events if e.at <= now]
        self.events = [e for e in self.events if e.at > now]
        for e in sorted(due, key=lambda e: e.at):
            e.fn(self, now)

        # 2. provisioner
        self.provisioner.maybe_reconcile(now)

        # 3. backends: autoscale, schedule, account (C7 + §5)
        for backend in self.backends:
            backend.tick(now, dt)

        # 4. negotiation
        if now - self._last_negotiate >= self.negotiate_interval_s:
            self.collector.negotiate(self.queue, now)
            self._last_negotiate = now

        # 5. workers advance
        advance_workers(self.collector, self.queue, self.cluster_view,
                        now, dt)

        # 5b. straggler mitigation (beyond-paper; see core/stragglers.py)
        if self.straggler_policy is not None:
            self.straggler_policy.tick(self.queue, self.collector,
                                       self.cluster_view, now)

        # 6. metrics
        self.recorder.record(
            now,
            idle_jobs=self.queue.n_idle(),
            running_jobs=self.queue.n_running(),
            pending_pods=len(self.cluster_view.pending_pods()),
            running_pods=len(self.cluster_view.running_pods()),
            ready_workers=len(self.collector.alive_workers(now)),
            busy_workers=sum(
                1 for w in self.collector.workers.values() if w.claimed
            ),
            live_nodes=sum(len(b.cluster.nodes) for b in self.backends),
            cost_rate=sum(b.cost_rate() for b in self.backends),
        )
        if len(self.backends) > 1:
            for b in self.backends:
                self.recorder.record_backend(
                    now, b.name,
                    pending_pods=b.pending(None),
                    live_pods=b.live_pods(),
                    live_nodes=len(b.cluster.nodes),
                    cost_rate=b.cost_rate(),
                )
        self.now += dt

    def run(self, until: float):
        while self.now < until:
            self.step()

    def run_until_drained(self, max_t: float = 1e6):
        while ((self.events or not self.queue.drained())
               and self.now < max_t):
            self.step()

    # -- summaries -----------------------------------------------------------------
    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {}
        out["jobs"] = summarize_jobs(self.queue.completed_log, self.now)
        out["workers"] = summarize_workers(self.all_workers)
        out["pods_submitted"] = self.provisioner.stats.submitted
        if self.autoscaler is not None:
            out["nodes"] = {
                "provisioned": self.autoscaler.provisioned_total,
                "deprovisioned": self.autoscaler.deprovisioned_total,
                "waste_fraction": self.autoscaler.waste_fraction(),
            }
        cap = busy = 0.0
        for b in self.backends:
            c, u = b.cluster.resource_seconds("gpu")
            cap += c
            busy += u
        out["gpu_utilization"] = busy / cap if cap > 0 else 0.0
        out["cost_total"] = sum(b.stats.cost_total for b in self.backends)
        out["backends"] = summarize_backends(self.backends)
        return out


# ---------------------------------------------------------------------------
# Convenience builders used by benchmarks/examples
# ---------------------------------------------------------------------------

def gpu_job(runtime_s: float, *, gpus: int = 1, cpus: int = 1,
            memory_gb: int = 4, arch: str | None = None,
            checkpoint_interval_s: float | None = None,
            extra_ad: dict | None = None) -> Job:
    ad: dict[str, Any] = {
        "request_cpus": cpus,
        "request_gpus": gpus,
        "request_memory": memory_gb,
        "request_disk": 8,
    }
    if arch is not None:
        ad["arch"] = arch
    if checkpoint_interval_s:
        ad["checkpoint_interval_s"] = checkpoint_interval_s
    ad.update(extra_ad or {})
    return Job(ad=ad, runtime_s=runtime_s)


def onprem_nodes(n: int, *, gpus: int = 8, cpus: int = 64,
                 memory_gb: int = 512, labels: dict | None = None,
                 prefix: str = "onprem") -> list[Node]:
    return [
        Node(
            name=f"{prefix}-{i}",
            capacity={"cpu": cpus, "gpu": gpus, "memory": memory_gb,
                      "disk": 1024},
            labels=dict(labels or {}),
        )
        for i in range(n)
    ]
