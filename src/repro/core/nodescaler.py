"""Cloud node auto-scaling (paper §6): the GKE NAP dynamic, simulated.

The pod-level provisioner and the node autoscaler compose in layers: the
provisioner converts HTCondor demand into pending pods; pending pods drive
node provisioning; empty nodes are deprovisioned after a delay.  The paper
observed (Fig 3) prompt node provisioning and "close to the minimum
achievable" deprovisioning waste — unavoidable because several pods share
a node and rarely terminate together.  `waste_fraction()` measures exactly
that: node-resource-seconds carrying zero pods while the node waits out
the scale-down delay (plus bin-packing leftovers).

Node template mirrors the paper's GKE test: 7-GPU nodes, 1-GPU pods.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

from repro.core.cluster import KubeCluster, Node


@dataclasses.dataclass
class NodeTemplate:
    capacity: dict[str, float]
    labels: dict[str, str] = dataclasses.field(default_factory=dict)
    taints: tuple[str, ...] = ()
    provision_delay_s: float = 90.0      # instance boot + kubelet join
    scale_down_delay_s: float = 600.0    # empty-node grace (GKE default ~10m)
    hourly_cost: float = 1.0


class NodeAutoscaler:
    def __init__(self, cluster: KubeCluster, template: NodeTemplate, *,
                 max_nodes: int = 64, prefix: str = "np"):
        self.cluster = cluster
        self.template = template
        self.max_nodes = max_nodes
        self.prefix = prefix
        self._ids = itertools.count()
        self._booting: list[tuple[float, Node]] = []   # (ready_at, node)
        self._empty_since: dict[str, float] = {}
        # accounting for the Fig-3 analogue
        self.node_seconds: float = 0.0
        self.empty_node_seconds: float = 0.0
        self.provisioned_total: int = 0
        self.deprovisioned_total: int = 0

    # -- sizing logic ----------------------------------------------------------
    def _pods_fit_per_node(self, request: dict[str, float]) -> int:
        cap = self.template.capacity
        n = float("inf")
        for k, v in request.items():
            if v > 0:
                n = min(n, cap.get(k, 0) // v)
        return int(n) if n != float("inf") else 0

    @staticmethod
    def _placeable(pod, node: Node) -> bool:
        """Could the scheduler ever put this pod on this node (taints +
        selector, capacity aside)?"""
        for taint in node.taints:
            if taint not in pod.tolerations:
                return False
        for k, want in pod.node_selector.items():
            have = node.labels.get(k)
            if isinstance(want, (list, tuple, set)):
                if have not in want:
                    return False
            elif have != want:
                return False
        return True

    def _nodes_needed(self) -> int:
        """Bin-pack pending pods into node templates (first-fit by count).

        Free capacity on already-live nodes is seeded as pre-existing bins
        so a tick where the scheduler hasn't yet placed freshly-submitted
        pods does NOT boot spurious nodes — only pods that overflow the
        pool's current allocatable headroom count toward new nodes.  A
        seeded bin only absorbs pods the scheduler could actually place
        there (taints/selector respected), so a pod blocked from live
        nodes by affinity still drives a scale-up."""
        pending = self.cluster.pending_pods(
            lambda p: all(
                self.template.capacity.get(k, 0) >= v
                for k, v in p.request.items()
            )
        )
        if not pending:
            return 0
        # pre-existing bins: current allocatable headroom of live nodes
        seeded: list[tuple[dict[str, float], Node]] = []
        for name, node in self.cluster.nodes.items():
            seeded.append((dict(node.allocatable(
                (), used=self.cluster.node_used(name))), node))
        new_bins: list[dict[str, float]] = []
        # greedy first-fit-decreasing over the dominant resource
        for pod in sorted(
            pending,
            key=lambda p: -max(p.request.values() or [0]),
        ):
            placed = False
            for b, node in seeded:
                if (self._placeable(pod, node)
                        and all(b.get(k, 0) >= v
                                for k, v in pod.request.items())):
                    for k, v in pod.request.items():
                        b[k] = b.get(k, 0) - v
                    placed = True
                    break
            if not placed:
                for b in new_bins:
                    if all(b.get(k, 0) >= v
                           for k, v in pod.request.items()):
                        for k, v in pod.request.items():
                            b[k] = b.get(k, 0) - v
                        placed = True
                        break
            if not placed:
                b = dict(self.template.capacity)
                for k, v in pod.request.items():
                    b[k] = b.get(k, 0) - v
                new_bins.append(b)
        return len(new_bins)

    # -- tick --------------------------------------------------------------------
    def tick(self, now: float, dt: float):
        # 1. finish booting nodes
        ready = [x for x in self._booting if x[0] <= now]
        self._booting = [x for x in self._booting if x[0] > now]
        for _, node in ready:
            self.cluster.add_node(node, now)

        # 2. scale up for pending pods (beyond what's already booting)
        need = self._nodes_needed() - len(self._booting)
        live = len([n for n in self.cluster.nodes
                    if n.startswith(self.prefix)]) + len(self._booting)
        for _ in range(max(0, min(need, self.max_nodes - live))):
            node = Node(
                name=f"{self.prefix}-{next(self._ids)}",
                capacity=dict(self.template.capacity),
                labels=dict(self.template.labels),
                taints=self.template.taints,
            )
            self._booting.append((now + self.template.provision_delay_s, node))
            self.provisioned_total += 1

        # 3. scale down empty nodes after the grace period
        for name in list(self.cluster.nodes):
            if not name.startswith(self.prefix):
                continue
            running = self.cluster.pods_on_node(name)
            if running:
                self._empty_since.pop(name, None)
                continue
            since = self._empty_since.setdefault(name, now)
            self.empty_node_seconds += dt
            if now - since >= self.template.scale_down_delay_s:
                self.cluster.remove_node(name, now)
                self._empty_since.pop(name, None)
                self.deprovisioned_total += 1

        # 4. accounting
        n_live = len([n for n in self.cluster.nodes
                      if n.startswith(self.prefix)])
        self.node_seconds += n_live * dt

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe snapshot.  `node_seconds`/`empty_node_seconds`
        integrate at tick granularity and are NOT flushed between ticks,
        so carrying the counters as of the last tick matches an
        uninterrupted run exactly."""
        from repro.core.cluster import node_state
        nid = next(self._ids)
        self._ids = itertools.count(nid)   # non-destructive peek
        return {
            "next_id": nid,
            "booting": [[t, node_state(n)] for t, n in self._booting],
            "empty_since": dict(self._empty_since),
            "node_seconds": self.node_seconds,
            "empty_node_seconds": self.empty_node_seconds,
            "provisioned_total": self.provisioned_total,
            "deprovisioned_total": self.deprovisioned_total,
        }

    def load_state(self, state: dict) -> None:
        from repro.core.cluster import node_from_state
        self._ids = itertools.count(int(state.get("next_id", 0)))
        self._booting = [(float(t), node_from_state(ns))
                         for t, ns in state.get("booting", [])]
        self._empty_since = {k: float(v)
                             for k, v in state.get("empty_since", {}).items()}
        self.node_seconds = float(state.get("node_seconds", 0.0))
        self.empty_node_seconds = float(state.get("empty_node_seconds", 0.0))
        self.provisioned_total = int(state.get("provisioned_total", 0))
        self.deprovisioned_total = int(state.get("deprovisioned_total", 0))

    # -- metrics (Fig 3 analogue) -------------------------------------------------
    def waste_fraction(self) -> float:
        """Empty-node-seconds / total node-seconds."""
        return (self.empty_node_seconds / self.node_seconds
                if self.node_seconds > 0 else 0.0)

    def live_nodes(self) -> int:
        return len([n for n in self.cluster.nodes
                    if n.startswith(self.prefix)])
