"""Discrete-event scheduler: the heap at the heart of the simulation.

The seed harness advanced in fixed ticks and rescanned a flat event list
every tick (O(events) per tick, and anything scheduled between ticks fired
up to ``tick_s`` late).  This module replaces that with a classic
discrete-event loop:

  * `schedule(at, fn)` pushes a one-shot event onto a heapq; events fire
    at their EXACT timestamp, in (time, priority, insertion) order
  * `every(interval, fn)` installs a periodic callback whose k-th firing
    is at ``first + k*interval`` — computed by multiplication, not by
    repeated addition, so neither tick quantization nor float
    accumulation can drift the cadence (the seed's
    ``_last_negotiate = now`` bug)
  * `fire_next()` pops exactly one event so the driver (simulation.py)
    can advance continuous processes — running jobs, accounting — up to
    the event's timestamp before it observes the world

Priorities order same-timestamp events deterministically; the simulation
uses them to reproduce the seed's intra-tick sequence (external events ->
reconcile -> backend ticks -> negotiate -> stragglers -> metrics).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable

EventFn = Callable[[float], None]


class EventHandle:
    """Cancellation token for a scheduled one-shot event."""

    __slots__ = ("at", "name", "cancelled")

    def __init__(self, at: float, name: str = ""):
        self.at = at
        self.name = name
        self.cancelled = False

    def cancel(self):
        self.cancelled = True

    def __repr__(self):
        flag = " cancelled" if self.cancelled else ""
        return f"EventHandle({self.name!r}@{self.at}{flag})"


class PeriodicHandle:
    """A repeating event; firing k lands exactly at ``first + k*interval``."""

    def __init__(self, loop: "EventLoop", interval: float, fn: EventFn, *,
                 first: float = 0.0, name: str = "", priority: int = 0):
        if interval <= 0:
            raise ValueError(f"interval must be positive, got {interval}")
        self.loop = loop
        self.interval = interval
        self.fn = fn
        self.first = first
        self.name = name
        self.priority = priority
        self.k = 0
        self.cancelled = False
        self._handle: EventHandle | None = None
        self._arm()

    @property
    def next_at(self) -> float:
        return self.first + self.k * self.interval

    def _arm(self):
        self._handle = self.loop.schedule(
            self.next_at, self._fire, name=self.name,
            priority=self.priority)

    def _fire(self, now: float):
        if self.cancelled:
            return
        self.fn(now)
        if self.cancelled:      # fn cancelled its own handle: don't re-arm
            return
        self.k += 1
        self._arm()

    def cancel(self):
        self.cancelled = True
        if self._handle is not None:
            self._handle.cancel()


class EventLoop:
    """heapq-based scheduler; the simulation drives it one event at a time."""

    def __init__(self, t0: float = 0.0):
        self.now = t0
        self.fired = 0
        self._heap: list[tuple[float, int, int, EventHandle, EventFn]] = []
        self._seq = itertools.count()

    # -- scheduling ----------------------------------------------------------
    def schedule(self, at: float, fn: EventFn, *, name: str = "",
                 priority: int = 0) -> EventHandle:
        if at < self.now - 1e-9:
            raise ValueError(
                f"cannot schedule {name!r} at {at} in the past "
                f"(now={self.now})")
        handle = EventHandle(at, name)
        heapq.heappush(self._heap, (at, priority, next(self._seq),
                                    handle, fn))
        return handle

    def every(self, interval: float, fn: EventFn, *, first: float = 0.0,
              name: str = "", priority: int = 0) -> PeriodicHandle:
        return PeriodicHandle(self, interval, fn, first=first, name=name,
                              priority=priority)

    def cancel(self, handle: "EventHandle | PeriodicHandle") -> None:
        """Cancel a scheduled one-shot or periodic callback by its
        handle.  The heap entry is dropped lazily (`_skim`), so
        cancellation is O(1); a cancelled periodic never re-arms.  This
        is how a drained backend's poll timers are retired — the
        simulation retains every periodic handle it installs exactly so
        they can be cancelled here (simulation.py `_backend_timers`)."""
        handle.cancel()

    # -- draining ------------------------------------------------------------
    def _skim(self):
        """Drop cancelled events from the top of the heap."""
        while self._heap and self._heap[0][3].cancelled:
            heapq.heappop(self._heap)

    def next_at(self) -> float | None:
        """Timestamp of the earliest live event, or None."""
        self._skim()
        return self._heap[0][0] if self._heap else None

    def has_event_before(self, at: float, priority: int) -> bool:
        """True when any LIVE event would fire strictly before the slot
        ``(at, priority)`` — i.e. its key is lexicographically smaller,
        with a 1e-9 time tolerance so float jitter on equal grids counts
        as "before".  O(heap) scan, no mutation: the negotiation-
        deferral arming check (simulation.py) asks this once per
        candidate window, and ANY intervening event — an external
        submit/failure injection, a reconcile, a backend timer, even a
        same-instant lower-priority follower — vetoes deferring past
        it."""
        for t, prio, _seq, handle, _fn in self._heap:
            if handle.cancelled:
                continue
            if t < at - 1e-9 or (t <= at + 1e-9 and prio < priority):
                return True
        return False

    def fire_next(self) -> float | None:
        """Fire exactly one event at its exact timestamp; returns the
        timestamp, or None when the heap is empty."""
        self._skim()
        if not self._heap:
            return None
        at, _prio, _seq, _handle, fn = heapq.heappop(self._heap)
        self.now = max(self.now, at)
        self.fired += 1
        fn(at)
        return at

    def run_until(self, t_end: float,
                  pre: Callable[[float], None] | None = None) -> int:
        """Fire every event with ``at <= t_end`` in order; `pre(t)` runs
        before each event so continuous state can be integrated up to the
        event's timestamp.  Returns the number of events fired."""
        n = 0
        while True:
            t = self.next_at()
            if t is None or t > t_end:
                break
            if pre is not None:
                pre(t)
            self.fire_next()
            n += 1
        if t_end > self.now:
            self.now = t_end
        return n

    def __len__(self):
        return sum(1 for e in self._heap if not e[3].cancelled)
