"""The auto-scaling provisioning service (paper §2–§3).

Reconciliation loop (C1), run every ``submit_interval_s``:

  1. snapshot idle jobs ACROSS EVERY SCHEDD feeding the pool; keep
     those passing the job filter (C3)
  2. subtract what the next negotiation cycle will absorb anyway: a
     claim-free dry run (`Collector.preview`) of the idle
     cohorts against current free capacity — including partial slots —
     leaves the POST-negotiation idle demand (the old unclaimed-worker
     count double-counted jobs about to match existing capacity)
  3. group the remainder by requirement signature (C4); per group:
     deficit = post-negotiation idle − pending pods of the group
  4. split ``min(deficit, limits)`` across the scaling backends via the
     configured RoutingPolicy; submit pods whose requests equal the
     signature and whose START expression is the pushed-down filter

Flocking: the provisioner serves an ordered list of schedd queues (a
single `JobQueue` still works — it becomes a one-element list, the same
compat pattern as the backend adapter).  Deficits are attributed per
schedd, and when pod-count room is scarce, groups are served by OWED
SHARE — demand weighted by 1/quota of the schedds it came from — rather
than raw idle counts, so an underserved community's demand is
provisioned for first.

Scale-down is NOT here: workers self-terminate when idle (C2, worker.py),
exactly as in the paper ("pods are configured to self-terminate if no user
jobs are waiting").  The provisioner also never deletes pending pods by
default — HTCondor demand is bursty and a pending pod is free; an optional
``cancel_stale_pending_s`` reaps pods pending longer than the horizon
(useful with the node autoscaler off).

Federation (backend API): the provisioner holds an ordered list of
`ScalingBackend`s (see core/backend.py) instead of one hard-wired
`KubeCluster`; passing a bare `KubeCluster` still works and becomes the
single default backend — the paper's original deployment shape.

Anti-affinity convention from the paper's INI (config.py): node_affinity
keys starting with ^ must NOT match.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Callable

from repro.core.backend import (
    KubeBackend, PodSpec, RoutingPolicy, adapt_single_cluster,
    make_routing_policy,
)
from repro.core.cluster import KubeCluster, Pod
from repro.core.config import ProvisionerConfig
from repro.core.groups import (
    GroupSignature, group_jobs, matches_signature, signature_of,
)
from repro.core.jobqueue import JobQueue
from repro.core.worker import Collector, LRUCache, Worker


@dataclasses.dataclass
class ProvisionStats:
    submitted: int = 0
    reaped_pending: int = 0
    per_group_submitted: dict = dataclasses.field(default_factory=dict)
    per_backend_submitted: dict = dataclasses.field(default_factory=dict)
    # post-negotiation idle demand attributed to each schedd at the
    # last reconcile (owed-share routing reads this; so do metrics)
    per_schedd_deficit: dict = dataclasses.field(default_factory=dict)


class Provisioner:
    """One instance per HTCondor pool; federates any number of resource
    providers — the paper's operation mode (a); mode (b) layers a dedicated
    local pool in front (see examples/grid_portal.py)."""

    COHORT_CACHE_MAX = 50_000    # entries; reset-on-full (pure caches)
    PREVIEW_CACHE_MAX = 256      # per-candidate dry-run memo entries

    def __init__(
        self,
        cfg: ProvisionerConfig,
        queue: JobQueue | list | tuple,
        collector: Collector,
        backends: KubeCluster | list | tuple,
        *,
        routing: RoutingPolicy | None = None,
        cancel_stale_pending_s: float | None = None,
        worker_factory: Callable[..., Worker] | None = None,
        schedd_quotas: dict[str, float] | None = None,
        debug_exact_deficits: bool = False,
        telemetry=None,
    ):
        self.cfg = cfg
        # one schedd or a flocking-ordered list of them (compat adapter,
        # mirroring the single-cluster backend adapter)
        self.queues = (list(queue) if isinstance(queue, (list, tuple))
                       else [queue])
        if not self.queues:
            raise ValueError("Provisioner needs at least one queue")
        self.queue = self.queues[0]
        self.schedd_quotas = dict(schedd_quotas or {})
        self.collector = collector
        if isinstance(backends, KubeCluster):
            backends = [adapt_single_cluster(backends)]
        elif not isinstance(backends, (list, tuple)):
            backends = [backends]          # a single ScalingBackend
        self.backends = list(backends)
        if not self.backends:
            raise ValueError("Provisioner needs at least one backend")
        self.routing = routing or make_routing_policy(cfg.routing_policy)
        self.filter = cfg.filter_expr()
        self.start_expr = cfg.start_expr()
        self.cancel_stale_pending_s = cancel_stale_pending_s
        self.worker_factory = worker_factory
        self._ids = itertools.count()
        self._last_run = -1e18
        self.stats = ProvisionStats()
        # per-cohort memoization: the filter verdict and the group
        # signature are pure functions of a cohort's (identical) ads
        self._cohort_filter: dict[tuple, bool] = {}
        self._cohort_sig: dict[tuple, GroupSignature] = {}
        # per-candidate LRU memo over the negotiation dry run: an IDLE
        # pool reconciles every interval against unchanged demand and
        # capacity, and the preview is the expensive half of the pass.
        # Keyed on (per-queue idle fingerprint, ready-worker free-matrix
        # digest): any claim/release/boot/death changes a worker's free
        # vector, any submit/remove changes an idle count, and a
        # cohort-set change bumps idle_version — so a hit implies an
        # identical dry run.  Multi-entry (was: latest-only) so each
        # distinct candidate pool state keeps its own dry run and a
        # state that recurs non-consecutively — an A/B/A claim-release
        # flap, or alternating flocking phases — still hits.
        self._preview_cache = LRUCache(self.PREVIEW_CACHE_MAX)
        # shares the collector's telemetry (one registry per pool)
        # unless explicitly handed its own
        if telemetry is None:
            self.telemetry = collector.telemetry
        else:
            from repro.observability import as_telemetry
            self.telemetry = as_telemetry(telemetry)
        reg = self.telemetry.registry
        self._c_preview_hits = reg.counter(
            "repro_preview_cache_hits_total",
            "Reconciles served by the memoized negotiation dry run")
        self._c_preview_misses = reg.counter(
            "repro_preview_cache_misses_total",
            "Reconciles that re-ran the negotiation dry run")
        # worker free-matrix digest reuse (Worker.free_rev dirty flag):
        # an unclaimed-pool poll costs an int compare per worker, not a
        # vector rebuild + serialization
        self._c_digest_hits = reg.counter(
            "repro_free_digest_hits_total",
            "Worker free-digest polls answered by the free_rev flag")
        self._c_digest_misses = reg.counter(
            "repro_free_digest_misses_total",
            "Worker free-digest polls that rebuilt the vector digest")
        self._preview_s = 0.0     # preview wall accrued this reconcile
        # incremental deficit counters: filtered PRE-preview idle demand
        # per (group signature, schedd), maintained in O(changes) by the
        # queues' idle hooks instead of recounted per reconcile.  Stale
        # until first use and after queue attach/detach or load_state
        # (restores bypass hooks) — then rebuilt once from live cohorts.
        self._inc_counts: dict[GroupSignature, dict[str, int]] = {}
        self._counts_stale = True
        self._idle_hook_of: dict[int, Callable] = {}   # id(queue) -> fn
        for q in self.queues:
            self._register_idle_hook(q)
        #: differential oracle: re-derive deficits with the retired
        #: per-cycle scan on every reconcile and assert equality (debug
        #: flag; the flocking differential suite runs with it on)
        self.debug_exact_deficits = debug_exact_deficits

    # compat properties over the registry counters (the pre-registry int
    # attributes are part of the test surface)
    @property
    def preview_hits(self) -> int:
        return int(self._c_preview_hits.value)

    @property
    def preview_misses(self) -> int:
        return int(self._c_preview_misses.value)

    @property
    def digest_hits(self) -> int:
        return int(self._c_digest_hits.value)

    @property
    def digest_misses(self) -> int:
        return int(self._c_digest_misses.value)

    @property
    def cluster(self) -> KubeCluster:
        """Primary backend's placement surface (single-backend compat)."""
        return self.backends[0].cluster

    def backend(self, name: str):
        for b in self.backends:
            if b.name == name:
                return b
        raise KeyError(name)

    # -- helpers --------------------------------------------------------------
    def _pod_group_label(self, sig: GroupSignature) -> str:
        # stable across processes/restarts (builtin hash() is salted by
        # PYTHONHASHSEED and would orphan pending-pod counts on restart)
        payload = repr(dataclasses.astuple(sig)).encode()
        return f"grp-{hashlib.sha1(payload).hexdigest()[:10]}"

    def _group_pending(self, label: str) -> int:
        return sum(b.pending(label) for b in self.backends)

    def _group_unclaimed(self, sig: GroupSignature) -> int:
        return self.collector.unclaimed_capacity(
            lambda ad: matches_signature(ad, sig)
        )

    def _total_live_pods(self) -> int:
        return sum(b.live_pods() for b in self.backends)

    def _schedd_name(self, qi: int) -> str:
        return getattr(self.queues[qi], "name", None) or f"schedd{qi:02d}"

    def _cohort_ok(self, key, rep) -> bool:
        ok = self._cohort_filter.get(key)
        if ok is None:
            ok = self.filter.evaluate(rep.ad)
            if len(self._cohort_filter) >= self.COHORT_CACHE_MAX:
                # unique-ad workloads: bound the memos (pure caches,
                # safe to drop wholesale) — checked per insertion so
                # one huge pass cannot blow past the cap
                self._cohort_filter.clear()
                self._cohort_sig.clear()
            self._cohort_filter[key] = ok
        return ok

    def _cohort_signature(self, key, rep) -> GroupSignature:
        sig = self._cohort_sig.get(key)
        if sig is None:
            sig = signature_of(rep)
            self._cohort_sig[key] = sig
        return sig

    def _preview_cached(self, now: float) -> list[dict]:
        """Memoized `Collector.preview` dry run (see __init__)."""
        workers = []
        for w in self.collector.workers.values():
            if w.ready(now) and not w.draining:
                # the digest is cached on the worker's claim-set
                # revision (free_rev dirty flag): an unchanged worker
                # costs an int compare, not a vector rebuild + hash
                cached = w._free_digest
                if cached is not None and cached[0] == w.free_rev:
                    self._c_digest_hits.value += 1
                else:
                    self._c_digest_misses.value += 1
                workers.append((w.name, w.free_digest()))
        key = (
            tuple((q.idle_version, q.n_idle()) for q in self.queues),
            tuple(workers),
        )
        cached = self._preview_cache.get(key)
        if cached is not None:
            self._c_preview_hits.value += 1
            return cached
        self._c_preview_misses.value += 1
        prof = self.telemetry.profiler
        t_p0 = prof.now() if prof is not None else 0.0
        previews = self.collector.preview(self.queues, now)
        if prof is not None:
            self._preview_s += prof.now() - t_p0
        self._preview_cache.put(key, previews)
        return previews

    # -- incremental deficit counters (idle hooks) ---------------------------
    def _register_idle_hook(self, q) -> None:
        if not hasattr(q, "add_idle_hook") or id(q) in self._idle_hook_of:
            return
        name = getattr(q, "name", None) or "schedd"

        def on_idle(job, delta: int, *, _name=name):
            if self._counts_stale:
                return          # a full rebuild is already scheduled
            key = job.cohort_key
            if not self._cohort_ok(key, job):
                return
            sig = self._cohort_signature(key, job)
            per = self._inc_counts.setdefault(sig, {})
            n = per.get(_name, 0) + delta
            if n:
                per[_name] = n
            else:
                per.pop(_name, None)
                if not per:
                    self._inc_counts.pop(sig, None)

        q.add_idle_hook(on_idle)
        self._idle_hook_of[id(q)] = on_idle

    def attach_queue(self, q) -> None:
        """Add a schedd queue to the federation at runtime: joins the
        deficit attribution LAST (flocking order) and gets an idle hook
        so the incremental counters keep tracking it."""
        if q not in self.queues:
            self.queues.append(q)
        self._register_idle_hook(q)
        self._counts_stale = True

    def detach_queue(self, q) -> None:
        """Remove a (drained) schedd queue: unhook it so later activity
        on the detached queue cannot leak into the counters."""
        self.queues.remove(q)
        self.queue = self.queues[0]
        fn = self._idle_hook_of.pop(id(q), None)
        if fn is not None and hasattr(q, "_idle_hooks"):
            q._idle_hooks.remove(fn)
        self._counts_stale = True

    def _rebuild_idle_counts(self) -> None:
        """One full recount of the filtered idle demand — only after
        construction, queue attach/detach, or a state restore (all of
        which bypass the hooks).  Every reconcile in between maintains
        the counters in O(idle-set changes)."""
        self._inc_counts = {}
        for qi, q in enumerate(self.queues):
            if not hasattr(q, "idle_cohorts"):
                continue
            name = self._schedd_name(qi)
            for key, jobs in q.idle_cohorts():
                if not jobs:
                    continue
                rep = next(iter(jobs.values()))
                if not self._cohort_ok(key, rep):
                    continue
                sig = self._cohort_signature(key, rep)
                per = self._inc_counts.setdefault(sig, {})
                per[name] = per.get(name, 0) + len(jobs)
        self._counts_stale = False

    def _idle_group_counts(self, now: float) -> tuple[
            dict[GroupSignature, int], dict[GroupSignature, dict], bool]:
        """Filtered POST-NEGOTIATION idle demand per requirement
        signature (C3 + C4), attributed per schedd.

        The pre-negotiation counts come from the incremental hook-fed
        counters (`_inc_counts` — O(changes) maintenance, not a recount;
        one ClassAd filter evaluation and one signature derivation per
        distinct ad ever).  What `Collector.preview` says the next
        negotiation cycle will absorb with capacity that already exists
        is then subtracted cohort-by-cohort, leaving post-negotiation
        demand.  Returns ``(counts, by_schedd, legacy)`` where `legacy`
        flags the foreign-queue fallback (pre-negotiation counts; the
        caller must subtract unclaimed workers as the seed did)."""
        if not all(hasattr(q, "idle_cohorts") for q in self.queues):
            # foreign queue exposing only the seed surface
            counts: dict[GroupSignature, int] = {}
            by_schedd: dict[GroupSignature, dict] = {}
            for qi, q in enumerate(self.queues):
                name = self._schedd_name(qi)
                idle = [j for j in q.idle_jobs()
                        if self.filter.evaluate(j.ad)]
                for sig, jobs in group_jobs(idle).items():
                    counts[sig] = counts.get(sig, 0) + len(jobs)
                    per = by_schedd.setdefault(sig, {})
                    per[name] = per.get(name, 0) + len(jobs)
            return counts, by_schedd, True
        if self._counts_stale:
            self._rebuild_idle_counts()
        previews = self._preview_cached(now)
        counts = {}
        by_schedd = {}
        for sig, per in self._inc_counts.items():
            n = sum(per.values())
            if n > 0:
                counts[sig] = n
                by_schedd[sig] = dict(per)
        # subtract preview absorption: map each absorbed cohort back to
        # its signature (memoized; cohorts absorbed is bounded by free
        # capacity, not queue depth)
        for qi, q in enumerate(self.queues):
            name = self._schedd_name(qi)
            for key, n_abs in previews[qi].items():
                rep = q.cohort_rep(key)
                if rep is None or not self._cohort_ok(key, rep):
                    continue
                sig = self._cohort_signature(key, rep)
                per = by_schedd.get(sig)
                if per is None:
                    continue
                take = min(int(n_abs), per.get(name, 0))
                if take <= 0:
                    continue
                per[name] -= take
                counts[sig] -= take
                if per[name] <= 0:
                    per.pop(name, None)
                if counts[sig] <= 0:
                    counts.pop(sig, None)
                    by_schedd.pop(sig, None)
        if self.debug_exact_deficits:
            oracle = self._idle_group_counts_scan(previews)
            assert (counts, by_schedd) == oracle, (
                "incremental deficits diverged from the dry-run oracle:"
                f"\n incremental: {(counts, by_schedd)}"
                f"\n oracle:      {oracle}")
        return counts, by_schedd, False

    def _idle_group_counts_scan(self, previews: list[dict]) -> tuple[
            dict[GroupSignature, int], dict[GroupSignature, dict]]:
        """The retired per-reconcile recount, kept verbatim as the
        differential oracle for the incremental counters
        (`debug_exact_deficits`; the flocking differential suite runs
        with it on)."""
        counts: dict[GroupSignature, int] = {}
        by_schedd: dict[GroupSignature, dict] = {}
        for qi, q in enumerate(self.queues):
            absorbed = previews[qi]
            name = self._schedd_name(qi)
            for key, jobs in q.idle_cohorts():
                if not jobs:
                    continue
                rep = next(iter(jobs.values()))
                if not self._cohort_ok(key, rep):
                    continue
                n = len(jobs) - absorbed.get(key, 0)
                if n <= 0:
                    continue
                sig = self._cohort_signature(key, rep)
                counts[sig] = counts.get(sig, 0) + n
                per = by_schedd.setdefault(sig, {})
                per[name] = per.get(name, 0) + n
        return counts, by_schedd

    def _owed_weight(self, n: int, per_schedd: dict) -> float:
        """Demand weighted by owed share: each schedd's contribution
        counts 1/quota-fold, so an underserved small-quota community
        does not get starved behind a big queue's raw counts.  With one
        schedd (or no quotas) this is exactly the raw idle count — the
        seed's ordering."""
        if len(self.queues) == 1 or not per_schedd:
            return float(n)
        return sum(k / self.schedd_quotas.get(s, 1.0)
                   for s, k in per_schedd.items())

    # -- the loop body ----------------------------------------------------------
    def reconcile(self, now: float) -> ProvisionStats:
        """One pass of the provisioning logic. Idempotent at fixed demand."""
        stats = ProvisionStats()
        prof = self.telemetry.profiler
        t_r0 = 0.0
        if prof is not None:
            t_r0 = prof.now()
            self._preview_s = 0.0

        groups, by_schedd, legacy = self._idle_group_counts(now)
        for sig, per in by_schedd.items():
            for name, k in per.items():
                stats.per_schedd_deficit[name] = (
                    stats.per_schedd_deficit.get(name, 0) + k)

        # ties on owed weight break on the stable group label, NOT dict
        # insertion order — a restored run rebuilds `groups` from
        # serialized cohort order and must submit pods identically
        for sig, n_idle in sorted(
            groups.items(),
            key=lambda kv: (-self._owed_weight(kv[1],
                                               by_schedd.get(kv[0], {})),
                            self._pod_group_label(kv[0]))
        ):
            label = self._pod_group_label(sig)
            pending = self._group_pending(label)
            if legacy:
                # seed semantics for foreign queues: pre-negotiation
                # idle minus zero-claim workers of the group
                deficit = n_idle - pending - self._group_unclaimed(sig)
            else:
                # n_idle is already post-negotiation (preview-adjusted)
                deficit = n_idle - pending
            if deficit <= 0:
                continue
            room_group = self.cfg.max_pods_per_group - pending
            room_total = self.cfg.max_total_pods - self._total_live_pods()
            n = max(0, min(deficit, room_group, room_total))
            if n <= 0:
                continue
            alloc = self.routing.split(
                n, sig.as_pod_request(), self.backends, now)
            submitted = 0
            for backend, k in alloc:
                for _ in range(k):
                    self._submit_pod(sig, label, now, backend)
                submitted += k
                stats.per_backend_submitted[backend.name] = (
                    stats.per_backend_submitted.get(backend.name, 0) + k)
            if submitted:
                stats.submitted += submitted
                stats.per_group_submitted[sig] = submitted

        if self.cancel_stale_pending_s is not None:
            for backend in self.backends:
                for pod in backend.cluster.pending_pods(
                    lambda p: p.labels.get("owner") == "prp-provisioner"
                ):
                    if now - pod.created_at > self.cancel_stale_pending_s:
                        backend.cluster.delete_pod(
                            pod.name, now, "stale_pending")
                        stats.reaped_pending += 1

        self.stats.submitted += stats.submitted
        self.stats.reaped_pending += stats.reaped_pending
        for name, k in stats.per_backend_submitted.items():
            self.stats.per_backend_submitted[name] = (
                self.stats.per_backend_submitted.get(name, 0) + k)
        # deficits are a gauge, not a counter: keep the latest snapshot
        self.stats.per_schedd_deficit = dict(stats.per_schedd_deficit)
        if prof is not None:
            prof.record_reconcile(
                t=now, w_start=t_r0, wall_s=prof.now() - t_r0,
                preview_s=self._preview_s, submitted=stats.submitted)
        return stats

    def maybe_reconcile(self, now: float) -> ProvisionStats | None:
        """Tick-poll compat: reconcile if a full interval elapsed (drifts
        when the interval is not a tick multiple — event-loop users get
        exact cadence from `schedule_on`)."""
        if now - self._last_run >= self.cfg.submit_interval_s:
            self._last_run = now
            return self.reconcile(now)
        return None

    def schedule_on(self, loop, *, first: float = 0.0, priority: int = 0):
        """Register the reconcile pass as an exact-interval callback on a
        discrete-event loop (core/events.py): firing k lands at
        ``first + k*submit_interval_s``, never quantized to a tick."""
        def fire(now: float):
            self._last_run = now
            self.reconcile(now)

        return loop.every(self.cfg.submit_interval_s, fire, first=first,
                          name="reconcile", priority=priority)

    # -- pod/worker wiring --------------------------------------------------------
    def _pod_callbacks(self, worker: Worker):
        """(on_start, on_stop) closures for one provisioner pod/worker
        pair — factored out so `rewire_pods` can rebuild them on a
        restored pod (closures don't serialize)."""
        def on_start(pod: Pod, t: float, *, _w=worker):
            _w.booted_at = t + _w.startup_delay
            self.collector.advertise(_w)

        def on_stop(pod: Pod, t: float, reason: str, *, _w=worker):
            if reason != "completed":
                from repro.core.worker import kill_worker
                kill_worker(self.collector, self.queue, _w.name, t)

        return on_start, on_stop

    def rewire_pods(self, workers_by_name: dict[str, Worker]) -> int:
        """Re-attach lifecycle closures to restored provisioner pods:
        each live pod labelled ours is matched to its Worker by name
        (pod name == worker name == worker.pod_name, by construction in
        `_submit_pod`).  Foreign pods are left callback-less.  Returns
        pods rewired."""
        n = 0
        for b in self.backends:
            cluster = b.cluster
            for pod in itertools.chain(cluster._pending.values(),
                                       cluster._running.values()):
                if pod.labels.get("owner") != "prp-provisioner":
                    continue
                w = workers_by_name.get(pod.name)
                if w is None:
                    raise ValueError(
                        f"restored pod {pod.name!r} has no worker")
                pod.on_start, pod.on_stop = self._pod_callbacks(w)
                n += 1
        return n

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe snapshot: the pod-name counter (pod/worker names
        MUST keep incrementing where they left off — they key claims and
        collector entries), the reconcile clock, and cumulative stats.
        The cohort/preview memos are pure caches and simply refill."""
        nid = next(self._ids)
        self._ids = itertools.count(nid)   # non-destructive peek
        return {
            "next_id": nid,
            "last_run": self._last_run,
            "stats": {
                "submitted": self.stats.submitted,
                "reaped_pending": self.stats.reaped_pending,
                "per_group_submitted": [
                    [list(dataclasses.astuple(sig)), k]
                    for sig, k in self.stats.per_group_submitted.items()
                ],
                "per_backend_submitted":
                    dict(self.stats.per_backend_submitted),
                "per_schedd_deficit": dict(self.stats.per_schedd_deficit),
            },
        }

    def load_state(self, state: dict) -> None:
        self._ids = itertools.count(int(state.get("next_id", 0)))
        self._last_run = float(state.get("last_run", -1e18))
        s = state.get("stats", {})
        self.stats = ProvisionStats(
            submitted=int(s.get("submitted", 0)),
            reaped_pending=int(s.get("reaped_pending", 0)),
            per_group_submitted={
                GroupSignature(*vals): int(k)
                for vals, k in s.get("per_group_submitted", [])
            },
            per_backend_submitted=dict(s.get("per_backend_submitted", {})),
            per_schedd_deficit=dict(s.get("per_schedd_deficit", {})),
        )
        self._preview_cache.invalidate()
        self._cohort_filter.clear()
        self._cohort_sig.clear()
        # restores rebuild the queues WITHOUT firing idle hooks — the
        # incremental counters must recount from the restored cohorts
        self._counts_stale = True

    def _submit_pod(self, sig: GroupSignature, label: str, now: float,
                    backend=None):
        backend = backend or self.backends[0]
        name = f"htc-exec-{next(self._ids)}"
        worker_ad = sig.as_worker_ad()
        worker_ad.update(self.cfg.envs)  # advertised extra attrs (Fig 1)

        factory = self.worker_factory or Worker
        worker = factory(
            name=name,
            ad=worker_ad,
            start_expr=self.start_expr,
            idle_timeout=self.cfg.idle_timeout_s,
            startup_delay=self.cfg.startup_delay_s,
            pod_name=name,
        )
        # stamp the owning backend so lifecycle spans can label claims
        # (set post-factory: custom factories need not accept the kwarg)
        worker.backend = backend.name

        on_start, on_stop = self._pod_callbacks(worker)

        selector = {}
        anti = {}
        for k, v in self.cfg.node_affinity.items():
            if k.startswith("^"):
                anti[k[1:]] = v
            else:
                selector[k] = v
        spec = PodSpec(
            name=name,
            request=sig.as_pod_request(),
            priority_class=self.cfg.priority_class,
            tolerations=self.cfg.tolerations,
            node_selector=selector,
            anti_affinity=anti,
            labels={
                "owner": "prp-provisioner",
                "provision-group": label,
            },
            on_start=on_start,
            on_stop=on_stop,
        )
        backend.submit(spec, now)
