"""The auto-scaling provisioning service (paper §2–§3).

Reconciliation loop (C1), run every ``submit_interval_s``:

  1. snapshot idle jobs; keep those passing the job filter (C3)
  2. group them by requirement signature (C4)
  3. per group:  deficit = n_idle − (pending pods of the group
                                     + unclaimed ready workers of the group)
  4. submit ``min(deficit, limits)`` pods whose requests equal the
     signature and whose START expression is the pushed-down filter

Scale-down is NOT here: workers self-terminate when idle (C2, worker.py),
exactly as in the paper ("pods are configured to self-terminate if no user
jobs are waiting").  The provisioner also never deletes pending pods by
default — HTCondor demand is bursty and a pending pod is free; an optional
``cancel_stale_pending_s`` reaps pods pending longer than the horizon
(useful with the node autoscaler off).

Anti-affinity convention from the paper's INI (config.py): node_affinity
keys starting with ^ must NOT match.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

from repro.core.classad import ClassAdExpr
from repro.core.cluster import KubeCluster, Pod, PodPhase
from repro.core.config import ProvisionerConfig
from repro.core.groups import (
    GroupSignature, group_jobs, matches_signature, signature_of,
)
from repro.core.jobqueue import JobQueue
from repro.core.worker import Collector, Worker


@dataclasses.dataclass
class ProvisionStats:
    submitted: int = 0
    reaped_pending: int = 0
    per_group_submitted: dict = dataclasses.field(default_factory=dict)


class Provisioner:
    """One instance per (HTCondor pool, Kubernetes namespace) pair — the
    paper's operation mode (a); mode (b) layers a dedicated local pool in
    front (see examples/grid_portal.py)."""

    def __init__(
        self,
        cfg: ProvisionerConfig,
        queue: JobQueue,
        collector: Collector,
        cluster: KubeCluster,
        *,
        cancel_stale_pending_s: float | None = None,
        worker_factory: Callable[..., Worker] | None = None,
    ):
        self.cfg = cfg
        self.queue = queue
        self.collector = collector
        self.cluster = cluster
        self.filter = cfg.filter_expr()
        self.start_expr = cfg.start_expr()
        self.cancel_stale_pending_s = cancel_stale_pending_s
        self.worker_factory = worker_factory
        self._ids = itertools.count()
        self._last_run = -1e18
        self.stats = ProvisionStats()

    # -- helpers --------------------------------------------------------------
    def _pod_group_label(self, sig: GroupSignature) -> str:
        return f"grp-{abs(hash(sig)) % 10**10:010d}"

    def _group_pending(self, label: str) -> int:
        return len(self.cluster.pending_pods(
            lambda p: p.labels.get("provision-group") == label
        ))

    def _group_unclaimed(self, sig: GroupSignature) -> int:
        return self.collector.unclaimed_capacity(
            lambda ad: matches_signature(ad, sig)
        )

    def _total_live_pods(self) -> int:
        return len([
            p for p in self.cluster.pods.values()
            if p.phase in (PodPhase.PENDING, PodPhase.RUNNING)
            and p.labels.get("owner") == "prp-provisioner"
        ])

    # -- the loop body ----------------------------------------------------------
    def reconcile(self, now: float) -> ProvisionStats:
        """One pass of the provisioning logic. Idempotent at fixed demand."""
        stats = ProvisionStats()

        idle = [j for j in self.queue.idle_jobs()
                if self.filter.evaluate(j.ad)]
        groups = group_jobs(idle)

        for sig, jobs in sorted(
            groups.items(), key=lambda kv: -len(kv[1])
        ):
            label = self._pod_group_label(sig)
            pending = self._group_pending(label)
            unclaimed = self._group_unclaimed(sig)
            deficit = len(jobs) - pending - unclaimed
            if deficit <= 0:
                continue
            room_group = self.cfg.max_pods_per_group - pending
            room_total = self.cfg.max_total_pods - self._total_live_pods()
            n = max(0, min(deficit, room_group, room_total))
            for _ in range(n):
                self._submit_pod(sig, label, now)
            if n:
                stats.submitted += n
                stats.per_group_submitted[sig] = n

        if self.cancel_stale_pending_s is not None:
            for pod in self.cluster.pending_pods(
                lambda p: p.labels.get("owner") == "prp-provisioner"
            ):
                if now - pod.created_at > self.cancel_stale_pending_s:
                    self.cluster.delete_pod(pod.name, now, "stale_pending")
                    stats.reaped_pending += 1

        self.stats.submitted += stats.submitted
        self.stats.reaped_pending += stats.reaped_pending
        return stats

    def maybe_reconcile(self, now: float) -> ProvisionStats | None:
        if now - self._last_run >= self.cfg.submit_interval_s:
            self._last_run = now
            return self.reconcile(now)
        return None

    # -- pod/worker wiring --------------------------------------------------------
    def _submit_pod(self, sig: GroupSignature, label: str, now: float):
        name = f"htc-exec-{next(self._ids)}"
        worker_ad = sig.as_worker_ad()
        worker_ad.update(self.cfg.envs)  # advertised extra attrs (Fig 1)

        factory = self.worker_factory or Worker
        worker = factory(
            name=name,
            ad=worker_ad,
            start_expr=self.start_expr,
            idle_timeout=self.cfg.idle_timeout_s,
            startup_delay=self.cfg.startup_delay_s,
            pod_name=name,
        )

        def on_start(pod: Pod, t: float, *, _w=worker):
            _w.booted_at = t + _w.startup_delay
            self.collector.advertise(_w)

        def on_stop(pod: Pod, t: float, reason: str, *, _w=worker):
            if reason != "completed":
                from repro.core.worker import kill_worker
                kill_worker(self.collector, self.queue, _w.name, t)

        selector = {}
        anti = {}
        for k, v in self.cfg.node_affinity.items():
            if k.startswith("^"):
                anti[k[1:]] = v
            else:
                selector[k] = v
        pod = Pod(
            name=name,
            request=sig.as_pod_request(),
            priority_class=self.cfg.priority_class,
            tolerations=self.cfg.tolerations,
            node_selector=selector,
            labels={
                "owner": "prp-provisioner",
                "provision-group": label,
                **({"anti-affinity": ",".join(anti)} if anti else {}),
            },
            on_start=on_start,
            on_stop=on_stop,
        )
        self.cluster.create_pod(pod, now)
