"""Hierarchical fair-share accounting: HTCondor's accountant, simulated.

The OSG deployments the paper targets serve several communities, each
submitting through its own schedd into one shared pool; the negotiator
must arbitrate between them, not just drain one queue FIFO.  HTCondor
does this with two ledgers:

  * per-SUBMITTER usage with exponential decay (PRIORITY_HALFLIFE):
    a user's *real* priority tracks their recent resource consumption,
    and their *effective* priority is that times an operator-set
    priority factor — a factor-2 user is entitled to half the machines
    of a factor-1 user under contention;
  * per-GROUP (here: per-schedd) quotas that carve the pool between
    communities before users inside each community compete.

`UsageLedger` implements the decayed-usage integral exactly: between
observations a key accrues at its current running-core rate while the
whole ledger decays with half-life ``half_life_s``, so
``du/dt = rate − (ln2/hl)·u`` is integrated in closed form at every rate
change (claim / completion / release).  At a steady rate the usage
converges to ``rate·hl/ln2``; `effective_cores` divides that constant
back out, so "usage" reads in *cores currently deserved* — directly
comparable with the virtual cores the negotiator charges while handing
out slots inside one cycle.

`Accountant` wires a ledger pair to any number of `JobQueue`s via the
queue's claim/complete/release hooks and answers the two questions the
negotiation cycle (worker.py `run_cycle`) asks while
water-filling capacity:

  * ``effective_priority(user)`` — factor × (base + decayed cores +
    virtual cores charged so far this cycle); LOWEST goes first.
  * ``group_owed(schedd)`` — decayed group cores / quota; the schedd
    with the smallest usage-per-quota is most *owed* and negotiates
    first.

Serving the argmin and charging what it claimed equalizes
``factor × usage`` across users (and ``usage / quota`` across schedds),
which is exactly the inverse-factor / proportional-quota split HTCondor
documents — the fair-share convergence test pins the 2:1 case.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable

from repro.core.jobqueue import DEFAULT_USER, USER_ATTR, user_of  # noqa: F401
#   (re-exported: the accountant's callers key ledgers by user_of(job))

LN2 = math.log(2.0)


def job_cores(job) -> float:
    """Slot weight a job is charged at — HTCondor's default SlotWeight
    (cpus); GPUs are charged on top so a 1-cpu/1-gpu job outweighs a
    1-cpu scavenger."""
    cpus = job.ad.get("request_cpus", 1) or 1
    gpus = job.ad.get("request_gpus", 0) or 0
    return max(1.0, float(cpus)) + float(gpus)


class UsageLedger:
    """Per-key exponentially-decayed usage, integrated in closed form.

    Keys accrue at their current `rate` (running cores) while decaying
    with half-life `half_life_s`; both the accrual and the decay are
    settled lazily whenever a key is observed or its rate changes, so
    the ledger is exact at event granularity and O(1) per update.
    """

    def __init__(self, half_life_s: float = 86400.0):
        if not half_life_s > 0:
            raise ValueError(
                f"half_life_s must be positive, got {half_life_s}")
        self.half_life_s = half_life_s
        self.tau = half_life_s / LN2       # decay-equilibrium constant
        self._usage: dict[str, float] = {}   # core-seconds, decayed
        self._rate: dict[str, float] = {}    # running cores
        self._t: dict[str, float] = {}       # last settle time per key

    def _settle(self, key: str, now: float):
        t0 = self._t.get(key)
        if t0 is None:
            self._t[key] = now
            return
        dt = now - t0
        if dt <= 0:
            return
        d = 0.5 ** (dt / self.half_life_s)
        u = self._usage.get(key, 0.0)
        r = self._rate.get(key, 0.0)
        # closed form of du/dt = r - (ln2/hl) u over [t0, now]
        self._usage[key] = u * d + r * self.tau * (1.0 - d)
        self._t[key] = now

    def add_rate(self, key: str, delta_cores: float, now: float):
        """A job started (+cores) or stopped (-cores) at `now`."""
        self._settle(key, now)
        self._rate[key] = self._rate.get(key, 0.0) + delta_cores

    def charge(self, key: str, core_seconds: float, now: float):
        """One-shot usage charge (tests / imported accounting state)."""
        self._settle(key, now)
        self._usage[key] = self._usage.get(key, 0.0) + core_seconds

    def usage(self, key: str, now: float) -> float:
        """Decayed core-seconds of accumulated usage at `now`."""
        self._settle(key, now)
        return self._usage.get(key, 0.0)

    def effective_cores(self, key: str, now: float) -> float:
        """Usage normalized by the decay equilibrium: a key holding a
        steady `r` running cores converges to exactly `r` — the unit the
        negotiator's virtual within-cycle charges are denominated in."""
        return self.usage(key, now) / self.tau

    def rate(self, key: str) -> float:
        return self._rate.get(key, 0.0)

    def keys(self) -> list[str]:
        return sorted(set(self._usage) | set(self._rate))

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> dict[str, Any]:
        """Plain-dict persistable state (JSON-safe: str keys, floats).
        The raw (usage, rate, last-settle) triples reproduce the ledger
        EXACTLY — no settling happens, so a dump/load round-trip is
        bitwise-neutral at any later query time."""
        return {
            "half_life_s": self.half_life_s,
            "usage": dict(self._usage),
            "rate": dict(self._rate),
            "t": dict(self._t),
        }

    def load_state(self, state: dict[str, Any]):
        """Inverse of `state_dict` (e.g. after a json.loads round-trip);
        replaces all ledger contents."""
        hl = float(state.get("half_life_s", self.half_life_s))
        if not hl > 0:
            raise ValueError(f"half_life_s must be positive, got {hl}")
        self.half_life_s = hl
        self.tau = hl / LN2
        self._usage = {str(k): float(v)
                       for k, v in state.get("usage", {}).items()}
        self._rate = {str(k): float(v)
                      for k, v in state.get("rate", {}).items()}
        self._t = {str(k): float(v)
                   for k, v in state.get("t", {}).items()}


@dataclasses.dataclass
class ScheddSpec:
    """One submit host in a flocking federation: its name, its share
    quota (relative weight of the pool its community is entitled to),
    and per-user priority factors for its submitters (merged into the
    accountant; factors are pool-global in HTCondor and here)."""

    name: str
    quota: float = 1.0
    priority_factors: dict[str, float] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if not self.quota > 0:
            raise ValueError(
                f"schedd {self.name!r}: quota must be positive, "
                f"got {self.quota}")


class Accountant:
    """The negotiator's usage/priority book-keeper (pool-level).

    Attach it to every schedd's queue (`attach_queue`); claim, complete,
    and release transitions then keep per-user and per-schedd running-
    core rates current, and the decayed ledgers answer priority queries
    at negotiation time.  Within one negotiation cycle the negotiator
    additionally charges *virtual* cores for the claims it just handed
    out (`charge_virtual`), so water-filling sees its own allocations
    before any sim time passes; `reset_cycle` drops them once real
    rates have taken over.
    """

    def __init__(self, *, half_life_s: float = 86400.0,
                 base_priority: float = 0.5,
                 default_factor: float = 1.0):
        if not base_priority > 0:
            raise ValueError(
                f"base_priority must be positive, got {base_priority}")
        self.users = UsageLedger(half_life_s)
        self.groups = UsageLedger(half_life_s)
        self.base_priority = base_priority
        self.default_factor = default_factor
        self.factors: dict[str, float] = {}
        self.quotas: dict[str, float] = {}
        # within-cycle virtual charges, in cores
        self._vuser: dict[str, float] = {}
        self._vgroup: dict[str, float] = {}

    # -- configuration -------------------------------------------------------
    def set_priority_factor(self, user: str, factor: float):
        if not factor > 0:
            raise ValueError(
                f"priority factor must be positive, got {factor}")
        self.factors[user] = factor

    def priority_factor(self, user: str) -> float:
        return self.factors.get(user, self.default_factor)

    def set_quota(self, schedd: str, quota: float):
        if not quota > 0:
            raise ValueError(f"quota must be positive, got {quota}")
        self.quotas[schedd] = quota

    def quota(self, schedd: str) -> float:
        return self.quotas.get(schedd, 1.0)

    # -- queue wiring --------------------------------------------------------
    def attach_queue(self, schedd: str, queue):
        """Subscribe to a schedd's job transitions so running-core rates
        stay exact: +cores at claim, −cores at completion/release."""

        def on_claim(job, now):
            cores = job_cores(job)
            self.users.add_rate(user_of(job), cores, now)
            self.groups.add_rate(schedd, cores, now)

        def on_stop(job, now):
            cores = job_cores(job)
            self.users.add_rate(user_of(job), -cores, now)
            self.groups.add_rate(schedd, -cores, now)

        queue.add_claim_hook(on_claim)
        queue.add_release_hook(on_stop)
        queue.add_complete_hook(lambda job: on_stop(job, job.completed_at))

    # -- negotiation-cycle queries -------------------------------------------
    def reset_cycle(self):
        """Drop the previous cycle's virtual charges (claims made then
        are now real running-core rates)."""
        self._vuser.clear()
        self._vgroup.clear()

    def charge_virtual(self, schedd: str, user: str, cores: float):
        self._vuser[user] = self._vuser.get(user, 0.0) + cores
        self._vgroup[schedd] = self._vgroup.get(schedd, 0.0) + cores

    def effective_priority(self, user: str, now: float) -> float:
        """HTCondor EUP: priority factor × (base + decayed usage), plus
        this cycle's virtual cores.  Lower is better."""
        cores = (self.users.effective_cores(user, now)
                 + self._vuser.get(user, 0.0))
        return self.priority_factor(user) * (self.base_priority + cores)

    def group_owed(self, schedd: str, now: float) -> float:
        """Usage-per-quota of a schedd (virtual charges included) — the
        water-filling key at the group level; lower means more owed."""
        cores = (self.groups.effective_cores(schedd, now)
                 + self._vgroup.get(schedd, 0.0))
        return cores / self.quota(schedd)

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> dict[str, Any]:
        """Everything needed to rebuild this accountant in a fresh
        process — plain dicts, JSON-safe.  Virtual within-cycle charges
        are deliberately NOT part of the state: they only exist inside
        one negotiation cycle and a restored accountant starts outside
        of any."""
        return {
            "base_priority": self.base_priority,
            "default_factor": self.default_factor,
            "factors": dict(self.factors),
            "quotas": dict(self.quotas),
            "users": self.users.state_dict(),
            "groups": self.groups.state_dict(),
        }

    def restore(self, state: dict[str, Any]):
        """Load a `state_dict()` — or a full `snapshot()` carrying one
        under its "state" key (snapshots stay directly restorable after
        a JSON round-trip).  Priority queries afterwards are identical
        to the source accountant's."""
        inner = state.get("state")
        if isinstance(inner, dict) and "users" in inner:
            state = inner
        self.base_priority = float(
            state.get("base_priority", self.base_priority))
        self.default_factor = float(
            state.get("default_factor", self.default_factor))
        self.factors = {str(k): float(v)
                        for k, v in state.get("factors", {}).items()}
        self.quotas = {str(k): float(v)
                       for k, v in state.get("quotas", {}).items()}
        self.users.load_state(state.get("users", {}))
        self.groups.load_state(state.get("groups", {}))
        self.reset_cycle()

    # -- introspection (metrics / tests) -------------------------------------
    def snapshot(self, now: float) -> dict[str, Any]:
        out = {
            "users": {
                u: {
                    "effective_cores": round(
                        self.users.effective_cores(u, now), 6),
                    "rate": self.users.rate(u),
                    "factor": self.priority_factor(u),
                    "effective_priority": round(
                        self.effective_priority(u, now), 6),
                }
                for u in self.users.keys()
            },
            "schedds": {
                s: {
                    "effective_cores": round(
                        self.groups.effective_cores(s, now), 6),
                    "rate": self.groups.rate(s),
                    "quota": self.quota(s),
                }
                for s in self.groups.keys()
            },
        }
        # the persistable half rides along so `json.dumps(snapshot)` is
        # both a metrics record AND a restore point (see `restore`)
        out["state"] = self.state_dict()
        return out


def make_schedd_specs(schedds: int | Iterable) -> list[ScheddSpec]:
    """Normalize the `Simulation(schedds=...)` argument: an int makes N
    equal-quota schedds named schedd00..; an iterable may mix names and
    ready-made `ScheddSpec`s."""
    if isinstance(schedds, int):
        if schedds < 1:
            raise ValueError(f"need at least one schedd, got {schedds}")
        return [ScheddSpec(name=f"schedd{i:02d}") for i in range(schedds)]
    specs: list[ScheddSpec] = []
    for s in schedds:
        if isinstance(s, ScheddSpec):
            specs.append(s)
        elif isinstance(s, str):
            specs.append(ScheddSpec(name=s))
        else:
            raise TypeError(f"schedd spec must be a name or ScheddSpec, "
                            f"got {s!r}")
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate schedd names: {names}")
    if not specs:
        raise ValueError("need at least one schedd")
    return specs
