"""The schedd: job queue with HTCondor-like job states and ads.

Jobs are pleasantly-parallel work units (the paper's OSG payload model).
Each job carries an ad (requirements + arbitrary advertised attributes) and
a simulated runtime; the "real mode" used by the examples attaches a
work_fn that advances actual JAX training steps instead.

Preemption semantics (paper §5): a preempted job transparently returns to
IDLE and reruns elsewhere; `preempt_count` and total wasted work are
tracked for the benchmarks.

Scale: the queue is fully indexed.  Jobs live in per-state buckets, so
`n_idle()` / `n_running()` are O(1), and idle jobs are additionally
bucketed into COHORTS — groups with identical ads and requirement
expressions, hence identical matchmaking behaviour.  A 100k-job campaign
of uniform jobs is ONE cohort: the negotiator and the provisioner evaluate
ClassAd expressions once per cohort instead of once per job.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Iterable, Iterator

from repro.core.classad import ClassAdExpr


class JobState(enum.Enum):
    IDLE = "idle"
    RUNNING = "running"
    COMPLETED = "completed"
    HELD = "held"
    REMOVED = "removed"


#: ad attribute naming the submitter; jobs without one are accounted
#: under a single anonymous submitter
USER_ATTR = "user"
DEFAULT_USER = "unknown"


def user_of(job: "Job") -> str:
    """Submitter a job is accounted to (its ad's ``user`` attribute)."""
    u = job.ad.get(USER_ATTR)
    return str(u) if u else DEFAULT_USER


@dataclasses.dataclass
class Job:
    ad: dict[str, Any]
    runtime_s: float = 60.0
    requirements: ClassAdExpr | None = None
    work_fn: Callable[["Job", float], bool] | None = None  # (job, dt) -> done
    jid: int = -1

    # lifecycle
    state: JobState = JobState.IDLE
    submitted_at: float = 0.0
    started_at: float = -1.0          # first claim (wait-time metric)
    attempt_started_at: float = -1.0  # latest claim (straggler detection)
    completed_at: float = -1.0
    remaining_s: float = dataclasses.field(default=-1.0)
    preempt_count: int = 0
    wasted_s: float = 0.0         # work lost to preemption
    claimed_by: str | None = None
    cohort_key: tuple | None = None   # assigned at submit; ad-derived
    # owning queue, stamped at submit: with several schedds flocking
    # into one pool, a worker's completions must route back to the
    # schedd the job came from (worker.py advance_workers)
    schedd: Any = dataclasses.field(default=None, repr=False,
                                    compare=False)

    def __post_init__(self):
        if self.remaining_s < 0:
            self.remaining_s = self.runtime_s


def _freeze(v: Any) -> Any:
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    return repr(v)


def canonical_ad(ad: dict[str, Any]) -> tuple:
    """Hashable canonical form of an ad.  Job cohorts AND worker slot
    shapes use this SAME canonicalization — the two halves jointly key
    the collector's match cache, so they must never diverge."""
    return tuple(sorted((str(k), _freeze(v)) for k, v in ad.items()))


def cohort_key_of(job: Job) -> tuple:
    """Matchmaking-equivalence key: two jobs with the same key match the
    same workers (same ad contents, same Requirements expression)."""
    req = job.requirements.src if job.requirements is not None else ""
    return (req, canonical_ad(job.ad))


class JobQueue:
    """Single schedd. The provisioner and the workers both query it — the
    workers through the collector's matchmaking (worker.py).

    Completion streaming: `add_complete_hook(fn)` registers observers
    called once per completed job, and `keep_completed = False` stops the
    queue retaining completed `Job` objects in `completed_log` — together
    they let a 100k-arrival trace replay aggregate wait/goodput stats
    without ever holding more than the in-flight jobs alive
    (workload/replay.py)."""

    def __init__(self, name: str = "schedd", ids=None):
        # `name` identifies this schedd in a flocking federation (metric
        # scopes, deficit attribution); `ids` lets several queues share
        # one job-id counter so jids stay pool-unique — a worker's claim
        # table is keyed by jid across every schedd it serves
        self.name = name
        self._jobs: dict[int, Job] = {}
        self._ids = ids if ids is not None else itertools.count()
        self.completed_log: list[Job] = []
        self.keep_completed = True
        self._complete_hooks: list[Callable[[Job], None]] = []
        self._claim_hooks: list[Callable[[Job, float], None]] = []
        self._release_hooks: list[Callable[[Job, float], None]] = []
        # per-user running-job counts (fair-share metrics read these;
        # the accountant tracks core RATES itself via the hooks)
        self.running_by_user: dict[str, int] = {}
        # bumped whenever the SET of idle cohorts changes (a cohort is
        # born or drained) — the collector's C2 idle-poll verdict for an
        # unclaimed worker is a pure function of this set, so workers
        # cache it per version (worker.py any_cohort_matches)
        self.idle_version = 0
        # indexes: per-state buckets + idle cohorts (jid -> Job each)
        self._by_state: dict[JobState, dict[int, Job]] = {
            s: {} for s in JobState
        }
        self._idle_cohorts: dict[tuple, dict[int, Job]] = {}
        # per-cohort FIFO bookkeeping: earliest (submitted_at, jid) seen
        # (sort key across cohorts) and whether insertion order ever
        # violated FIFO (a released job re-entering behind newer ones) —
        # only then does cohort_jobs_sorted() actually have to sort
        self._cohort_min: dict[tuple, tuple] = {}
        self._cohort_tail: dict[tuple, tuple] = {}
        self._cohort_unsorted: set[tuple] = set()

    # -- index maintenance ---------------------------------------------------
    def _enter_state(self, job: Job, state: JobState):
        self._by_state[state][job.jid] = job
        job.state = state
        if state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is None:
                cohort = self._idle_cohorts[key] = {}
                self.idle_version += 1
            cohort[job.jid] = job
            order = (job.submitted_at, job.jid)
            cur_min = self._cohort_min.get(key)
            if cur_min is None or order < cur_min:
                self._cohort_min[key] = order
            tail = self._cohort_tail.get(key)
            if tail is not None and order < tail:
                self._cohort_unsorted.add(key)
            if tail is None or order > tail:
                self._cohort_tail[key] = order

    def _leave_state(self, job: Job):
        self._by_state[job.state].pop(job.jid, None)
        if job.state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is not None:
                cohort.pop(job.jid, None)
                if not cohort:
                    del self._idle_cohorts[key]
                    self._cohort_min.pop(key, None)
                    self._cohort_tail.pop(key, None)
                    self._cohort_unsorted.discard(key)
                    self.idle_version += 1

    def submit(self, job: Job, now: float = 0.0) -> int:
        job.jid = next(self._ids)
        job.submitted_at = now
        job.schedd = self
        if job.cohort_key is None:
            job.cohort_key = cohort_key_of(job)
        self._jobs[job.jid] = job
        self._enter_state(job, JobState.IDLE)
        return job.jid

    def jobs(self, state: JobState | None = None) -> list[Job]:
        if state is None:
            return list(self._jobs.values())
        return list(self._by_state[state].values())

    def idle_jobs(self) -> list[Job]:
        return list(self._by_state[JobState.IDLE].values())

    def idle_cohorts(self) -> Iterator[tuple[tuple, dict[int, Job]]]:
        """(cohort_key, {jid: job}) for every non-empty idle cohort.
        Every job in a cohort matches exactly the same workers."""
        return iter(list(self._idle_cohorts.items()))

    def cohort_first_submit(self, key: tuple) -> tuple:
        """Earliest (submitted_at, jid) a cohort has held while idle —
        the negotiator's cross-cohort FIFO key.  May be slightly stale
        after the oldest member leaves; a lower bound is fine for
        ordering."""
        return self._cohort_min.get(key, (float("inf"), -1))

    def cohort_jobs_sorted(self, key: tuple,
                           limit: int | None = None) -> list[Job]:
        """A cohort's idle jobs in FIFO (submission) order.  Insertion
        order already IS submission order unless a released job re-entered
        behind newer ones — then ONE sort is paid and the cohort dict is
        rebuilt in order (flag + tail reset), restoring the O(n) fast
        path for subsequent cycles.  `limit` returns only the first N —
        fair-share hands out claim budgets of a few jobs at a time, and
        must not copy a 10k-job cohort to take one."""
        cohort = self._idle_cohorts.get(key)
        if not cohort:
            return []
        if key in self._cohort_unsorted:
            jobs = sorted(cohort.values(),
                          key=lambda j: (j.submitted_at, j.jid))
            self._idle_cohorts[key] = {j.jid: j for j in jobs}
            self._cohort_unsorted.discard(key)
            last = jobs[-1]
            self._cohort_tail[key] = (last.submitted_at, last.jid)
            return jobs if limit is None else jobs[:limit]
        if limit is None or limit >= len(cohort):
            return list(cohort.values())
        return list(itertools.islice(cohort.values(), limit))

    def get(self, jid: int) -> Job:
        return self._jobs[jid]

    # -- transitions (driven by workers) -------------------------------------
    def claim(self, jid: int, worker_name: str, now: float) -> Job:
        job = self._jobs[jid]
        assert job.state == JobState.IDLE, (jid, job.state)
        self._leave_state(job)
        self._enter_state(job, JobState.RUNNING)
        job.claimed_by = worker_name
        job.attempt_started_at = now
        if job.started_at < 0:
            job.started_at = now
        user = user_of(job)
        self.running_by_user[user] = self.running_by_user.get(user, 0) + 1
        for hook in self._claim_hooks:
            hook(job, now)
        return job

    def _drop_running_user(self, job: Job):
        user = user_of(job)
        n = self.running_by_user.get(user, 0) - 1
        if n > 0:
            self.running_by_user[user] = n
        else:
            self.running_by_user.pop(user, None)

    def add_complete_hook(self, fn: Callable[[Job], None]):
        """Observe every completion as it happens (streaming stats)."""
        self._complete_hooks.append(fn)

    def add_claim_hook(self, fn: Callable[[Job, float], None]):
        """Observe every claim as it happens — the fair-share accountant
        bumps the submitter's running-core rate here."""
        self._claim_hooks.append(fn)

    def add_release_hook(self, fn: Callable[[Job, float], None]):
        """Observe every RUNNING -> IDLE release (preemption / worker
        death) — the accounting mirror of the claim hook."""
        self._release_hooks.append(fn)

    def complete(self, jid: int, now: float):
        job = self._jobs.pop(jid)
        if job.state == JobState.RUNNING:
            self._drop_running_user(job)
        self._leave_state(job)
        job.state = JobState.COMPLETED
        job.completed_at = now
        job.claimed_by = None
        for hook in self._complete_hooks:
            hook(job)
        if self.keep_completed:
            self.completed_log.append(job)

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Job returns to IDLE (preemption / worker death). Progress on the
        current attempt is lost — HTCondor restarts vanilla-universe jobs."""
        job = self._jobs[jid]
        if job.state != JobState.RUNNING:
            return
        if preempted:
            job.preempt_count += 1
            done = job.runtime_s - job.remaining_s  # progress so far
            # Jobs restart from scratch (HTCondor vanilla universe) unless
            # they self-checkpoint (OSG best practice; our JAX training
            # jobs do): then only progress past the last boundary is lost.
            ckpt_every = job.ad.get("checkpoint_interval_s") or 0
            kept = (done // ckpt_every) * ckpt_every if ckpt_every else 0.0
            job.wasted_s += done - kept
            job.remaining_s = job.runtime_s - kept
        self._drop_running_user(job)
        self._leave_state(job)
        self._enter_state(job, JobState.IDLE)
        job.claimed_by = None
        for hook in self._release_hooks:
            hook(job, now)

    # -- stats ----------------------------------------------------------------
    def n_idle(self) -> int:
        return len(self._by_state[JobState.IDLE])

    def n_idle_cohorts(self) -> int:
        """Distinct matchmaking-equivalence classes currently idle — how a
        trace's requirement mix materializes in the queue (a uniform burst
        is 1; a replayed OSG day is kinds × users × Requirements)."""
        return len(self._idle_cohorts)

    def n_running(self) -> int:
        return len(self._by_state[JobState.RUNNING])

    def idle_by_user(self, now: float | None = None
                     ) -> dict[str, tuple[int, float]]:
        """{user: (idle count, starvation age)} from the idle cohorts —
        starvation age is `now` minus the oldest idle submission the
        user has CURRENTLY pending (0.0 when `now` is omitted).  One
        pass over cohorts, not jobs: the oldest live member is the
        cohort's first FIFO entry (`_cohort_min` would do — but it is
        only reset when a cohort fully drains, so a continuously-fed
        cohort would pin the age at its first-ever arrival)."""
        out: dict[str, tuple[int, float]] = {}
        for key, jobs in self._idle_cohorts.items():
            rep = next(iter(jobs.values()))
            user = user_of(rep)
            oldest = self.cohort_jobs_sorted(key, 1)[0].submitted_at
            n, prev_oldest = out.get(user, (0, float("inf")))
            out[user] = (n + len(jobs), min(prev_oldest, oldest))
        return {
            u: (n, max(0.0, (now - t) if now is not None
                       and t != float("inf") else 0.0))
            for u, (n, t) in out.items()
        }

    def drained(self) -> bool:
        return not self._jobs


class FlockedQueues:
    """Federation view over several schedds' queues, for pool
    components that held a single-queue handle (the C2 idle poll, the
    tick engine's scan negotiation, straggler mitigation).  Claims and
    completions do NOT go through this view — they route to the owning
    queue via `job.schedd`; only `release` routes here, by jid, for
    callers that hold job ids rather than Job objects."""

    def __init__(self, queues: Iterable[JobQueue]):
        self.queues = list(queues)

    @property
    def idle_version(self) -> int:
        # sum of per-queue versions: monotonic, and it changes whenever
        # any queue's idle-cohort SET changes — the property the
        # collector's C2 poll cache keys on
        return sum(q.idle_version for q in self.queues)

    def idle_cohorts(self) -> Iterator[tuple[tuple, dict[int, Job]]]:
        for q in self.queues:
            yield from q.idle_cohorts()

    def idle_jobs(self) -> list[Job]:
        out: list[Job] = []
        for q in self.queues:
            out.extend(q.idle_jobs())
        return out

    def jobs(self, state: JobState | None = None) -> list[Job]:
        out: list[Job] = []
        for q in self.queues:
            out.extend(q.jobs(state))
        return out

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Route a release to the owning queue (jids are pool-unique
        when the queues share an id counter — the straggler policy
        holds jids, not Job objects)."""
        for q in self.queues:
            if jid in q._jobs:
                q.release(jid, now, preempted=preempted)
                return
        raise KeyError(jid)

    def n_idle(self) -> int:
        return sum(q.n_idle() for q in self.queues)

    def n_idle_cohorts(self) -> int:
        return sum(q.n_idle_cohorts() for q in self.queues)

    def n_running(self) -> int:
        return sum(q.n_running() for q in self.queues)

    def drained(self) -> bool:
        return all(q.drained() for q in self.queues)
