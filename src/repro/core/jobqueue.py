"""The schedd: job queue with HTCondor-like job states and ads.

Jobs are pleasantly-parallel work units (the paper's OSG payload model).
Each job carries an ad (requirements + arbitrary advertised attributes) and
a simulated runtime; the "real mode" used by the examples attaches a
work_fn that advances actual JAX training steps instead.

Preemption semantics (paper §5): a preempted job transparently returns to
IDLE and reruns elsewhere; `preempt_count` and total wasted work are
tracked for the benchmarks.

Scale: the queue is fully indexed.  Jobs live in per-state buckets, so
`n_idle()` / `n_running()` are O(1), and idle jobs are additionally
bucketed into COHORTS — groups with identical ads and requirement
expressions, hence identical matchmaking behaviour.  A 100k-job campaign
of uniform jobs is ONE cohort: the negotiator and the provisioner evaluate
ClassAd expressions once per cohort instead of once per job.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Iterable, Iterator

from repro.core.classad import ClassAdExpr


class JobState(enum.Enum):
    IDLE = "idle"
    RUNNING = "running"
    COMPLETED = "completed"
    HELD = "held"
    REMOVED = "removed"


#: ad attribute naming the submitter; jobs without one are accounted
#: under a single anonymous submitter
USER_ATTR = "user"
DEFAULT_USER = "unknown"


def user_of(job: "Job") -> str:
    """Submitter a job is accounted to (its ad's ``user`` attribute)."""
    u = job.ad.get(USER_ATTR)
    return str(u) if u else DEFAULT_USER


@dataclasses.dataclass
class Job:
    ad: dict[str, Any]
    runtime_s: float = 60.0
    requirements: ClassAdExpr | None = None
    work_fn: Callable[["Job", float], bool] | None = None  # (job, dt) -> done
    jid: int = -1

    # lifecycle
    state: JobState = JobState.IDLE
    submitted_at: float = 0.0
    started_at: float = -1.0          # first claim (wait-time metric)
    attempt_started_at: float = -1.0  # latest claim (straggler detection)
    completed_at: float = -1.0
    remaining_s: float = dataclasses.field(default=-1.0)
    preempt_count: int = 0
    wasted_s: float = 0.0         # work lost to preemption
    claimed_by: str | None = None
    cohort_key: tuple | None = None   # assigned at submit; ad-derived
    # owning queue, stamped at submit: with several schedds flocking
    # into one pool, a worker's completions must route back to the
    # schedd the job came from (worker.py advance_workers)
    schedd: Any = dataclasses.field(default=None, repr=False,
                                    compare=False)

    def __post_init__(self):
        if self.remaining_s < 0:
            self.remaining_s = self.runtime_s


def _freeze(v: Any) -> Any:
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    return repr(v)


def canonical_ad(ad: dict[str, Any]) -> tuple:
    """Hashable canonical form of an ad.  Job cohorts AND worker slot
    shapes use this SAME canonicalization — the two halves jointly key
    the collector's match cache, so they must never diverge."""
    return tuple(sorted((str(k), _freeze(v)) for k, v in ad.items()))


def cohort_key_of(job: Job) -> tuple:
    """Matchmaking-equivalence key: two jobs with the same key match the
    same workers (same ad contents, same Requirements expression)."""
    req = job.requirements.src if job.requirements is not None else ""
    return (req, canonical_ad(job.ad))


# -- job (de)serialization ----------------------------------------------------
def job_state(job: Job) -> dict:
    """JSON-safe snapshot of a Job.  Requirements serialize as their
    source text (recompiled on load — ClassAdExpr compilation is pure);
    `work_fn` jobs cannot snapshot: an arbitrary Python closure has no
    faithful serial form, and resuming it mid-flight would silently
    change semantics."""
    if job.work_fn is not None:
        raise ValueError(
            f"job {job.jid} has a work_fn; live-callable jobs cannot be "
            "snapshotted")
    return {
        "jid": job.jid,
        "ad": dict(job.ad),
        "runtime_s": job.runtime_s,
        "requirements": (job.requirements.src
                         if job.requirements is not None else None),
        "state": job.state.value,
        "submitted_at": job.submitted_at,
        "started_at": job.started_at,
        "attempt_started_at": job.attempt_started_at,
        "completed_at": job.completed_at,
        "remaining_s": job.remaining_s,
        "preempt_count": job.preempt_count,
        "wasted_s": job.wasted_s,
        "claimed_by": job.claimed_by,
    }


def job_from_state(state: dict, *, schedd: "JobQueue | None" = None) -> Job:
    req_src = state.get("requirements")
    job = Job(
        ad=dict(state["ad"]),
        runtime_s=float(state["runtime_s"]),
        requirements=ClassAdExpr(req_src) if req_src else None,
        jid=int(state["jid"]),
        state=JobState(state["state"]),
        submitted_at=float(state["submitted_at"]),
        started_at=float(state.get("started_at", -1.0)),
        attempt_started_at=float(state.get("attempt_started_at", -1.0)),
        completed_at=float(state.get("completed_at", -1.0)),
        remaining_s=float(state["remaining_s"]),
        preempt_count=int(state.get("preempt_count", 0)),
        wasted_s=float(state.get("wasted_s", 0.0)),
        claimed_by=state.get("claimed_by"),
        schedd=schedd,
    )
    job.cohort_key = cohort_key_of(job)
    return job


class JobQueue:
    """Single schedd. The provisioner and the workers both query it — the
    workers through the collector's matchmaking (worker.py).

    Completion streaming: `add_complete_hook(fn)` registers observers
    called once per completed job, and `keep_completed = False` stops the
    queue retaining completed `Job` objects in `completed_log` — together
    they let a 100k-arrival trace replay aggregate wait/goodput stats
    without ever holding more than the in-flight jobs alive
    (workload/replay.py)."""

    def __init__(self, name: str = "schedd", ids=None):
        # `name` identifies this schedd in a flocking federation (metric
        # scopes, deficit attribution); `ids` lets several queues share
        # one job-id counter so jids stay pool-unique — a worker's claim
        # table is keyed by jid across every schedd it serves
        self.name = name
        self._jobs: dict[int, Job] = {}
        self._ids = ids if ids is not None else itertools.count()
        self.completed_log: list[Job] = []
        self.keep_completed = True
        self._complete_hooks: list[Callable[[Job], None]] = []
        self._claim_hooks: list[Callable[[Job, float], None]] = []
        self._release_hooks: list[Callable[[Job, float], None]] = []
        # fn(job, +1|-1) on every IDLE entry/exit — the provisioner's
        # incremental deficit counters live off these (O(changes)
        # maintenance instead of a per-cycle recount)
        self._idle_hooks: list[Callable[[Job, int], None]] = []
        # per-user running-job counts (fair-share metrics read these;
        # the accountant tracks core RATES itself via the hooks)
        self.running_by_user: dict[str, int] = {}
        # bumped whenever the SET of idle cohorts changes (a cohort is
        # born or drained) — the collector's C2 idle-poll verdict for an
        # unclaimed worker is a pure function of this set, so workers
        # cache it per version (worker.py any_cohort_matches)
        self.idle_version = 0
        # bumped on EVERY job entering or leaving IDLE — the fine-grained
        # companion of idle_version (which only moves on cohort births/
        # drains): "has the idle set changed at all?" is one int compare
        self.idle_seq = 0
        # indexes: per-state buckets + idle cohorts (jid -> Job each)
        self._by_state: dict[JobState, dict[int, Job]] = {
            s: {} for s in JobState
        }
        self._idle_cohorts: dict[tuple, dict[int, Job]] = {}
        # per-cohort FIFO bookkeeping: earliest (submitted_at, jid) seen
        # (sort key across cohorts) and whether insertion order ever
        # violated FIFO (a released job re-entering behind newer ones) —
        # only then does cohort_jobs_sorted() actually have to sort
        self._cohort_min: dict[tuple, tuple] = {}
        self._cohort_tail: dict[tuple, tuple] = {}
        self._cohort_unsorted: set[tuple] = set()
        # a draining schedd stops ACCEPTING submissions (the pool
        # service refuses them) but keeps negotiating until empty, then
        # detaches — the schedd-side mirror of backend draining
        self.draining = False

    # -- index maintenance ---------------------------------------------------
    def _enter_state(self, job: Job, state: JobState):
        self._by_state[state][job.jid] = job
        job.state = state
        if state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is None:
                cohort = self._idle_cohorts[key] = {}
                self.idle_version += 1
            cohort[job.jid] = job
            order = (job.submitted_at, job.jid)
            cur_min = self._cohort_min.get(key)
            if cur_min is None or order < cur_min:
                self._cohort_min[key] = order
            tail = self._cohort_tail.get(key)
            if tail is not None and order < tail:
                self._cohort_unsorted.add(key)
            if tail is None or order > tail:
                self._cohort_tail[key] = order
            self.idle_seq += 1
            for hook in self._idle_hooks:
                hook(job, +1)

    def _leave_state(self, job: Job):
        self._by_state[job.state].pop(job.jid, None)
        if job.state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is not None:
                cohort.pop(job.jid, None)
                if not cohort:
                    del self._idle_cohorts[key]
                    self._cohort_min.pop(key, None)
                    self._cohort_tail.pop(key, None)
                    self._cohort_unsorted.discard(key)
                    self.idle_version += 1
            self.idle_seq += 1
            for hook in self._idle_hooks:
                hook(job, -1)

    def submit(self, job: Job, now: float = 0.0) -> int:
        job.jid = next(self._ids)
        job.submitted_at = now
        job.schedd = self
        if job.cohort_key is None:
            job.cohort_key = cohort_key_of(job)
        self._jobs[job.jid] = job
        self._enter_state(job, JobState.IDLE)
        return job.jid

    def jobs(self, state: JobState | None = None) -> list[Job]:
        if state is None:
            return list(self._jobs.values())
        return list(self._by_state[state].values())

    def idle_jobs(self) -> list[Job]:
        return list(self._by_state[JobState.IDLE].values())

    def idle_cohorts(self) -> Iterator[tuple[tuple, dict[int, Job]]]:
        """(cohort_key, {jid: job}) for every non-empty idle cohort.
        Every job in a cohort matches exactly the same workers."""
        return iter(list(self._idle_cohorts.items()))

    def cohort_rep(self, key: tuple) -> Job | None:
        """One representative member of an idle cohort (all members
        carry matchmaking-identical ads), or None if the cohort is not
        currently idle.  O(1) — consumers holding bare cohort keys (the
        provisioner mapping preview absorption onto group signatures)
        must not pay a cohort scan per lookup."""
        cohort = self._idle_cohorts.get(key)
        if not cohort:
            return None
        return next(iter(cohort.values()))

    def cohort_first_submit(self, key: tuple) -> tuple:
        """Earliest (submitted_at, jid) a cohort has held while idle —
        the negotiator's cross-cohort FIFO key.  May be slightly stale
        after the oldest member leaves; a lower bound is fine for
        ordering."""
        return self._cohort_min.get(key, (float("inf"), -1))

    def cohort_jobs_sorted(self, key: tuple,
                           limit: int | None = None) -> list[Job]:
        """A cohort's idle jobs in FIFO (submission) order.  Insertion
        order already IS submission order unless a released job re-entered
        behind newer ones — then ONE sort is paid and the cohort dict is
        rebuilt in order (flag + tail reset), restoring the O(n) fast
        path for subsequent cycles.  `limit` returns only the first N —
        fair-share hands out claim budgets of a few jobs at a time, and
        must not copy a 10k-job cohort to take one."""
        cohort = self._idle_cohorts.get(key)
        if not cohort:
            return []
        if key in self._cohort_unsorted:
            jobs = sorted(cohort.values(),
                          key=lambda j: (j.submitted_at, j.jid))
            self._idle_cohorts[key] = {j.jid: j for j in jobs}
            self._cohort_unsorted.discard(key)
            last = jobs[-1]
            self._cohort_tail[key] = (last.submitted_at, last.jid)
            return jobs if limit is None else jobs[:limit]
        if limit is None or limit >= len(cohort):
            return list(cohort.values())
        return list(itertools.islice(cohort.values(), limit))

    def get(self, jid: int) -> Job:
        return self._jobs[jid]

    # -- transitions (driven by workers) -------------------------------------
    def claim(self, jid: int, worker_name: str, now: float) -> Job:
        job = self._jobs[jid]
        assert job.state == JobState.IDLE, (jid, job.state)
        self._leave_state(job)
        self._enter_state(job, JobState.RUNNING)
        job.claimed_by = worker_name
        job.attempt_started_at = now
        if job.started_at < 0:
            job.started_at = now
        user = user_of(job)
        self.running_by_user[user] = self.running_by_user.get(user, 0) + 1
        for hook in self._claim_hooks:
            hook(job, now)
        return job

    def _drop_running_user(self, job: Job):
        user = user_of(job)
        n = self.running_by_user.get(user, 0) - 1
        if n > 0:
            self.running_by_user[user] = n
        else:
            self.running_by_user.pop(user, None)

    def add_complete_hook(self, fn: Callable[[Job], None]):
        """Observe every completion as it happens (streaming stats)."""
        self._complete_hooks.append(fn)

    def add_claim_hook(self, fn: Callable[[Job, float], None]):
        """Observe every claim as it happens — the fair-share accountant
        bumps the submitter's running-core rate here."""
        self._claim_hooks.append(fn)

    def add_release_hook(self, fn: Callable[[Job, float], None]):
        """Observe every RUNNING -> IDLE release (preemption / worker
        death) — the accounting mirror of the claim hook."""
        self._release_hooks.append(fn)

    def add_idle_hook(self, fn: Callable[[Job, int], None]):
        """Observe every idle-set mutation as `fn(job, +1|-1)` — +1 when
        a job enters IDLE (submit, release), -1 when it leaves (claim,
        complete, remove).  NOT replayed by `load_state`; counter-style
        consumers must rebuild from `idle_jobs()` after a restore."""
        self._idle_hooks.append(fn)

    def complete(self, jid: int, now: float):
        job = self._jobs.pop(jid)
        if job.state == JobState.RUNNING:
            self._drop_running_user(job)
        self._leave_state(job)
        job.state = JobState.COMPLETED
        job.completed_at = now
        job.claimed_by = None
        for hook in self._complete_hooks:
            hook(job)
        if self.keep_completed:
            self.completed_log.append(job)

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Job returns to IDLE (preemption / worker death). Progress on the
        current attempt is lost — HTCondor restarts vanilla-universe jobs."""
        job = self._jobs[jid]
        if job.state != JobState.RUNNING:
            return
        if preempted:
            job.preempt_count += 1
            done = job.runtime_s - job.remaining_s  # progress so far
            # Jobs restart from scratch (HTCondor vanilla universe) unless
            # they self-checkpoint (OSG best practice; our JAX training
            # jobs do): then only progress past the last boundary is lost.
            ckpt_every = job.ad.get("checkpoint_interval_s") or 0
            kept = (done // ckpt_every) * ckpt_every if ckpt_every else 0.0
            job.wasted_s += done - kept
            job.remaining_s = job.runtime_s - kept
        self._drop_running_user(job)
        self._leave_state(job)
        self._enter_state(job, JobState.IDLE)
        job.claimed_by = None
        for hook in self._release_hooks:
            hook(job, now)

    def remove(self, jid: int, now: float) -> Job | None:
        """`condor_rm`: take a job out of the queue entirely.  Running
        jobs are released first so the release hooks fire (the fair-share
        accountant's core rates stay exact); the CALLER must also drop
        the worker-side claim (`job.claimed_by` names it).  Returns the
        removed Job, or None if the jid is unknown."""
        job = self._jobs.get(jid)
        if job is None:
            return None
        if job.state == JobState.RUNNING:
            self._drop_running_user(job)
            for hook in self._release_hooks:
                hook(job, now)
        self._leave_state(job)
        self._jobs.pop(jid, None)
        job.state = JobState.REMOVED
        job.claimed_by = None
        return job

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe snapshot.  Iteration ORDERS are part of the state:
        negotiation sorts are stable, best-fit ties break on insertion
        order, and `_cohort_min` is a possibly-stale lower bound that
        cross-cohort FIFO ordering depends on — so the snapshot carries
        jobs in `_jobs` order, per-state jid lists, the idle-cohort
        member lists in cohort order, and the raw min/tail/unsorted
        bookkeeping rather than anything recomputed.  Hooks and the
        (possibly shared) jid counter are NOT serialized — the restoring
        Simulation re-attaches hooks at construction and re-seeds the
        shared counter itself."""
        idle_order = []
        cohort_meta = []
        for key, cohort in self._idle_cohorts.items():
            idle_order.append(list(cohort.keys()))
            m = self._cohort_min.get(key)
            t = self._cohort_tail.get(key)
            cohort_meta.append({
                "min": list(m) if m is not None else None,
                "tail": list(t) if t is not None else None,
                "unsorted": key in self._cohort_unsorted,
            })
        return {
            "name": self.name,
            "draining": self.draining,
            "keep_completed": self.keep_completed,
            "idle_version": self.idle_version,
            "idle_seq": self.idle_seq,
            "jobs": [job_state(j) for j in self._jobs.values()],
            "by_state": {
                s.value: list(self._by_state[s].keys())
                for s in JobState if self._by_state[s]
            },
            "idle_order": idle_order,
            "cohort_meta": cohort_meta,
            "completed": [job_state(j) for j in self.completed_log],
        }

    def load_state(self, state: dict) -> None:
        """Restore from `state_dict()` output, rebuilding every index in
        the serialized order (NOT via submit(): that would re-fire hooks
        and reassign jids).  Leaves hooks and `_ids` untouched."""
        self.draining = bool(state.get("draining", False))
        self.keep_completed = bool(state.get("keep_completed", True))
        jobs = [job_from_state(s, schedd=self) for s in state.get("jobs", [])]
        self._jobs = {j.jid: j for j in jobs}
        self._by_state = {s: {} for s in JobState}
        for sval, jids in state.get("by_state", {}).items():
            bucket = self._by_state[JobState(sval)]
            for jid in jids:
                bucket[jid] = self._jobs[jid]
        self._idle_cohorts = {}
        self._cohort_min = {}
        self._cohort_tail = {}
        self._cohort_unsorted = set()
        for jids, meta in zip(state.get("idle_order", []),
                              state.get("cohort_meta", [])):
            members = {jid: self._jobs[jid] for jid in jids}
            key = next(iter(members.values())).cohort_key
            self._idle_cohorts[key] = members
            if meta.get("min") is not None:
                self._cohort_min[key] = tuple(meta["min"])
            if meta.get("tail") is not None:
                self._cohort_tail[key] = tuple(meta["tail"])
            if meta.get("unsorted"):
                self._cohort_unsorted.add(key)
        self.idle_version = int(state.get("idle_version", 0))
        self.idle_seq = int(state.get("idle_seq", 0))
        self.completed_log = [job_from_state(s, schedd=self)
                              for s in state.get("completed", [])]
        self.running_by_user = {}
        for j in self._by_state[JobState.RUNNING].values():
            u = user_of(j)
            self.running_by_user[u] = self.running_by_user.get(u, 0) + 1

    # -- stats ----------------------------------------------------------------
    def n_idle(self) -> int:
        return len(self._by_state[JobState.IDLE])

    def n_idle_cohorts(self) -> int:
        """Distinct matchmaking-equivalence classes currently idle — how a
        trace's requirement mix materializes in the queue (a uniform burst
        is 1; a replayed OSG day is kinds × users × Requirements)."""
        return len(self._idle_cohorts)

    def n_running(self) -> int:
        return len(self._by_state[JobState.RUNNING])

    def idle_by_user(self, now: float | None = None
                     ) -> dict[str, tuple[int, float]]:
        """{user: (idle count, starvation age)} from the idle cohorts —
        starvation age is `now` minus the oldest idle submission the
        user has CURRENTLY pending (0.0 when `now` is omitted).  One
        pass over cohorts, not jobs: the oldest live member is the
        cohort's first FIFO entry (`_cohort_min` would do — but it is
        only reset when a cohort fully drains, so a continuously-fed
        cohort would pin the age at its first-ever arrival)."""
        out: dict[str, tuple[int, float]] = {}
        for key, jobs in self._idle_cohorts.items():
            rep = next(iter(jobs.values()))
            user = user_of(rep)
            oldest = self.cohort_jobs_sorted(key, 1)[0].submitted_at
            n, prev_oldest = out.get(user, (0, float("inf")))
            out[user] = (n + len(jobs), min(prev_oldest, oldest))
        return {
            u: (n, max(0.0, (now - t) if now is not None
                       and t != float("inf") else 0.0))
            for u, (n, t) in out.items()
        }

    def drained(self) -> bool:
        return not self._jobs


class FlockedQueues:
    """Federation view over several schedds' queues, for pool
    components that held a single-queue handle (the C2 idle poll, the
    tick engine's scan negotiation, straggler mitigation).  Claims and
    completions do NOT go through this view — they route to the owning
    queue via `job.schedd`; only `release` routes here, by jid, for
    callers that hold job ids rather than Job objects."""

    def __init__(self, queues: Iterable[JobQueue]):
        self.queues = list(queues)

    @property
    def idle_version(self) -> int:
        # sum of per-queue versions: monotonic, and it changes whenever
        # any queue's idle-cohort SET changes — the property the
        # collector's C2 poll cache keys on
        return sum(q.idle_version for q in self.queues)

    @property
    def idle_seq(self) -> int:
        return sum(q.idle_seq for q in self.queues)

    def idle_cohorts(self) -> Iterator[tuple[tuple, dict[int, Job]]]:
        for q in self.queues:
            yield from q.idle_cohorts()

    def idle_jobs(self) -> list[Job]:
        out: list[Job] = []
        for q in self.queues:
            out.extend(q.idle_jobs())
        return out

    def jobs(self, state: JobState | None = None) -> list[Job]:
        out: list[Job] = []
        for q in self.queues:
            out.extend(q.jobs(state))
        return out

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Route a release to the owning queue (jids are pool-unique
        when the queues share an id counter — the straggler policy
        holds jids, not Job objects)."""
        for q in self.queues:
            if jid in q._jobs:
                q.release(jid, now, preempted=preempted)
                return
        raise KeyError(jid)

    def n_idle(self) -> int:
        return sum(q.n_idle() for q in self.queues)

    def n_idle_cohorts(self) -> int:
        return sum(q.n_idle_cohorts() for q in self.queues)

    def n_running(self) -> int:
        return sum(q.n_running() for q in self.queues)

    def drained(self) -> bool:
        return all(q.drained() for q in self.queues)
