"""The schedd: job queue with HTCondor-like job states and ads.

Jobs are pleasantly-parallel work units (the paper's OSG payload model).
Each job carries an ad (requirements + arbitrary advertised attributes) and
a simulated runtime; the "real mode" used by the examples attaches a
work_fn that advances actual JAX training steps instead.

Preemption semantics (paper §5): a preempted job transparently returns to
IDLE and reruns elsewhere; `preempt_count` and total wasted work are
tracked for the benchmarks.

Scale: the queue is fully indexed.  Jobs live in per-state buckets, so
`n_idle()` / `n_running()` are O(1), and idle jobs are additionally
bucketed into COHORTS — groups with identical ads and requirement
expressions, hence identical matchmaking behaviour.  A 100k-job campaign
of uniform jobs is ONE cohort: the negotiator and the provisioner evaluate
ClassAd expressions once per cohort instead of once per job.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Iterator

from repro.core.classad import ClassAdExpr


class JobState(enum.Enum):
    IDLE = "idle"
    RUNNING = "running"
    COMPLETED = "completed"
    HELD = "held"
    REMOVED = "removed"


@dataclasses.dataclass
class Job:
    ad: dict[str, Any]
    runtime_s: float = 60.0
    requirements: ClassAdExpr | None = None
    work_fn: Callable[["Job", float], bool] | None = None  # (job, dt) -> done
    jid: int = -1

    # lifecycle
    state: JobState = JobState.IDLE
    submitted_at: float = 0.0
    started_at: float = -1.0          # first claim (wait-time metric)
    attempt_started_at: float = -1.0  # latest claim (straggler detection)
    completed_at: float = -1.0
    remaining_s: float = dataclasses.field(default=-1.0)
    preempt_count: int = 0
    wasted_s: float = 0.0         # work lost to preemption
    claimed_by: str | None = None
    cohort_key: tuple | None = None   # assigned at submit; ad-derived

    def __post_init__(self):
        if self.remaining_s < 0:
            self.remaining_s = self.runtime_s


def _freeze(v: Any) -> Any:
    if isinstance(v, (int, float, str, bool, type(None))):
        return v
    return repr(v)


def canonical_ad(ad: dict[str, Any]) -> tuple:
    """Hashable canonical form of an ad.  Job cohorts AND worker slot
    shapes use this SAME canonicalization — the two halves jointly key
    the collector's match cache, so they must never diverge."""
    return tuple(sorted((str(k), _freeze(v)) for k, v in ad.items()))


def cohort_key_of(job: Job) -> tuple:
    """Matchmaking-equivalence key: two jobs with the same key match the
    same workers (same ad contents, same Requirements expression)."""
    req = job.requirements.src if job.requirements is not None else ""
    return (req, canonical_ad(job.ad))


class JobQueue:
    """Single schedd. The provisioner and the workers both query it — the
    workers through the collector's matchmaking (worker.py).

    Completion streaming: `add_complete_hook(fn)` registers observers
    called once per completed job, and `keep_completed = False` stops the
    queue retaining completed `Job` objects in `completed_log` — together
    they let a 100k-arrival trace replay aggregate wait/goodput stats
    without ever holding more than the in-flight jobs alive
    (workload/replay.py)."""

    def __init__(self):
        self._jobs: dict[int, Job] = {}
        self._ids = itertools.count()
        self.completed_log: list[Job] = []
        self.keep_completed = True
        self._complete_hooks: list[Callable[[Job], None]] = []
        # bumped whenever the SET of idle cohorts changes (a cohort is
        # born or drained) — the collector's C2 idle-poll verdict for an
        # unclaimed worker is a pure function of this set, so workers
        # cache it per version (worker.py any_cohort_matches)
        self.idle_version = 0
        # indexes: per-state buckets + idle cohorts (jid -> Job each)
        self._by_state: dict[JobState, dict[int, Job]] = {
            s: {} for s in JobState
        }
        self._idle_cohorts: dict[tuple, dict[int, Job]] = {}
        # per-cohort FIFO bookkeeping: earliest (submitted_at, jid) seen
        # (sort key across cohorts) and whether insertion order ever
        # violated FIFO (a released job re-entering behind newer ones) —
        # only then does cohort_jobs_sorted() actually have to sort
        self._cohort_min: dict[tuple, tuple] = {}
        self._cohort_tail: dict[tuple, tuple] = {}
        self._cohort_unsorted: set[tuple] = set()

    # -- index maintenance ---------------------------------------------------
    def _enter_state(self, job: Job, state: JobState):
        self._by_state[state][job.jid] = job
        job.state = state
        if state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is None:
                cohort = self._idle_cohorts[key] = {}
                self.idle_version += 1
            cohort[job.jid] = job
            order = (job.submitted_at, job.jid)
            cur_min = self._cohort_min.get(key)
            if cur_min is None or order < cur_min:
                self._cohort_min[key] = order
            tail = self._cohort_tail.get(key)
            if tail is not None and order < tail:
                self._cohort_unsorted.add(key)
            if tail is None or order > tail:
                self._cohort_tail[key] = order

    def _leave_state(self, job: Job):
        self._by_state[job.state].pop(job.jid, None)
        if job.state == JobState.IDLE:
            key = job.cohort_key
            cohort = self._idle_cohorts.get(key)
            if cohort is not None:
                cohort.pop(job.jid, None)
                if not cohort:
                    del self._idle_cohorts[key]
                    self._cohort_min.pop(key, None)
                    self._cohort_tail.pop(key, None)
                    self._cohort_unsorted.discard(key)
                    self.idle_version += 1

    def submit(self, job: Job, now: float = 0.0) -> int:
        job.jid = next(self._ids)
        job.submitted_at = now
        if job.cohort_key is None:
            job.cohort_key = cohort_key_of(job)
        self._jobs[job.jid] = job
        self._enter_state(job, JobState.IDLE)
        return job.jid

    def jobs(self, state: JobState | None = None) -> list[Job]:
        if state is None:
            return list(self._jobs.values())
        return list(self._by_state[state].values())

    def idle_jobs(self) -> list[Job]:
        return list(self._by_state[JobState.IDLE].values())

    def idle_cohorts(self) -> Iterator[tuple[tuple, dict[int, Job]]]:
        """(cohort_key, {jid: job}) for every non-empty idle cohort.
        Every job in a cohort matches exactly the same workers."""
        return iter(list(self._idle_cohorts.items()))

    def cohort_first_submit(self, key: tuple) -> tuple:
        """Earliest (submitted_at, jid) a cohort has held while idle —
        the negotiator's cross-cohort FIFO key.  May be slightly stale
        after the oldest member leaves; a lower bound is fine for
        ordering."""
        return self._cohort_min.get(key, (float("inf"), -1))

    def cohort_jobs_sorted(self, key: tuple) -> list[Job]:
        """A cohort's idle jobs in FIFO (submission) order.  Insertion
        order already IS submission order unless a released job re-entered
        behind newer ones — then ONE sort is paid and the cohort dict is
        rebuilt in order (flag + tail reset), restoring the O(n) fast
        path for subsequent cycles."""
        cohort = self._idle_cohorts.get(key)
        if not cohort:
            return []
        if key not in self._cohort_unsorted:
            return list(cohort.values())
        jobs = sorted(cohort.values(),
                      key=lambda j: (j.submitted_at, j.jid))
        self._idle_cohorts[key] = {j.jid: j for j in jobs}
        self._cohort_unsorted.discard(key)
        last = jobs[-1]
        self._cohort_tail[key] = (last.submitted_at, last.jid)
        return jobs

    def get(self, jid: int) -> Job:
        return self._jobs[jid]

    # -- transitions (driven by workers) -------------------------------------
    def claim(self, jid: int, worker_name: str, now: float) -> Job:
        job = self._jobs[jid]
        assert job.state == JobState.IDLE, (jid, job.state)
        self._leave_state(job)
        self._enter_state(job, JobState.RUNNING)
        job.claimed_by = worker_name
        job.attempt_started_at = now
        if job.started_at < 0:
            job.started_at = now
        return job

    def add_complete_hook(self, fn: Callable[[Job], None]):
        """Observe every completion as it happens (streaming stats)."""
        self._complete_hooks.append(fn)

    def complete(self, jid: int, now: float):
        job = self._jobs.pop(jid)
        self._leave_state(job)
        job.state = JobState.COMPLETED
        job.completed_at = now
        job.claimed_by = None
        for hook in self._complete_hooks:
            hook(job)
        if self.keep_completed:
            self.completed_log.append(job)

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Job returns to IDLE (preemption / worker death). Progress on the
        current attempt is lost — HTCondor restarts vanilla-universe jobs."""
        job = self._jobs[jid]
        if job.state != JobState.RUNNING:
            return
        if preempted:
            job.preempt_count += 1
            done = job.runtime_s - job.remaining_s  # progress so far
            # Jobs restart from scratch (HTCondor vanilla universe) unless
            # they self-checkpoint (OSG best practice; our JAX training
            # jobs do): then only progress past the last boundary is lost.
            ckpt_every = job.ad.get("checkpoint_interval_s") or 0
            kept = (done // ckpt_every) * ckpt_every if ckpt_every else 0.0
            job.wasted_s += done - kept
            job.remaining_s = job.runtime_s - kept
        self._leave_state(job)
        self._enter_state(job, JobState.IDLE)
        job.claimed_by = None

    # -- stats ----------------------------------------------------------------
    def n_idle(self) -> int:
        return len(self._by_state[JobState.IDLE])

    def n_idle_cohorts(self) -> int:
        """Distinct matchmaking-equivalence classes currently idle — how a
        trace's requirement mix materializes in the queue (a uniform burst
        is 1; a replayed OSG day is kinds × users × Requirements)."""
        return len(self._idle_cohorts)

    def n_running(self) -> int:
        return len(self._by_state[JobState.RUNNING])

    def drained(self) -> bool:
        return not self._jobs
