"""The schedd: job queue with HTCondor-like job states and ads.

Jobs are pleasantly-parallel work units (the paper's OSG payload model).
Each job carries an ad (requirements + arbitrary advertised attributes) and
a simulated runtime; the "real mode" used by the examples attaches a
work_fn that advances actual JAX training steps instead.

Preemption semantics (paper §5): a preempted job transparently returns to
IDLE and reruns elsewhere; `preempt_count` and total wasted work are
tracked for the benchmarks.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable

from repro.core.classad import ClassAdExpr


class JobState(enum.Enum):
    IDLE = "idle"
    RUNNING = "running"
    COMPLETED = "completed"
    HELD = "held"
    REMOVED = "removed"


@dataclasses.dataclass
class Job:
    ad: dict[str, Any]
    runtime_s: float = 60.0
    requirements: ClassAdExpr | None = None
    work_fn: Callable[["Job", float], bool] | None = None  # (job, dt) -> done
    jid: int = -1

    # lifecycle
    state: JobState = JobState.IDLE
    submitted_at: float = 0.0
    started_at: float = -1.0          # first claim (wait-time metric)
    attempt_started_at: float = -1.0  # latest claim (straggler detection)
    completed_at: float = -1.0
    remaining_s: float = dataclasses.field(default=-1.0)
    preempt_count: int = 0
    wasted_s: float = 0.0         # work lost to preemption
    claimed_by: str | None = None

    def __post_init__(self):
        if self.remaining_s < 0:
            self.remaining_s = self.runtime_s


class JobQueue:
    """Single schedd. The provisioner and the workers both query it — the
    workers through the collector's matchmaking (worker.py)."""

    def __init__(self):
        self._jobs: dict[int, Job] = {}
        self._ids = itertools.count()
        self.completed_log: list[Job] = []

    def submit(self, job: Job, now: float = 0.0) -> int:
        job.jid = next(self._ids)
        job.submitted_at = now
        job.state = JobState.IDLE
        self._jobs[job.jid] = job
        return job.jid

    def jobs(self, state: JobState | None = None) -> list[Job]:
        if state is None:
            return list(self._jobs.values())
        return [j for j in self._jobs.values() if j.state == state]

    def idle_jobs(self) -> list[Job]:
        return self.jobs(JobState.IDLE)

    def get(self, jid: int) -> Job:
        return self._jobs[jid]

    # -- transitions (driven by workers) -------------------------------------
    def claim(self, jid: int, worker_name: str, now: float) -> Job:
        job = self._jobs[jid]
        assert job.state == JobState.IDLE, (jid, job.state)
        job.state = JobState.RUNNING
        job.claimed_by = worker_name
        job.attempt_started_at = now
        if job.started_at < 0:
            job.started_at = now
        return job

    def complete(self, jid: int, now: float):
        job = self._jobs.pop(jid)
        job.state = JobState.COMPLETED
        job.completed_at = now
        job.claimed_by = None
        self.completed_log.append(job)

    def release(self, jid: int, now: float, *, preempted: bool = True):
        """Job returns to IDLE (preemption / worker death). Progress on the
        current attempt is lost — HTCondor restarts vanilla-universe jobs."""
        job = self._jobs[jid]
        if job.state != JobState.RUNNING:
            return
        if preempted:
            job.preempt_count += 1
            done = job.runtime_s - job.remaining_s  # progress so far
            # Jobs restart from scratch (HTCondor vanilla universe) unless
            # they self-checkpoint (OSG best practice; our JAX training
            # jobs do): then only progress past the last boundary is lost.
            ckpt_every = job.ad.get("checkpoint_interval_s") or 0
            kept = (done // ckpt_every) * ckpt_every if ckpt_every else 0.0
            job.wasted_s += done - kept
            job.remaining_s = job.runtime_s - kept
        job.state = JobState.IDLE
        job.claimed_by = None

    # -- stats ----------------------------------------------------------------
    def n_idle(self) -> int:
        return len(self.idle_jobs())

    def n_running(self) -> int:
        return len(self.jobs(JobState.RUNNING))

    def drained(self) -> bool:
        return not self._jobs
