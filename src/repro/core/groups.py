"""Requirement grouping (paper §2 C4): the key delta vs. Kubernetes HPA.

Heterogeneous idle jobs are quantized into signatures; each signature is an
independent provisioning stream whose pods request exactly the signature's
resources.  The paper groups on (CPU, GPU, memory, disk) "but could be
extended" — our TPU adaptation extends it with (chips, hbm_gb, arch) so a
mamba2 decode job and a llama4 train job never share a pod shape.

Quantization: memory/disk are bucketed to the next power-of-two GB so
near-identical requests share a group (avoids one group per byte count);
cpu/gpu/chips are exact (small integers).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable

from repro.core.jobqueue import Job

GroupKey = tuple


@dataclasses.dataclass(frozen=True)
class GroupSignature:
    cpus: int = 1
    gpus: int = 0
    memory_gb: int = 4          # pow2-bucketed
    disk_gb: int = 8            # pow2-bucketed
    chips: int = 0              # TPU extension
    hbm_gb: int = 0
    arch: str | None = None     # job class label (extension attr)

    def as_pod_request(self) -> dict[str, float]:
        req = {
            "cpu": float(self.cpus),
            "memory": float(self.memory_gb),
            "disk": float(self.disk_gb),
        }
        if self.gpus:
            req["gpu"] = float(self.gpus)
        if self.chips:
            req["chips"] = float(self.chips)
        return req

    def as_worker_ad(self) -> dict[str, Any]:
        ad: dict[str, Any] = {
            "cpus": self.cpus,
            "gpus": self.gpus,
            "memory": self.memory_gb,
            "disk": self.disk_gb,
        }
        if self.chips:
            ad["chips"] = self.chips
            ad["hbm_gb"] = self.hbm_gb
        if self.arch:
            ad["arch"] = self.arch
        return ad


def _pow2_bucket(x: float, lo: int = 1) -> int:
    if x <= lo:
        return lo
    return 1 << math.ceil(math.log2(x))


def signature_of(job: Job, *, extra_keys: tuple[str, ...] = ("arch",)
                 ) -> GroupSignature:
    ad = job.ad
    return GroupSignature(
        cpus=int(ad.get("request_cpus", 1) or 1),
        gpus=int(ad.get("request_gpus", 0) or 0),
        memory_gb=_pow2_bucket(float(ad.get("request_memory", 4) or 4)),
        disk_gb=_pow2_bucket(float(ad.get("request_disk", 8) or 8)),
        chips=int(ad.get("request_chips", 0) or 0),
        hbm_gb=int(ad.get("request_hbm_gb", 0) or 0),
        arch=ad.get("arch") if "arch" in extra_keys else None,
    )


def group_jobs(jobs: Iterable[Job]) -> dict[GroupSignature, list[Job]]:
    groups: dict[GroupSignature, list[Job]] = {}
    for job in jobs:
        groups.setdefault(signature_of(job), []).append(job)
    return groups


def matches_signature(ad: dict, sig: GroupSignature) -> bool:
    """Does a worker ad belong to this provisioning group? (used when
    counting unclaimed workers against the group's deficit)."""
    w = sig.as_worker_ad()
    for k, v in w.items():
        if ad.get(k) != v:
            return False
    return True
