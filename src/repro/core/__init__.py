"""The paper's contribution: demand-driven auto-scaling provisioning of
Kubernetes-managed resources into HTCondor pools (Sfiligoi et al., PEARC22).
"""
from repro.core.classad import ClassAdExpr, symmetric_match, UNDEFINED
from repro.core.events import EventHandle, EventLoop, PeriodicHandle
from repro.core.fairshare import (
    Accountant, ScheddSpec, UsageLedger, job_cores, make_schedd_specs,
)
from repro.core.jobqueue import (
    FlockedQueues, Job, JobQueue, JobState, cohort_key_of, user_of,
)
from repro.core.cluster import KubeCluster, Node, Pod, PodPhase
from repro.core.matchmaker import (
    HAVE_JAX, JaxMatchmaker, MatchPlan, MatchProblem, Matchmaker,
    NumpyMatchmaker, RESOURCE_KEYS, ScanMatchmaker, make_matchmaker,
    matchmaker_names, register_matchmaker,
)
from repro.core.worker import (
    Collector, LRUCache, Worker, advance_workers, kill_worker,
)
from repro.core.groups import GroupSignature, group_jobs, signature_of
from repro.core.config import (
    BackendConfig, ProvisionerConfig, dump_ini, load_ini, PAPER_EXAMPLE_INI,
)
from repro.core.backend import (
    FederatedClusterView, KubeBackend, PodSpec, ROUTING_POLICIES,
    RoutingPolicy, ScalingBackend, adapt_single_cluster, backend_from_config,
    build_backends, make_routing_policy,
)
from repro.core.provisioner import Provisioner
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate
from repro.core.simulation import Simulation, gpu_job, onprem_nodes
from repro.core.metrics import (
    CompletedStats, Recorder, percentile, summarize_backends, timeline,
)
from repro.core.stragglers import StragglerPolicy
