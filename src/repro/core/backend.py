"""Pluggable scaling backends: one provisioner, many resource providers.

The paper runs identical provisioning logic against an on-prem Kubernetes
cluster (PRP/Nautilus, §2–§5) and a cloud deployment with node
auto-provisioning (GKE NAP, §6); its OSG follow-up generalizes this to
many heterogeneous providers feeding one HTCondor pool.  A
`ScalingBackend` is the seam that makes that federation possible: it
bundles a pod-placement surface (`KubeCluster`), an optional
`NodeAutoscaler`, a cost model, capacity limits, and a readiness view
behind a uniform interface —

    pending(label)     pods of a provisioning group still waiting
    submit(spec, now)  place a pod request on this provider
    tick(now, dt)      advance autoscaler / scheduler / cost accounting
    cost_rate()        current $/s burn
    headroom(request)  pods of this shape the provider can still absorb

The provisioner never talks to a cluster directly any more; it asks a
`RoutingPolicy` to split each group's deficit across an ordered list of
backends (fill-onprem-first, cheapest-first, weighted-spread,
spot-with-fallback) and attributes stats per backend.  A single
`KubeCluster` is adapted into a one-element backend list, so the paper's
single-provider deployment is just the degenerate case.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable, Protocol, runtime_checkable

from repro.core.cluster import KubeCluster, Node, Pod, PodPhase
from repro.core.config import BackendConfig, ProvisionerConfig
from repro.core.nodescaler import NodeAutoscaler, NodeTemplate

OWNER = "prp-provisioner"


# ---------------------------------------------------------------------------
# Pod requests as data (what the provisioner hands a backend)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PodSpec:
    """Provider-independent pod request.  The backend applies its own
    priority class / tolerations / affinity on top before placement."""
    name: str
    request: dict[str, float]
    labels: dict[str, str] = dataclasses.field(default_factory=dict)
    priority_class: str = "default"
    tolerations: tuple[str, ...] = ()
    node_selector: dict[str, Any] = dataclasses.field(default_factory=dict)
    anti_affinity: dict[str, Any] = dataclasses.field(default_factory=dict)
    on_start: Callable[[Pod, float], None] | None = None
    on_stop: Callable[[Pod, float, str], None] | None = None


@dataclasses.dataclass
class BackendStats:
    pods_submitted: int = 0
    pods_reclaimed: int = 0
    cost_total: float = 0.0          # integrated $ spent


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class ScalingBackend(Protocol):
    """Anything that can turn pod requests into HTCondor execute capacity.

    The full surface the provisioner, routing policies, simulation, and
    metrics rely on — implement all of it (subclassing `KubeBackend` is
    the easy path; `autoscaler` may be None and `reclaim` may be a
    no-op for non-spot providers)."""
    name: str
    cluster: KubeCluster
    autoscaler: NodeAutoscaler | None
    stats: BackendStats
    spot: bool
    weight: float

    def pending(self, label: str | None = None) -> int: ...
    def submit(self, spec: PodSpec, now: float) -> str: ...
    def tick(self, now: float, dt: float) -> None: ...
    def cost_rate(self) -> float: ...
    def marginal_pod_cost(self, request: dict[str, float]) -> float: ...
    def headroom(self, request: dict[str, float]) -> int: ...
    def live_pods(self) -> int: ...
    def healthy(self) -> bool: ...
    def reclaim(self, frac: float, now: float, rng=None) -> int: ...


# ---------------------------------------------------------------------------
# The Kubernetes-backed implementation (covers static + autoscaled + spot)
# ---------------------------------------------------------------------------

class KubeBackend:
    """A Kubernetes resource provider: a static on-prem cluster when
    `autoscaler` is None, a NAP-style elastic pool when it is set, a spot
    pool when `spot` is additionally true (reclaims via `reclaim()`)."""

    def __init__(
        self,
        name: str,
        cluster: KubeCluster,
        autoscaler: NodeAutoscaler | None = None,
        *,
        max_pods: int = 1_000_000,
        priority_class: str = "",          # "" -> use the PodSpec's
        tolerations: tuple[str, ...] = (),
        node_affinity: dict[str, Any] | None = None,
        node_hourly_cost: float = 0.0,
        pod_hourly_cost: float = 0.0,
        spot: bool = False,
        weight: float = 1.0,
    ):
        self.name = name
        self.cluster = cluster
        self.autoscaler = autoscaler
        self.max_pods = max_pods
        self.priority_class = priority_class
        self.tolerations = tolerations
        self.node_affinity = dict(node_affinity or {})
        if autoscaler is not None and node_hourly_cost == 0.0:
            node_hourly_cost = autoscaler.template.hourly_cost
        self.node_hourly_cost = node_hourly_cost
        self.pod_hourly_cost = pod_hourly_cost
        self.spot = spot
        self.weight = weight
        self.stats = BackendStats()
        self._cost_t = 0.0            # cost accrued up to this sim time
        # a draining backend reports unhealthy (every routing policy
        # filters on healthy()), so no NEW pods route here; existing
        # claims run to completion, then the simulation detaches it
        # (Simulation.drain_backend)
        self.draining = False

    # -- ScalingBackend surface ---------------------------------------------
    def pending(self, label: str | None = None) -> int:
        def sel(p: Pod) -> bool:
            if p.labels.get("owner") != OWNER:
                return False
            return label is None or p.labels.get("provision-group") == label
        return len(self.cluster.pending_pods(sel))

    def live_pods(self) -> int:
        sel = (lambda p: p.labels.get("owner") == OWNER)
        return (len(self.cluster.pending_pods(sel))
                + len(self.cluster.running_pods(sel)))

    def submit(self, spec: PodSpec, now: float) -> str:
        selector = dict(spec.node_selector)
        anti = dict(spec.anti_affinity)
        for k, v in self.node_affinity.items():
            if k.startswith("^"):
                anti[k[1:]] = v
            else:
                selector[k] = v
        pod = Pod(
            name=spec.name,
            request=dict(spec.request),
            priority_class=self.priority_class or spec.priority_class,
            tolerations=self.tolerations or spec.tolerations,
            node_selector=selector,
            labels={
                **spec.labels,
                "backend": self.name,
                **({"anti-affinity": ",".join(anti)} if anti else {}),
            },
            on_start=spec.on_start,
            on_stop=spec.on_stop,
        )
        self.stats.pods_submitted += 1
        return self.cluster.create_pod(pod, now)

    def tick(self, now: float, dt: float) -> None:
        """Advance this provider by one interval ending at `now`: accrue
        cost at the pre-mutation rate, then node autoscaler, pod
        scheduler, and (lazy, exact-to-`now`) accounting.  Under the
        event engine this runs as a periodic event-loop callback
        (`schedule_backend_on`); the tick engine still polls it."""
        self.accrue_cost(now)         # BEFORE nodes change: a node added
        #                               at `now` is not billed for the past
        if self.autoscaler is not None:
            self.autoscaler.tick(now, dt)
        self.cluster.schedule(now)
        self.cluster.tick_accounting(dt, now)

    def accrue_cost(self, now: float):
        """Integrate $ burn continuously up to `now` at the current rate
        (rate changes between accrual points bill at the newer rate for
        the elapsed slice — bounded by the tick interval).  Idempotent at
        fixed `now`; the simulation flushes it before every summary so
        partial final intervals are charged."""
        if now > self._cost_t:
            self.stats.cost_total += self.cost_rate() * (now - self._cost_t)
            self._cost_t = now

    def rebase(self, now: float) -> None:
        """Align a backend constructed at t=0 with a pool already at
        `now` (runtime `Simulation.add_backend`): cost accrual and node
        alive-time integrals start at attach, not at the epoch — a
        static cluster added at t=5000 must not bill 5000s of history."""
        self._cost_t = now
        for n in self.cluster.nodes.values():
            n.created_at = now
        for name in list(self.cluster._acct_t):
            self.cluster._acct_t[name] = now

    def cost_rate(self) -> float:
        """Current burn in $/s: billed nodes plus per-pod surcharges."""
        if self.autoscaler is not None:
            n_nodes = self.autoscaler.live_nodes()
        else:
            n_nodes = len(self.cluster.nodes)
        n_pods = len(self.cluster.running_pods(
            lambda p: p.labels.get("owner") == OWNER))
        return (n_nodes * self.node_hourly_cost
                + n_pods * self.pod_hourly_cost) / 3600.0

    def headroom(self, request: dict[str, float]) -> int:
        """Pods of this shape the backend can still absorb: free capacity
        on live nodes (minus what pending pods will consume), plus — for
        elastic backends — capacity the autoscaler may still add."""
        fits = 0
        for name, node in self.cluster.nodes.items():
            free = node.allocatable((), used=self.cluster.node_used(name))
            fits += _pods_fit(free, request)
        fits -= self.pending(None)       # queued pods will eat capacity
        fits = max(0, fits)
        if self.autoscaler is not None:
            a = self.autoscaler
            room_nodes = max(
                0, a.max_nodes - a.live_nodes() - len(a._booting))
            fits += room_nodes * _pods_fit(a.template.capacity, request)
        return max(0, min(fits, self.max_pods - self.live_pods()))

    def healthy(self) -> bool:
        if self.draining:
            return False                      # stop routing; drain out
        if self.autoscaler is not None:
            return True                       # can always (try to) grow
        return bool(self.cluster.nodes)

    def health(self) -> dict[str, Any]:
        """Readiness view (what a /healthz of the provider would say)."""
        return {
            "healthy": self.healthy(),
            "draining": self.draining,
            "live_nodes": len(self.cluster.nodes),
            "booting_nodes": (len(self.autoscaler._booting)
                              if self.autoscaler else 0),
            "pending_pods": self.pending(None),
            "live_pods": self.live_pods(),
            "cost_rate_per_h": self.cost_rate() * 3600.0,
        }

    # -- cost model ----------------------------------------------------------
    def marginal_pod_cost(self, request: dict[str, float]) -> float:
        """$/h for one MORE pod of this shape.  Static nodes are sunk cost
        (marginal ≈ pod surcharge); elastic nodes amortize the node price
        over the pods that share it."""
        cost = self.pod_hourly_cost
        if self.autoscaler is not None:
            per_node = _pods_fit(self.autoscaler.template.capacity, request)
            if per_node > 0:
                cost += self.node_hourly_cost / per_node
            else:
                cost += self.node_hourly_cost
        return cost

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-safe snapshot of the MUTABLE half: cluster, autoscaler,
        stats, cost accrual point, drain flag.  Configuration (costs,
        affinity, limits) is not serialized — restore targets a backend
        built from the same config."""
        out = {
            "name": self.name,
            "draining": self.draining,
            "cost_t": self._cost_t,
            "stats": dataclasses.asdict(self.stats),
            "cluster": self.cluster.state_dict(),
        }
        if self.autoscaler is not None:
            out["autoscaler"] = self.autoscaler.state_dict()
        return out

    def load_state(self, state: dict) -> None:
        if state.get("name") != self.name:
            raise ValueError(
                f"backend snapshot is for {state.get('name')!r}, "
                f"not {self.name!r}")
        self.draining = bool(state.get("draining", False))
        self._cost_t = float(state.get("cost_t", 0.0))
        self.stats = BackendStats(**state.get("stats", {}))
        self.cluster.load_state(state["cluster"])
        if self.autoscaler is not None and "autoscaler" in state:
            self.autoscaler.load_state(state["autoscaler"])

    # -- spot dynamics -------------------------------------------------------
    def reclaim(self, frac: float, now: float, rng=None) -> int:
        """Spot-style reclaim of a fraction of running provisioner pods
        on THIS backend (§5: preemption is routine, not exceptional)."""
        pods = self.cluster.running_pods(
            lambda p: p.labels.get("owner") == OWNER)
        if not pods:
            return 0
        k = max(1, int(len(pods) * frac))
        if rng is not None:
            idx = list(rng.permutation(len(pods))[:k])
        else:
            idx = list(range(k))
        for i in idx:
            self.cluster.delete_pod(pods[i].name, now, "preempted")
        self.stats.pods_reclaimed += len(idx)
        return len(idx)


def schedule_backend_on(backend, loop, interval_s: float, *,
                        priority: int = 0):
    """Drive any ScalingBackend from a discrete-event loop: periodic
    `tick`s at exact cadence (the k-th fires at now + k*interval and
    accounts the interval ENDING at its firing), preceded by a zero-dt
    priming pass at t=now so pods submitted by the first reconcile place
    immediately, like the seed's first tick did.  Works for backends that
    only implement the Protocol (no event-loop awareness required)."""
    loop.schedule(loop.now, lambda now: backend.tick(now, 0.0),
                  name=f"backend:{backend.name}:prime", priority=priority)
    return loop.every(interval_s,
                      lambda now: backend.tick(now, interval_s),
                      first=loop.now + interval_s,
                      name=f"backend:{backend.name}", priority=priority)


def _pods_fit(free: dict[str, float], request: dict[str, float]) -> int:
    n = float("inf")
    for k, v in request.items():
        if v > 0:
            n = min(n, free.get(k, 0) // v)
    return int(n) if n != float("inf") else 0


# ---------------------------------------------------------------------------
# Routing policies: how a group's deficit is split across backends
# ---------------------------------------------------------------------------

class RoutingPolicy:
    """Base policy: fill backends in declaration order (on-prem first is
    just 'declare on-prem first').  Demand beyond every backend's headroom
    queues as pending pods on the overflow target — pending pods are free
    and HTCondor demand is bursty (same rationale as the provisioner's
    no-delete default)."""

    name = "fill-first"

    def order(self, backends: list, request: dict[str, float]) -> list:
        return [b for b in backends if b.healthy()] or list(backends)

    def overflow_target(self, order: list):
        return order[0] if order else None

    def split(self, n: int, request: dict[str, float], backends: list,
              now: float) -> list[tuple[Any, int]]:
        order = self.order(list(backends), request)
        alloc: dict[str, int] = {}
        by_name = {b.name: b for b in order}
        left = n
        for b in order:
            if left <= 0:
                break
            k = min(left, b.headroom(request))
            if k > 0:
                alloc[b.name] = alloc.get(b.name, 0) + k
                left -= k
        if left > 0:
            tgt = self.overflow_target(order)
            if tgt is not None:
                alloc[tgt.name] = alloc.get(tgt.name, 0) + left
        return [(by_name[name], k) for name, k in alloc.items() if k > 0]


class FillFirstRouting(RoutingPolicy):
    name = "fill-first"


class CheapestFirstRouting(RoutingPolicy):
    """Order by marginal $/h for one more pod of the group's shape; ties
    break by declaration order (so on-prem beats equally-free spot)."""

    name = "cheapest-first"

    def order(self, backends, request):
        healthy = super().order(backends, request)
        idx = {b.name: i for i, b in enumerate(backends)}
        return sorted(
            healthy,
            key=lambda b: (b.marginal_pod_cost(request), idx[b.name]),
        )


class WeightedSpreadRouting(RoutingPolicy):
    """Split proportionally to backend weights (clamped to headroom);
    the remainder falls through fill-first over the same order."""

    name = "weighted-spread"

    def split(self, n, request, backends, now):
        order = self.order(list(backends), request)
        if not order:
            return []
        total_w = sum(max(b.weight, 0.0) for b in order) or 1.0
        alloc: dict[str, int] = {}
        head = {b.name: b.headroom(request) for b in order}
        left = n
        for b in order:
            want = int(n * max(b.weight, 0.0) / total_w)
            k = min(want, head[b.name], left)
            if k > 0:
                alloc[b.name] = k
                head[b.name] -= k
                left -= k
        for b in order:                      # fill-first the remainder
            if left <= 0:
                break
            k = min(left, head[b.name])
            if k > 0:
                alloc[b.name] = alloc.get(b.name, 0) + k
                left -= k
        if left > 0:
            tgt = self.overflow_target(order)
            if tgt is not None:
                alloc[tgt.name] = alloc.get(tgt.name, 0) + left
        by_name = {b.name: b for b in order}
        return [(by_name[name], k) for name, k in alloc.items() if k > 0]


class SpotWithFallbackRouting(RoutingPolicy):
    """Prefer spot capacity (cheap, reclaimable); fall back to on-demand
    when spot headroom is exhausted.  Overflow queues on the FALLBACK,
    not on spot — a pod stuck pending on a reclaimable pool is the worst
    of both worlds."""

    name = "spot-with-fallback"

    def order(self, backends, request):
        healthy = super().order(backends, request)
        idx = {b.name: i for i, b in enumerate(backends)}
        return sorted(healthy, key=lambda b: (not b.spot, idx[b.name]))

    def overflow_target(self, order):
        for b in order:
            if not b.spot:
                return b
        return order[0] if order else None


ROUTING_POLICIES: dict[str, type[RoutingPolicy]] = {
    p.name: p for p in (
        FillFirstRouting, CheapestFirstRouting, WeightedSpreadRouting,
        SpotWithFallbackRouting,
    )
}


def make_routing_policy(name: str) -> RoutingPolicy:
    try:
        return ROUTING_POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown routing policy {name!r}; "
            f"known: {sorted(ROUTING_POLICIES)}") from None


# ---------------------------------------------------------------------------
# Builders / adapters
# ---------------------------------------------------------------------------

def adapt_single_cluster(cluster: KubeCluster,
                         autoscaler: NodeAutoscaler | None = None,
                         name: str = "default") -> KubeBackend:
    """The compatibility adapter: one bare KubeCluster (+ optional
    autoscaler) becomes a one-element backend list — the paper's original
    single-provider deployment."""
    return KubeBackend(name, cluster, autoscaler)


def backend_from_config(bc: BackendConfig) -> KubeBackend:
    """Materialize one `[backend:<name>]` INI section."""
    if bc.kind not in ("static", "autoscale"):
        raise ValueError(
            f"[backend:{bc.name}] unknown kind {bc.kind!r}; "
            "expected 'static' or 'autoscale'")
    cluster = KubeCluster([], name=bc.name)
    autoscaler = None
    if bc.kind == "autoscale":
        tmpl = NodeTemplate(
            capacity=dict(bc.capacity),
            labels=dict(bc.node_labels),
            taints=bc.taints,
            provision_delay_s=bc.provision_delay_s,
            scale_down_delay_s=bc.scale_down_delay_s,
            hourly_cost=bc.node_hourly_cost,
        )
        autoscaler = NodeAutoscaler(cluster, tmpl, max_nodes=bc.max_nodes,
                                    prefix=f"{bc.name}-np")
    else:
        for i in range(bc.nodes):
            cluster.add_node(
                Node(name=f"{bc.name}-{i}", capacity=dict(bc.capacity),
                     labels=dict(bc.node_labels), taints=bc.taints),
                now=0.0,
            )
    return KubeBackend(
        bc.name, cluster, autoscaler,
        max_pods=bc.max_pods,
        priority_class=bc.priority_class,
        tolerations=bc.tolerations,
        node_affinity=bc.node_affinity,
        node_hourly_cost=bc.node_hourly_cost,
        pod_hourly_cost=bc.pod_hourly_cost,
        spot=bc.spot,
        weight=bc.weight,
    )


def build_backends(cfg: ProvisionerConfig) -> list[KubeBackend]:
    """All `[backend:*]` sections of a config, in declaration order."""
    return [backend_from_config(bc) for bc in cfg.backends]


class FederatedClusterView:
    """Read/terminate view over every backend's cluster, for components
    (advance_workers) that held a single-cluster handle.  Pod names are
    globally unique (one provisioner counter), so dispatch is a scan."""

    def __init__(self, backends: Iterable):
        self.backends = list(backends)

    def _owning(self, pod_name: str) -> KubeCluster | None:
        for b in self.backends:
            if pod_name in b.cluster.pods:
                return b.cluster
        return None

    def succeed_pod(self, name: str, now: float):
        c = self._owning(name)
        if c is not None:
            c.succeed_pod(name, now)

    def delete_pod(self, name: str, now: float, reason: str = "deleted"):
        c = self._owning(name)
        if c is not None:
            c.delete_pod(name, now, reason)

    @property
    def pods(self) -> dict[str, Pod]:
        out: dict[str, Pod] = {}
        for b in self.backends:
            out.update(b.cluster.pods)
        return out

    def pending_pods(self, selector=None) -> list[Pod]:
        out: list[Pod] = []
        for b in self.backends:
            out.extend(b.cluster.pending_pods(selector))
        return out

    def running_pods(self, selector=None) -> list[Pod]:
        out: list[Pod] = []
        for b in self.backends:
            out.extend(b.cluster.running_pods(selector))
        return out
