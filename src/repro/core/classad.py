"""ClassAd-style matchmaking expressions (paper §2 C3, HTCondor semantics).

HTCondor matches a job to a machine by evaluating the job's Requirements
against the machine ad and the machine's START policy against the job ad.
We reproduce the essentials with Python expression syntax, safely evaluated
over an AST whitelist (no builtins, no calls except whitelisted helpers):

    expr   := python expression
    names  := resolve in MY ad first, then TARGET ad (HTCondor scoping);
              explicit MY.x / TARGET.x / my.x / target.x also work
    absent := attributes missing from both ads evaluate to UNDEFINED, which
              is falsy and propagates through comparisons (HTCondor 3-value
              logic approximated: UNDEFINED comparisons are False)

The provisioner evaluates the SAME filter expression on the job side (which
jobs to count, §2) and pushes it into the worker START policy (which jobs a
provisioned pod may claim) — the paper's symmetric-filter design, so a
worker never claims a job that wasn't counted toward its provisioning.
"""
from __future__ import annotations

import ast
from typing import Any, Mapping


class Undefined:
    """HTCondor UNDEFINED: falsy; all rich comparisons return False."""

    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __bool__(self):
        return False

    def __repr__(self):
        return "UNDEFINED"

    # comparisons never match
    def _cmp(self, other):
        return False

    __eq__ = __ne__ = __lt__ = __le__ = __gt__ = __ge__ = _cmp
    __contains__ = _cmp

    def __hash__(self):
        return 0


UNDEFINED = Undefined()

_ALLOWED_NODES = (
    ast.Expression, ast.BoolOp, ast.And, ast.Or, ast.UnaryOp, ast.Not,
    ast.USub, ast.UAdd, ast.BinOp, ast.Add, ast.Sub, ast.Mult, ast.Div,
    ast.FloorDiv, ast.Mod, ast.Compare, ast.Eq, ast.NotEq, ast.Lt, ast.LtE,
    ast.Gt, ast.GtE, ast.In, ast.NotIn, ast.Name, ast.Load, ast.Constant,
    ast.Tuple, ast.List, ast.Attribute, ast.IfExp, ast.Call,
)

_ALLOWED_FUNCS = {
    "min": min, "max": max, "abs": abs, "int": int, "float": float,
    "len": len, "str": str, "bool": bool,
    "regexp": lambda pat, s: __import__("re").search(str(pat), str(s))
    is not None,
}


class ClassAdExpr:
    """Compiled, reusable matchmaking expression."""

    def __init__(self, src: str | None):
        self.src = (src or "").strip()
        self.refs: frozenset[str] = frozenset()  # ad attrs the expr reads
        if not self.src or self.src.lower() == "true":
            self._tree = None  # vacuously true
            return
        tree = ast.parse(self.src, mode="eval")
        for node in ast.walk(tree):
            if not isinstance(node, _ALLOWED_NODES):
                raise ValueError(
                    f"disallowed syntax {type(node).__name__!r} in "
                    f"ClassAd expression: {self.src!r}"
                )
            if isinstance(node, ast.Call):
                if (not isinstance(node.func, ast.Name)
                        or node.func.id not in _ALLOWED_FUNCS):
                    raise ValueError(
                        f"disallowed call in ClassAd expression: {self.src!r}"
                    )
            if isinstance(node, ast.Attribute):
                # attribute access is ONLY the MY.x / TARGET.x scoping —
                # anything else (e.g. ().__class__) is an escape hatch
                if (not isinstance(node.value, ast.Name)
                        or node.value.id.lower() not in ("my", "target")
                        or node.attr.startswith("__")):
                    raise ValueError(
                        f"disallowed attribute access in ClassAd "
                        f"expression: {self.src!r}"
                    )
        refs = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Name):
                n = node.id.lower()
                if n not in ("my", "target", "true", "false",
                             "undefined") and n not in _ALLOWED_FUNCS:
                    refs.add(n)
            elif isinstance(node, ast.Attribute):
                refs.add(node.attr.lower())
        self.refs = frozenset(refs)
        self._tree = compile(tree, "<classad>", "eval")

    def evaluate(self, my: Mapping[str, Any],
                 target: Mapping[str, Any] | None = None) -> bool:
        if self._tree is None:
            return True
        target = target or {}
        my_l = _lower(my)
        tg_l = _lower(target)

        class _Scope(dict):
            def __missing__(self, key):
                kl = key.lower()
                if kl == "my":
                    return _AdProxy(my_l)
                if kl == "target":
                    return _AdProxy(tg_l)
                if kl in _ALLOWED_FUNCS:
                    return _ALLOWED_FUNCS[kl]
                if kl in ("true", "false"):
                    return kl == "true"
                if kl == "undefined":
                    return UNDEFINED
                if kl in my_l:
                    return my_l[kl]
                if kl in tg_l:
                    return tg_l[kl]
                return UNDEFINED

        try:
            out = eval(self._tree, {"__builtins__": {}}, _Scope())
        except (TypeError, ZeroDivisionError, AttributeError):
            return False
        if out is UNDEFINED:
            return False
        return bool(out)

    def __repr__(self):
        return f"ClassAdExpr({self.src!r})"


class _AdProxy:
    def __init__(self, ad_lower: Mapping[str, Any]):
        self._ad = ad_lower

    def __getattr__(self, name: str):
        return self._ad.get(name.lower(), UNDEFINED)


def _lower(ad: Mapping[str, Any]) -> dict[str, Any]:
    return {str(k).lower(): v for k, v in ad.items()}


def symmetric_match(job_ad: Mapping[str, Any], offer_ad: Mapping[str, Any],
                    job_requirements: ClassAdExpr | None = None,
                    start_expr: ClassAdExpr | None = None) -> bool:
    """HTCondor negotiation: job.Requirements(machine) AND machine.START(job).

    Also honours resource-quantity sanity (request_* <= offered *) so a job
    can never be matched onto a smaller worker even if expressions pass."""
    for res in ("cpus", "gpus", "memory", "disk", "chips", "hbm_gb"):
        want = job_ad.get(f"request_{res}", 0) or 0
        have = offer_ad.get(res, 0) or 0
        if want > have:
            return False
    if job_requirements is not None and not job_requirements.evaluate(
            job_ad, offer_ad):
        return False
    if start_expr is not None and not start_expr.evaluate(offer_ad, job_ad):
        return False
    return True
