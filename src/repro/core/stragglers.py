"""Straggler mitigation (beyond-paper, required at 1000+-node scale).

HTCondor's own answer to stragglers is job-level: if a job runs far past
its expected runtime on some node, kick it back to IDLE and let
matchmaking place it elsewhere (the slow node's worker is retired so it
stops attracting work).  This is the control-plane analogue of
speculative re-execution; combined with self-checkpointing jobs the lost
work is bounded by one checkpoint interval.

Detection: a running job whose wall-clock age exceeds
``factor × runtime_s`` is a straggler (progress-rate proxy; the real
deployment reads HTCondor's job heartbeat attribute the same way).
"""
from __future__ import annotations

import dataclasses

from repro.core.jobqueue import JobQueue, JobState
from repro.core.worker import Collector, kill_worker


@dataclasses.dataclass
class StragglerPolicy:
    factor: float = 2.0            # age > factor × expected runtime
    retire_worker: bool = True     # stop the slow worker claiming more
    min_runtime_s: float = 60.0    # ignore very short jobs

    rescheduled: int = 0
    retired_workers: int = 0

    def tick(self, queue: JobQueue, collector: Collector, cluster,
             now: float) -> int:
        n = 0
        for job in queue.jobs(JobState.RUNNING):
            if job.runtime_s < self.min_runtime_s:
                continue
            age = now - job.attempt_started_at
            if age <= self.factor * job.runtime_s:
                continue
            worker_name = job.claimed_by
            queue.release(job.jid, now, preempted=True)
            n += 1
            self.rescheduled += 1
            if self.retire_worker and worker_name:
                w = collector.workers.get(worker_name)
                if w is not None:
                    kill_worker(collector, queue, worker_name, now)
                    if w.pod_name and cluster is not None:
                        cluster.delete_pod(w.pod_name, now, "straggler")
                    self.retired_workers += 1
        return n
