"""Time-series + summary metrics for the provisioning experiments.

Records per-tick gauges (queue depth, pods pending/running, workers busy,
nodes live) and derives the paper's headline quantities:

  * demand-tracking lag (Fig 3): time from a job arriving idle to a worker
    slot being available for its group
  * harvested compute (Fig 2): busy resource-seconds on provisioned pods
  * utilization / waste: busy / alive on workers, empty-node fraction
  * scale-down latency (C2): worker idle time before self-termination
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class Recorder:
    """Gauge time-series store.

    Sampling cadence is the CALLER's business (the event engine installs
    its own periodic metrics callback; see simulation.py); as a guard for
    tick-loop callers that record every step, an optional
    `sample_interval_s` rate-limits aggregate samples so recording cost is
    decoupled from tick cadence at 100k-job scale."""

    series: dict[str, list[tuple[float, float]]] = dataclasses.field(
        default_factory=dict)
    sample_interval_s: float | None = None
    _last_sample: float = dataclasses.field(default=-1e18, repr=False)

    def _sample_ok(self, now: float) -> bool:
        """Shared rate-limit gate: aggregate and per-backend series stay
        on the SAME sample grid (a timestamp either records everywhere or
        nowhere)."""
        if self.sample_interval_s is None:
            return True
        if now == self._last_sample:      # same instant as an accepted one
            return True
        if now - self._last_sample >= self.sample_interval_s - 1e-9:
            self._last_sample = now
            return True
        return False

    def record(self, now: float, **gauges: float):
        if not self._sample_ok(now):
            return
        for key, val in gauges.items():
            self.series.setdefault(key, []).append((now, float(val)))

    # -- per-backend series (federation) -----------------------------------
    def record_backend(self, now: float, backend: str, **gauges: float):
        """Record gauges attributed to one scaling backend; stored under
        ``key@backend`` so aggregate keys stay untouched.  Honours the
        same sampling grid as `record`."""
        if not self._sample_ok(now):
            return
        for key, val in gauges.items():
            self.series.setdefault(f"{key}@{backend}", []).append(
                (now, float(val)))

    def backend_values(self, key: str, backend: str) -> list[float]:
        return self.values(f"{key}@{backend}")

    def backends_recorded(self) -> list[str]:
        return sorted({k.split("@", 1)[1] for k in self.series
                       if "@" in k and "@schedd:" not in k
                       and "@user:" not in k})

    # -- per-schedd / per-user series (flocking fair-share) ------------------
    def record_schedd(self, now: float, schedd: str, **gauges: float):
        """Gauges attributed to one submit host, stored under
        ``key@schedd:<name>`` (same sampling grid as `record`)."""
        if not self._sample_ok(now):
            return
        for key, val in gauges.items():
            self.series.setdefault(f"{key}@schedd:{schedd}", []).append(
                (now, float(val)))

    def record_user(self, now: float, user: str, **gauges: float):
        """Gauges attributed to one submitter (pool-global, like the
        accountant's ledger), stored under ``key@user:<name>``."""
        if not self._sample_ok(now):
            return
        for key, val in gauges.items():
            self.series.setdefault(f"{key}@user:{user}", []).append(
                (now, float(val)))

    def schedd_values(self, key: str, schedd: str) -> list[float]:
        return self.values(f"{key}@schedd:{schedd}")

    def user_values(self, key: str, user: str) -> list[float]:
        return self.values(f"{key}@user:{user}")

    def schedds_recorded(self) -> list[str]:
        return sorted({k.split("@schedd:", 1)[1] for k in self.series
                       if "@schedd:" in k})

    def users_recorded(self) -> list[str]:
        return sorted({k.split("@user:", 1)[1] for k in self.series
                       if "@user:" in k})

    def values(self, key: str) -> list[float]:
        return [v for _, v in self.series.get(key, [])]

    def times(self, key: str) -> list[float]:
        return [t for t, _ in self.series.get(key, [])]

    def last(self, key: str, default: float = 0.0) -> float:
        s = self.series.get(key)
        return s[-1][1] if s else default

    def integral(self, key: str) -> float:
        """Trapezoid integral of a gauge over time."""
        s = self.series.get(key, [])
        out = 0.0
        for (t0, v0), (t1, v1) in zip(s, s[1:]):
            out += 0.5 * (v0 + v1) * (t1 - t0)
        return out

    def mean(self, key: str) -> float:
        v = self.values(key)
        return sum(v) / len(v) if v else 0.0

    def max(self, key: str) -> float:
        v = self.values(key)
        return max(v) if v else 0.0

    # -- derived summaries ------------------------------------------------------
    def tracking_lag(self, demand_key: str, supply_key: str,
                     threshold: float = 0.95) -> float:
        """Mean time for supply to reach `threshold`×(new demand level) after
        each upward demand step."""
        dem = self.series.get(demand_key, [])
        sup = self.series.get(supply_key, [])
        if not dem or not sup:
            return 0.0
        lags = []
        prev = dem[0][1]
        for (t, v) in dem[1:]:
            if v > prev:  # upward step
                target = threshold * v
                t_hit = None
                for (ts, vs) in sup:
                    if ts >= t and vs >= target:
                        t_hit = ts
                        break
                if t_hit is not None:
                    lags.append(t_hit - t)
            prev = v
        return sum(lags) / len(lags) if lags else 0.0


def percentile(vals: list[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]) of an unsorted list."""
    if not vals:
        return 0.0
    s = sorted(vals)
    return s[min(len(s) - 1, int(q * (len(s) - 1) + 0.5))]


def summarize_jobs(completed: list, now: float) -> dict[str, Any]:
    if not completed:
        return {"n": 0}
    waits = [j.started_at - j.submitted_at for j in completed
             if j.started_at >= 0]
    wasted = sum(j.wasted_s for j in completed)
    done_work = sum(j.runtime_s for j in completed)
    return {
        "n": len(completed),
        "mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
        "p95_wait_s": sorted(waits)[int(0.95 * (len(waits) - 1))]
        if waits else 0.0,
        "preemptions": sum(j.preempt_count for j in completed),
        "wasted_s": wasted,
        "goodput": done_work / (done_work + wasted)
        if done_work + wasted > 0 else 1.0,
    }


class CompletedStats:
    """Streaming completed-job aggregator for trace replay at scale.

    Installed as a `JobQueue.add_complete_hook` observer (usually with
    ``queue.keep_completed = False``): it folds each completion into
    scalar accumulators plus a wait-time sample — plain floats, so a
    100k-job campaign costs one small list, not 100k retained `Job`
    objects.  `summary()` yields the wait-time percentiles and
    core/GPU-hour totals the policy-comparison harness (workload/
    compare.py) builds its Fig 2/3-style tables and conservation checks
    from."""

    WAIT_QUANTILES = (0.5, 0.9, 0.95, 0.99)

    def __init__(self):
        self.n = 0
        self.runtime_s = 0.0
        self.core_seconds = 0.0       # request_cpus × runtime
        self.gpu_seconds = 0.0        # request_gpus × runtime
        self.wasted_s = 0.0
        self.preemptions = 0
        self.waits: list[float] = []
        self.last_completed_at = 0.0

    def observe(self, job):
        self.n += 1
        self.runtime_s += job.runtime_s
        cpus = float(job.ad.get("request_cpus", 1) or 1)
        gpus = float(job.ad.get("request_gpus", 0) or 0)
        self.core_seconds += cpus * job.runtime_s
        self.gpu_seconds += gpus * job.runtime_s
        self.wasted_s += job.wasted_s
        self.preemptions += job.preempt_count
        if job.started_at >= 0:
            self.waits.append(job.started_at - job.submitted_at)
        self.last_completed_at = max(self.last_completed_at,
                                     job.completed_at)

    def merge(self, other: "CompletedStats") -> "CompletedStats":
        """Fold another aggregator in (cross-schedd totals under
        flocking: one CompletedStats per replayer, merged for the
        pool-level conservation checks).  Returns self."""
        self.n += other.n
        self.runtime_s += other.runtime_s
        self.core_seconds += other.core_seconds
        self.gpu_seconds += other.gpu_seconds
        self.wasted_s += other.wasted_s
        self.preemptions += other.preemptions
        self.waits.extend(other.waits)
        self.last_completed_at = max(self.last_completed_at,
                                     other.last_completed_at)
        return self

    # -- persistence (pool-service snapshot/resume) --------------------------
    def state_dict(self) -> dict:
        return {
            "n": self.n,
            "runtime_s": self.runtime_s,
            "core_seconds": self.core_seconds,
            "gpu_seconds": self.gpu_seconds,
            "wasted_s": self.wasted_s,
            "preemptions": self.preemptions,
            "waits": list(self.waits),
            "last_completed_at": self.last_completed_at,
        }

    def load_state(self, state: dict) -> None:
        self.n = int(state.get("n", 0))
        self.runtime_s = float(state.get("runtime_s", 0.0))
        self.core_seconds = float(state.get("core_seconds", 0.0))
        self.gpu_seconds = float(state.get("gpu_seconds", 0.0))
        self.wasted_s = float(state.get("wasted_s", 0.0))
        self.preemptions = int(state.get("preemptions", 0))
        self.waits = [float(w) for w in state.get("waits", [])]
        self.last_completed_at = float(state.get("last_completed_at", 0.0))

    def summary(self) -> dict[str, Any]:
        out: dict[str, Any] = {
            "n": self.n,
            "mean_wait_s": (sum(self.waits) / len(self.waits)
                            if self.waits else 0.0),
            "preemptions": self.preemptions,
            "wasted_s": self.wasted_s,
            "goodput": (self.runtime_s / (self.runtime_s + self.wasted_s)
                        if self.runtime_s + self.wasted_s > 0 else 1.0),
            "core_hours": self.core_seconds / 3600.0,
            "gpu_hours": self.gpu_seconds / 3600.0,
        }
        for q in self.WAIT_QUANTILES:
            out[f"p{int(q * 100)}_wait_s"] = percentile(self.waits, q)
        return out


def timeline(recorder: Recorder, keys: tuple[str, ...],
             max_points: int = 200) -> dict[str, dict[str, list[float]]]:
    """Extract gauge series (queue depth, provisioned cores, cost rate …)
    as JSON-ready {key: {"t": [...], "v": [...]}} tables, stride-
    downsampled to at most `max_points` points (last sample always
    kept) — the Fig 2/3-style curves the comparison harness emits."""
    out: dict[str, dict[str, list[float]]] = {}
    for key in keys:
        s = recorder.series.get(key, [])
        if not s:
            out[key] = {"t": [], "v": []}
            continue
        stride = max(1, -(-len(s) // max_points))
        pts = s[::stride]
        if pts[-1] != s[-1]:
            pts.append(s[-1])
        out[key] = {"t": [round(t, 3) for t, _ in pts],
                    "v": [v for _, v in pts]}
    return out


def summarize_backends(backends: list) -> dict[str, dict[str, Any]]:
    """Per-backend attribution: pods submitted/reclaimed, integrated cost,
    deprovisioning waste (Fig 3; definitionally 0 for a static pool), and
    harvested GPU-seconds (Fig 2 split per provider)."""
    out: dict[str, dict[str, Any]] = {}
    for b in backends:
        cap_s, busy_s = b.cluster.resource_seconds("gpu")
        out[b.name] = {
            "pods_submitted": b.stats.pods_submitted,
            "pods_reclaimed": b.stats.pods_reclaimed,
            "cost": b.stats.cost_total,
            "waste_fraction": (b.autoscaler.waste_fraction()
                               if b.autoscaler is not None else 0.0),
            "gpu_utilization": b.cluster.utilization("gpu"),
            "gpu_seconds_provisioned": cap_s,
            "gpu_seconds_busy": busy_s,
            "live_nodes": len(b.cluster.nodes),
            "spot": b.spot,
        }
    return out


def summarize_workers(workers: list) -> dict[str, Any]:
    alive = sum(w.alive_s for w in workers)
    busy = sum(w.busy_s for w in workers)
    return {
        "n_workers": len(workers),
        "alive_s": alive,
        "busy_s": busy,
        "utilization": busy / alive if alive > 0 else 0.0,
    }
