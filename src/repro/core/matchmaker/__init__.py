"""Swappable matchmaking backends behind one protocol (see base.py).

    from repro.core.matchmaker import make_matchmaker
    mm = make_matchmaker("jax")          # or "numpy", "scan", "pallas"
    plan = mm.match(problem)

Selection flows from `Simulation(matchmaker=...)` / the `[provision]
matchmaker=` INI key through `Collector(matchmaker=...)`; every backend
is claim-for-claim identical on quantity-blind policies (the
differential suite pins it).
"""
from repro.core.matchmaker.base import (
    EXHAUSTIBLE_IDX, FIT_EPS, RESOURCE_KEYS, MatchPlan, MatchProblem,
    Matchmaker, cohort_fits, make_matchmaker, matchmaker_names,
    register_matchmaker,
)
from repro.core.matchmaker.numpy_backend import NumpyMatchmaker
from repro.core.matchmaker.scan_backend import ScanMatchmaker
from repro.core.matchmaker.jax_backend import HAVE_JAX, JaxMatchmaker
from repro.core.matchmaker.pallas_backend import HAVE_PALLAS, PallasMatchmaker

register_matchmaker("numpy", NumpyMatchmaker)
register_matchmaker("scan", ScanMatchmaker)
register_matchmaker("jax", JaxMatchmaker)
register_matchmaker("pallas", PallasMatchmaker)

__all__ = [
    "EXHAUSTIBLE_IDX", "FIT_EPS", "HAVE_JAX", "HAVE_PALLAS",
    "RESOURCE_KEYS", "JaxMatchmaker", "MatchPlan", "MatchProblem",
    "Matchmaker", "NumpyMatchmaker", "PallasMatchmaker", "ScanMatchmaker",
    "cohort_fits", "make_matchmaker", "matchmaker_names",
    "register_matchmaker",
]
