"""Pallas matchmaker: the single-cycle water-fill as a fused TPU kernel.

`make_matchmaker("pallas")` — identical host-side plumbing to the jax
backend (same `_prep` padding/ordering, same scatter-back), but the
chunked claim loop runs as ONE Pallas program with the free matrix
resident in VMEM across every chunk (src/repro/kernels/waterfill/).
Off-TPU the kernel runs in interpret mode, so plans stay bit-identical
to the jax and numpy backends in float64 and CI can pin the parity
without hardware.

Multi-cycle fusion (`match_cycles`) is inherited from the jax backend:
the K-cycle batch is an outer lax.scan around the identical chunk
arithmetic, so a pallas-selected pool still gets device-resident fused
batches — the kernel covers the steady-state per-cycle path, which
dominates the paper's demand >> supply negotiation profile.
"""
from __future__ import annotations

import numpy as np

from repro.core.matchmaker.jax_backend import HAVE_JAX, JaxMatchmaker

try:                                    # gate: pallas rides on jax
    from repro.kernels.waterfill import waterfill
    HAVE_PALLAS = HAVE_JAX
except ImportError:                     # pragma: no cover
    waterfill = None
    HAVE_PALLAS = False


class PallasMatchmaker(JaxMatchmaker):
    """The Pallas water-fill backend (`make_matchmaker("pallas")`)."""

    name = "pallas"

    def __init__(self, *, dtype: str = "float64", chunk: int = 64,
                 unroll: int = 4, interpret: bool | None = None):
        if not HAVE_PALLAS:
            raise ImportError(
                "matchmaker='pallas' needs jax with pallas support; "
                "use matchmaker='jax' or 'numpy'")
        super().__init__(dtype=dtype, chunk=chunk, unroll=unroll)
        self.interpret = interpret

    def _run(self, dt, freeT, left, req_o, safe, big, d_o, crow_o,
             chunk_min, nch, chunk, R, Wp):
        return waterfill(
            freeT, float(left),
            np.ascontiguousarray(req_o.reshape(nch, chunk, R)),
            np.ascontiguousarray(safe.reshape(nch, chunk, R)),
            np.ascontiguousarray(big.reshape(nch, chunk, R)),
            d_o.reshape(nch, chunk),
            np.ascontiguousarray(crow_o.reshape(nch, chunk, Wp)),
            chunk_min,
            dtype=dt, interpret=self.interpret,
        )
