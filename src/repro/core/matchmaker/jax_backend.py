"""Jitted JAX matchmaker: the whole negotiation water-fill as XLA ops.

The per-cohort claiming loop is a `lax.scan` over cohort positions in
processing order: the carry is the transposed free-resource matrix
(R, W) plus the remaining claim budget, and each step converts one
cohort's request row into per-worker takes with the exact legacy
arithmetic — ``fits = floor(free/want + FIT_EPS)`` (true division, so
float64 runs are bitwise-identical to the NumPy reference), a
compat-mask multiply, and the greedy prefix allocation
``take = clip(d - exclusive_cumsum(fits), 0, fits)`` which reproduces
the seed's first-match worker walk in closed form.

Scale tricks (the ROADMAP's array-compiled matchmaking item):

  * **chunked scan + drain guard** — cohorts are processed in chunks of
    ``chunk`` positions; a chunk is skipped (``lax.cond``) once every
    worker falls below the chunk's componentwise-minimum request vector
    in some resource — provably nothing in it can fit, so skipping is
    claim-exact.  In the paper's demand >> supply regime (a 100k-job
    backlog against a ~600-pod Kubernetes pool) the pool drains early
    and most chunks cost one (R, W) comparison.
  * **padded/bucketed tensors** — cohort count pads to the chunk size
    and workers pad to lanes of 128, so XLA re-traces only when the
    bucket changes, not every cycle.
  * **donated free buffer** — the (R, W) carry is donated to the jit,
    avoiding a defensive copy per cycle.

dtype: ``float64`` (default) matches the NumPy reference bit-for-bit
via `jax.experimental.enable_x64`.  ``float32`` is faster but only
exact while resource quantities stay integer-valued below 2**24 — fine
for whole-core/GPU pools, not for fractional-CPU requests.
"""
from __future__ import annotations

import math
from functools import lru_cache, partial

import numpy as np

from repro.core.matchmaker.base import (
    FIT_EPS, RESOURCE_KEYS, CycleDelta, MatchPlan, MatchProblem,
)

try:                                    # gate: jax is an optional dep
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
except ImportError:                     # pragma: no cover
    jax = None
    HAVE_JAX = False

_ZERO_WANT_BIG = 1e15     # ratio offset for zero-request resource lanes
_W_LANES = 128            # worker-axis padding bucket
_PREVIEW_LANES = 512      # preview lane floor (one trace per replay)


def _make_steps(unroll: int):
    """The shared inner/chunk scan bodies — the single-cycle jit and the
    fused multi-cycle jit run EXACTLY these ops, so their plans agree
    bit-for-bit."""

    def inner_step(carry, x):
        freeT, left = carry
        want, safe, big, d, crow = x
        d = jnp.minimum(d, left)
        ratio = freeT / safe[:, None] + big[:, None]
        fits = jnp.maximum(jnp.floor(jnp.min(ratio, axis=0) + FIT_EPS), 0.0)
        # capping fits at d leaves the greedy prefix allocation exact
        # (prefix sums below d are uncapped; above d both saturate) and
        # bounds the zero-request sentinel lanes; crow is uint8 (the
        # compat mask ships to the device at 1 byte/cell — at C=4096,
        # W=512 the f64 version alone was 16MB of PCIe per cycle)
        fits = jnp.minimum(fits, d) * crow
        cum = jnp.cumsum(fits)
        take = jnp.clip(d - (cum - fits), 0.0, fits)
        freeT = freeT - want[:, None] * take[None, :]
        left = left - jnp.sum(take)
        # emit int32 rows: takes are whole job counts, and stacking the
        # (C, W) output as f64 would cost 134MB of write traffic at the
        # 1M tier before a round+cast pass doubled it
        return (freeT, left), jnp.round(take).astype(jnp.int32)

    def chunk_step(carry, x):
        freeT, left = carry
        want_c, safe_c, big_c, d_c, crow_c, minreq = x
        # drain guard: `minreq` is the componentwise minimum request
        # vector over the chunk's still-demanding cohorts (inf when the
        # chunk has none).  A worker below it in ANY resource fits NO
        # cohort of the chunk — minreq[r] <= want[r] for every cohort —
        # so when every worker fails somewhere the whole chunk is
        # provably empty and the inner scan is skipped, claim-exactly.
        # On the paper's demand >> supply shape the pool drains a few
        # chunks in (memory/GPUs exhaust even while CPUs linger, which a
        # CPU-only guard would miss) and later chunks cost one (R, W)
        # comparison.  The (1 - 2eps) slack keeps the guard conservative
        # against the fits eps.
        ok = freeT >= (minreq * (1.0 - 2 * FIT_EPS))[:, None]
        alive = jnp.any(jnp.all(ok, axis=0)) & (left > 0)

        def run(c):
            c2, takes = lax.scan(inner_step, c,
                                 (want_c, safe_c, big_c, d_c, crow_c),
                                 unroll=unroll)
            return c2, (takes, True)

        def skip(c):
            return c, (jnp.zeros(crow_c.shape, jnp.int32), False)

        return lax.cond(alive, run, skip, (freeT, left))

    return inner_step, chunk_step


@lru_cache(maxsize=None)
def _build_scan(chunk: int, unroll: int):
    """The jitted chunked water-fill (built once per config, shape-
    polymorphic thereafter — XLA caches one executable per bucket).
    lru_cache shares the jitted callable — and therefore its per-bucket
    executable cache — across backend instances, so a process that
    builds many pools (test suites, benchmark sweeps) traces each
    (config, bucket) pair once."""
    _inner, chunk_step = _make_steps(unroll)

    def fn(freeT, left, want_s, safe_s, big_s, d_s, crow_s, chunk_min):
        (freeT, left), (takes, ran) = lax.scan(
            chunk_step, (freeT, left),
            (want_s, safe_s, big_s, d_s, crow_s, chunk_min))
        # `ran` flags which chunks executed — the host scatters only
        # those rows, so a drained 1M-cohort backlog does not pay for
        # converting a matrix of zeros
        return takes, freeT, ran

    return jax.jit(fn, donate_argnums=(0,))


@lru_cache(maxsize=None)
def _build_preview_scan(chunk: int, unroll: int):
    """The batched-preview jit: a `vmap` over N independent candidate
    (free, demand) pairs of the SAME chunked water-fill inner scan the
    match path runs, emitting only per-cohort absorbed counts.

    Differences from `_build_scan`, neither of which changes claims:

      * no drain guard — the guard's skip branch emits the exact zeros
        the inner scan would compute, so omitting it is claim-exact; a
        preview is one dispatch per reconcile (not per cycle), so the
        guard's saving does not pay for its per-chunk `lax.cond`
        under `vmap` (which lowers to running both branches anyway);
      * no (C, W) takes output — only the (nch, chunk) per-cohort sums
        ship back, so an N=8 candidate batch returns 8*Cp ints instead
        of 8 full matrices.

    All N candidates share the device-resident cohort constants
    (requests/compat, cached across calls by `JaxMatchmaker`'s preview
    session); only the stacked free matrices and demand vectors ship
    down per call."""
    inner_step, _chunk_step = _make_steps(unroll)

    def one(freeT, d_s, want_s, safe_s, big_s, crow_s):
        left0 = jnp.asarray(jnp.inf, dtype=freeT.dtype)

        def chunk_step(carry, x):
            want_c, safe_c, big_c, d_c, crow_c = x
            c2, takes = lax.scan(inner_step, carry,
                                 (want_c, safe_c, big_c, d_c, crow_c),
                                 unroll=unroll)
            # takes: (chunk, Wp) int32 rows from the SHARED inner_step —
            # summing them per cohort is exactly plan.per_cohort()
            return c2, jnp.sum(takes, axis=1)

        (_f, _l), absorbed = lax.scan(
            chunk_step, (freeT, left0),
            (want_s, safe_s, big_s, d_s, crow_s))
        return absorbed                       # (nch, chunk) int32

    return jax.jit(jax.vmap(one, in_axes=(0, 0, None, None, None, None)))


@lru_cache(maxsize=None)
def _build_cycles_scan(chunk: int, unroll: int):
    """The fused multi-cycle jit: an outer `lax.scan` over K negotiation
    cycles wrapping the same chunked water-fill, so the free matrix and
    the carried demand stay DEVICE-RESIDENT across cycles — one dispatch
    and one host round-trip per K-cycle batch instead of per cycle.

    Per cycle the carry applies the staged deltas on device (``demand +=
    arrivals``, ``freeT += free_add``), re-derives the drain guard's
    per-chunk componentwise-minimum request from the LIVE demand (the
    single-cycle path computes it on the host; here demand changes
    across cycles, so the guard must be recomputed per cycle with the
    identical arithmetic to stay claim-exact), resets the claim budget,
    and runs the inner chunk scan unchanged — the emitted takes are
    bit-identical to K sequential single-cycle matches."""
    _inner, chunk_step = _make_steps(unroll)

    def cycle_step(carry, x):
        freeT, d_s = carry              # d_s: (nch, chunk) live demand
        arr, fadd, left, want_s, safe_s, big_s, crow_s = x
        d_s = d_s + arr
        freeT = freeT + fadd
        # drain-guard lower bound over the cycle's still-demanding
        # cohorts — same where/min arithmetic as the host precompute
        minreq = jnp.min(
            jnp.where((d_s > 0)[..., None], want_s, jnp.inf), axis=1)
        (freeT, _left), (takes, ran) = lax.scan(
            chunk_step, (freeT, left),
            (want_s, safe_s, big_s, d_s, crow_s, minreq))
        d_s = d_s - jnp.sum(takes, axis=2).astype(d_s.dtype)
        return (freeT, d_s), (takes, ran, freeT)

    def fn(freeT, d_s, arrivals, free_addT, budgets,
           want_s, safe_s, big_s, crow_s):
        # deltas scan over cycles; the per-chunk tensors are loop
        # constants (closed over via broadcast in xs would copy K-fold)
        def step(carry, x):
            arr, fadd, left = x
            return cycle_step(carry, (arr, fadd, left,
                                      want_s, safe_s, big_s, crow_s))

        (freeT, d_s), ys = lax.scan(
            step, (freeT, d_s), (arrivals, free_addT, budgets))
        takes, ran, free_per = ys
        return takes, ran, free_per

    # no buffer donation here: the per-cycle freeT snapshots are emitted
    # as scan ys, so the input buffers stay live for the whole dispatch
    return jax.jit(fn)


class JaxMatchmaker:
    """The XLA backend (`make_matchmaker("jax")`)."""

    name = "jax"

    def __init__(self, *, dtype: str = "float64", chunk: int = 64,
                 unroll: int = 4):
        if not HAVE_JAX:
            raise ImportError(
                "matchmaker='jax' needs the jax package; install jax or "
                "use matchmaker='numpy'")
        if dtype not in ("float64", "float32"):
            raise ValueError(f"dtype must be float64|float32, got {dtype!r}")
        self.dtype = dtype
        self.chunk = int(chunk)
        self.unroll = int(unroll)
        self._fn = _build_scan(self.chunk, self.unroll)
        self._fn_cycles = _build_cycles_scan(self.chunk, self.unroll)
        # unroll=1 for preview: the preview path is compile-bound, not
        # dispatch-bound (a handful of memo-missing calls per replay,
        # each on a fresh lane bucket as the pool grows), and a rolled
        # scan body halves the XLA trace cost for the same steady-state
        # latency (245ms vs 509ms trace, ~0.86ms/call either way).
        self._fn_preview = _build_preview_scan(self.chunk, 1)
        # one-entry preview session: the cohort-side constants of the
        # last previewed problem (requests/compat, permuted + padded +
        # shipped to the device).  The collector's preview problems
        # repeat their structure across reconciles while only free
        # capacity and demand move, so a session hit ships (R, Wp)
        # floats per candidate instead of rebuilding ~4 (Cp, ...)
        # tensors — measured 0.44ms vs 8.2ms per preview on the 2k
        # diurnal replay.  Validated on (caller token, order, shape);
        # demand is NEVER cached (it changes within a session).
        self._preview_session: dict | None = None
        # compile-vs-execute telemetry: XLA retraces per padded-shape
        # bucket, so the first call on a fresh bucket pays the trace +
        # compile and every repeat hits the executable cache.  The
        # profiler reads `last_call` after each match.
        self._seen_buckets: set[tuple] = set()
        self.last_call: dict | None = None

    def _note_call(self, kind: str, bucket: tuple):
        compiled = bucket not in self._seen_buckets
        self._seen_buckets.add(bucket)
        self.last_call = {"kind": kind, "bucket": bucket,
                          "compiled": compiled}

    def warm_preview(self):
        """Pre-compile the canonical preview bucket: nch=1 cohort
        chunks, the `_PREVIEW_LANES` lane floor, one candidate.  The
        floor exists precisely so that every small-to-medium pool lands
        on this one bucket, which makes it pre-compilable — a long-lived
        pool (the Collector calls this at construction) pays the ~0.25s
        XLA trace at startup instead of inside the first reconcile's
        preview.  The executable lands in the process-shared builder
        cache, so repeat warms are free.  `_seen_buckets` is left
        untouched: compile telemetry still reports the first live call
        on the bucket as a fresh trace (which it was, just earlier)."""
        chunk, Wp = self.chunk, _PREVIEW_LANES
        R = len(RESOURCE_KEYS)
        dt = jnp.float64 if self.dtype == "float64" else jnp.float32

        def go():
            z = lambda *s: jnp.zeros(s, dtype=dt)
            self._fn_preview(
                z(1, R, Wp), z(1, 1, chunk), z(1, chunk, R),
                jnp.ones((1, chunk, R), dtype=dt), z(1, chunk, R),
                jnp.zeros((1, chunk, Wp), dtype=jnp.uint8),
            ).block_until_ready()

        if self.dtype == "float64":
            with enable_x64():
                go()
        else:
            go()

    def _prep(self, p: MatchProblem, active=None, *, lanes=None):
        """Order-permuted, padded host arrays (pad cohorts have demand 0
        and pad workers have zero free capacity — both take nothing).
        ``lanes`` widens the worker padding beyond the default 128-lane
        granularity — the preview path passes a power-of-two bucket so
        a pool growing through many widths retraces once or twice per
        run instead of once per 128-lane step."""
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        Cp = max(chunk, ((C + chunk - 1) // chunk) * chunk)
        Wp = max(_W_LANES, ((W + _W_LANES - 1) // _W_LANES) * _W_LANES)
        if lanes is not None:
            Wp = max(Wp, int(lanes))
        order = np.concatenate(
            [np.asarray(p.order, dtype=np.int64),
             np.arange(C, Cp, dtype=np.int64)])
        req_o = np.zeros((Cp, R))
        req_o[:C] = p.requests[order[:C]]
        d_o = np.zeros(Cp)
        d_o[:C] = p.demand[order[:C]]
        if active is not None:
            d_o[:C] *= active[order[:C]]
        crow_o = np.zeros((Cp, Wp), dtype=np.uint8)
        crow_o[:C, :W] = p.compat[order[:C]]
        freeT = np.zeros((R, Wp))
        freeT[:, :W] = p.free.T
        pos = req_o > 0
        safe = np.where(pos, req_o, 1.0)
        big = np.where(pos, 0.0, _ZERO_WANT_BIG)
        return order, req_o, d_o, crow_o, freeT, safe, big, Cp, Wp

    def match(self, p: MatchProblem, *, budget: int | None = None,
              active: np.ndarray | None = None) -> MatchPlan:
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        (order, req_o, d_o, crow_o, freeT, safe, big,
         Cp, Wp) = self._prep(p, active)
        # per-chunk componentwise-min request among demanding cohorts
        # (the drain guard's lower bound; inf where a chunk is empty)
        req_live = np.where((d_o > 0)[:, None], req_o, np.inf)
        chunk_min = req_live.reshape(-1, chunk, R).min(axis=1)
        nch = Cp // chunk
        left = math.inf if budget is None else float(budget)
        self._note_call("match", (nch, Wp, self.dtype))

        if self.dtype == "float64":
            with enable_x64():
                takes_j, freeT_j, ran_j = self._run(
                    jnp.float64, freeT, left, req_o, safe, big, d_o,
                    crow_o, chunk_min, nch, chunk, R, Wp)
                takes_j = np.asarray(takes_j)
                freeT_j = np.asarray(freeT_j)
                ran = np.asarray(ran_j)
        else:
            takes_j, freeT_j, ran_j = self._run(
                jnp.float32, freeT, left, req_o, safe, big, d_o,
                crow_o, chunk_min, nch, chunk, R, Wp)
            takes_j = np.asarray(takes_j)
            freeT_j = np.asarray(freeT_j, dtype=np.float64)
            ran = np.asarray(ran_j)

        # scatter back to original cohort rows — only chunks that ran
        # (skipped chunks are all-zero by construction)
        takes_flat = takes_j.reshape(Cp, Wp)
        takes = np.zeros((Cp, W), dtype=np.int64)
        live = np.nonzero(np.repeat(ran, chunk))[0]
        takes[order[live]] = takes_flat[live, :W]
        return MatchPlan(takes=takes[:C],
                         free_after=freeT_j[:, :W].T.copy())

    def preview_many(self, p: MatchProblem, frees: list,
                     demands: list | None = None, *,
                     session=None) -> list[np.ndarray]:
        """N independent candidate previews in ONE vmapped dispatch —
        see `base.sequential_preview_many` for the reference semantics
        this reproduces bit-for-bit (the inner scan body is shared with
        `match`).  ``session`` is an opaque hashable token naming the
        problem STRUCTURE (cohort keys + worker shapes): consecutive
        calls with the same token and cohort order reuse the device-
        resident request/compat constants and ship only the stacked
        free matrices and demand vectors."""
        N = len(frees)
        if N == 0:
            return []
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        dt = jnp.float64 if self.dtype == "float64" else jnp.float32
        order_key = np.asarray(p.order, dtype=np.int64).tobytes()

        def run():
            sess = self._preview_session
            if (session is not None and sess is not None
                    and sess["token"] == session
                    and sess["shape"] == (C, W, R)
                    and sess["order"] == order_key):
                order = sess["order_arr"]
                Cp, Wp = sess["pad"]
                consts = sess["consts"]
            else:
                # power-of-two lane bucket with a 512-lane floor: the
                # live pool's worker count drifts through many 128-lane
                # widths over a replay and each width is a fresh XLA
                # trace (~0.25s), while a 512-wide steady-state call is
                # <1ms — so one wide compile beats three narrow ones.
                # Pad workers have zero free and take nothing, so
                # results are unchanged.
                lanes = max(_PREVIEW_LANES, 1 << max(0, W - 1).bit_length())
                (order, req_o, _d_o, crow_o, _freeT, safe, big,
                 Cp, Wp) = self._prep(p, lanes=lanes)
                nch = Cp // chunk
                consts = (
                    jnp.asarray(req_o.reshape(nch, chunk, R), dtype=dt),
                    jnp.asarray(safe.reshape(nch, chunk, R), dtype=dt),
                    jnp.asarray(big.reshape(nch, chunk, R), dtype=dt),
                    jnp.asarray(crow_o.reshape(nch, chunk, Wp)),
                )
                self._preview_session = None if session is None else {
                    "token": session, "shape": (C, W, R),
                    "order": order_key, "order_arr": order,
                    "pad": (Cp, Wp), "consts": consts,
                }
            nch = Cp // chunk
            if demands is None:
                d_o = np.zeros(Cp)
                d_o[:C] = np.asarray(p.demand, dtype=np.float64)[order[:C]]
                dd = np.broadcast_to(
                    d_o.reshape(1, nch, chunk), (N, nch, chunk))
            else:
                dd = np.zeros((N, Cp))
                for i, dv in enumerate(demands):
                    dd[i, :C] = np.asarray(
                        dv, dtype=np.float64)[order[:C]]
                dd = dd.reshape(N, nch, chunk)
            fstack = np.zeros((N, R, Wp))
            for i, f in enumerate(frees):
                fstack[i, :, :W] = np.asarray(f, dtype=np.float64).T
            self._note_call("preview", (nch, Wp, N, self.dtype))
            absorbed = self._fn_preview(
                jnp.asarray(fstack, dtype=dt),
                jnp.asarray(dd, dtype=dt),
                *consts)
            return order, Cp, np.asarray(absorbed)

        if self.dtype == "float64":
            with enable_x64():
                order, Cp, absorbed = run()
        else:
            order, Cp, absorbed = run()

        flat = absorbed.reshape(N, Cp)
        out: list[np.ndarray] = []
        for i in range(N):
            res = np.zeros(C, dtype=np.int64)
            res[order[:C]] = flat[i, :C]
            out.append(res)
        return out

    def match_cycles(self, p: MatchProblem,
                     deltas: list[CycleDelta]) -> list[MatchPlan]:
        """K fused negotiation cycles in ONE device dispatch — see
        `base.sequential_match_cycles` for the reference semantics this
        must (and does, bit-for-bit) reproduce.  The free matrix and the
        live demand never leave the device between cycles; only the
        staged deltas ship down and only the K plans ship back."""
        if not deltas:
            return []
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        (order, req_o, d_o, crow_o, freeT, safe, big,
         Cp, Wp) = self._prep(p)
        nch = Cp // chunk
        K = len(deltas)
        self._note_call("match_cycles", (nch, Wp, K, self.dtype))

        arrivals = np.zeros((K, Cp))
        free_addT = np.zeros((K, R, Wp))
        budgets = np.empty(K)
        for k, d in enumerate(deltas):
            arrivals[k, :C] = np.asarray(d.arrivals, dtype=np.float64)[
                order[:C]]
            if d.free_add is not None:
                free_addT[k, :, :W] = np.asarray(d.free_add).T
            budgets[k] = math.inf if d.budget is None else float(d.budget)

        if self.dtype == "float64":
            with enable_x64():
                takes_j, ran_j, free_per = self._run_cycles(
                    jnp.float64, freeT, d_o, arrivals, free_addT,
                    budgets, req_o, safe, big, crow_o, nch, chunk, R, Wp)
                takes_j = np.asarray(takes_j)
                ran = np.asarray(ran_j)
                free_per = np.asarray(free_per)
        else:
            takes_j, ran_j, free_per = self._run_cycles(
                jnp.float32, freeT, d_o, arrivals, free_addT,
                budgets, req_o, safe, big, crow_o, nch, chunk, R, Wp)
            takes_j = np.asarray(takes_j)
            ran = np.asarray(ran_j)
            free_per = np.asarray(free_per, dtype=np.float64)

        plans: list[MatchPlan] = []
        for k in range(K):
            takes_flat = takes_j[k].reshape(Cp, Wp)
            takes = np.zeros((Cp, W), dtype=np.int64)
            live = np.nonzero(np.repeat(ran[k], chunk))[0]
            takes[order[live]] = takes_flat[live, :W]
            plans.append(MatchPlan(takes=takes[:C],
                                   free_after=free_per[k][:, :W].T.copy()))
        return plans

    def _run_cycles(self, dt, freeT, d_o, arrivals, free_addT, budgets,
                    req_o, safe, big, crow_o, nch, chunk, R, Wp):
        K = arrivals.shape[0]
        return self._fn_cycles(
            jnp.asarray(freeT, dtype=dt),
            jnp.asarray(d_o.reshape(nch, chunk), dtype=dt),
            jnp.asarray(arrivals.reshape(K, nch, chunk), dtype=dt),
            jnp.asarray(free_addT, dtype=dt),
            jnp.asarray(budgets, dtype=dt),
            jnp.asarray(req_o.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(safe.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(big.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(crow_o.reshape(nch, chunk, Wp)),   # uint8 mask
        )

    def _run(self, dt, freeT, left, req_o, safe, big, d_o, crow_o,
             chunk_min, nch, chunk, R, Wp):
        return self._fn(
            jnp.asarray(freeT, dtype=dt),
            jnp.asarray(left, dtype=dt),
            jnp.asarray(req_o.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(safe.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(big.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(d_o.reshape(nch, chunk), dtype=dt),
            jnp.asarray(crow_o.reshape(nch, chunk, Wp)),   # uint8 mask
            jnp.asarray(chunk_min, dtype=dt),
        )
