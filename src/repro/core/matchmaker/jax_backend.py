"""Jitted JAX matchmaker: the whole negotiation water-fill as XLA ops.

The per-cohort claiming loop is a `lax.scan` over cohort positions in
processing order: the carry is the transposed free-resource matrix
(R, W) plus the remaining claim budget, and each step converts one
cohort's request row into per-worker takes with the exact legacy
arithmetic — ``fits = floor(free/want + FIT_EPS)`` (true division, so
float64 runs are bitwise-identical to the NumPy reference), a
compat-mask multiply, and the greedy prefix allocation
``take = clip(d - exclusive_cumsum(fits), 0, fits)`` which reproduces
the seed's first-match worker walk in closed form.

Scale tricks (the ROADMAP's array-compiled matchmaking item):

  * **chunked scan + drain guard** — cohorts are processed in chunks of
    ``chunk`` positions; a chunk is skipped (``lax.cond``) once every
    worker falls below the chunk's componentwise-minimum request vector
    in some resource — provably nothing in it can fit, so skipping is
    claim-exact.  In the paper's demand >> supply regime (a 100k-job
    backlog against a ~600-pod Kubernetes pool) the pool drains early
    and most chunks cost one (R, W) comparison.
  * **padded/bucketed tensors** — cohort count pads to the chunk size
    and workers pad to lanes of 128, so XLA re-traces only when the
    bucket changes, not every cycle.
  * **donated free buffer** — the (R, W) carry is donated to the jit,
    avoiding a defensive copy per cycle.

dtype: ``float64`` (default) matches the NumPy reference bit-for-bit
via `jax.experimental.enable_x64`.  ``float32`` is faster but only
exact while resource quantities stay integer-valued below 2**24 — fine
for whole-core/GPU pools, not for fractional-CPU requests.
"""
from __future__ import annotations

import math
from functools import partial

import numpy as np

from repro.core.matchmaker.base import (
    FIT_EPS, CycleDelta, MatchPlan, MatchProblem,
)

try:                                    # gate: jax is an optional dep
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
except ImportError:                     # pragma: no cover
    jax = None
    HAVE_JAX = False

_ZERO_WANT_BIG = 1e15     # ratio offset for zero-request resource lanes
_W_LANES = 128            # worker-axis padding bucket


def _make_steps(unroll: int):
    """The shared inner/chunk scan bodies — the single-cycle jit and the
    fused multi-cycle jit run EXACTLY these ops, so their plans agree
    bit-for-bit."""

    def inner_step(carry, x):
        freeT, left = carry
        want, safe, big, d, crow = x
        d = jnp.minimum(d, left)
        ratio = freeT / safe[:, None] + big[:, None]
        fits = jnp.maximum(jnp.floor(jnp.min(ratio, axis=0) + FIT_EPS), 0.0)
        # capping fits at d leaves the greedy prefix allocation exact
        # (prefix sums below d are uncapped; above d both saturate) and
        # bounds the zero-request sentinel lanes; crow is uint8 (the
        # compat mask ships to the device at 1 byte/cell — at C=4096,
        # W=512 the f64 version alone was 16MB of PCIe per cycle)
        fits = jnp.minimum(fits, d) * crow
        cum = jnp.cumsum(fits)
        take = jnp.clip(d - (cum - fits), 0.0, fits)
        freeT = freeT - want[:, None] * take[None, :]
        left = left - jnp.sum(take)
        # emit int32 rows: takes are whole job counts, and stacking the
        # (C, W) output as f64 would cost 134MB of write traffic at the
        # 1M tier before a round+cast pass doubled it
        return (freeT, left), jnp.round(take).astype(jnp.int32)

    def chunk_step(carry, x):
        freeT, left = carry
        want_c, safe_c, big_c, d_c, crow_c, minreq = x
        # drain guard: `minreq` is the componentwise minimum request
        # vector over the chunk's still-demanding cohorts (inf when the
        # chunk has none).  A worker below it in ANY resource fits NO
        # cohort of the chunk — minreq[r] <= want[r] for every cohort —
        # so when every worker fails somewhere the whole chunk is
        # provably empty and the inner scan is skipped, claim-exactly.
        # On the paper's demand >> supply shape the pool drains a few
        # chunks in (memory/GPUs exhaust even while CPUs linger, which a
        # CPU-only guard would miss) and later chunks cost one (R, W)
        # comparison.  The (1 - 2eps) slack keeps the guard conservative
        # against the fits eps.
        ok = freeT >= (minreq * (1.0 - 2 * FIT_EPS))[:, None]
        alive = jnp.any(jnp.all(ok, axis=0)) & (left > 0)

        def run(c):
            c2, takes = lax.scan(inner_step, c,
                                 (want_c, safe_c, big_c, d_c, crow_c),
                                 unroll=unroll)
            return c2, (takes, True)

        def skip(c):
            return c, (jnp.zeros(crow_c.shape, jnp.int32), False)

        return lax.cond(alive, run, skip, (freeT, left))

    return inner_step, chunk_step


def _build_scan(chunk: int, unroll: int):
    """The jitted chunked water-fill (built once per config, shape-
    polymorphic thereafter — XLA caches one executable per bucket)."""
    _inner, chunk_step = _make_steps(unroll)

    def fn(freeT, left, want_s, safe_s, big_s, d_s, crow_s, chunk_min):
        (freeT, left), (takes, ran) = lax.scan(
            chunk_step, (freeT, left),
            (want_s, safe_s, big_s, d_s, crow_s, chunk_min))
        # `ran` flags which chunks executed — the host scatters only
        # those rows, so a drained 1M-cohort backlog does not pay for
        # converting a matrix of zeros
        return takes, freeT, ran

    return jax.jit(fn, donate_argnums=(0,))


def _build_cycles_scan(chunk: int, unroll: int):
    """The fused multi-cycle jit: an outer `lax.scan` over K negotiation
    cycles wrapping the same chunked water-fill, so the free matrix and
    the carried demand stay DEVICE-RESIDENT across cycles — one dispatch
    and one host round-trip per K-cycle batch instead of per cycle.

    Per cycle the carry applies the staged deltas on device (``demand +=
    arrivals``, ``freeT += free_add``), re-derives the drain guard's
    per-chunk componentwise-minimum request from the LIVE demand (the
    single-cycle path computes it on the host; here demand changes
    across cycles, so the guard must be recomputed per cycle with the
    identical arithmetic to stay claim-exact), resets the claim budget,
    and runs the inner chunk scan unchanged — the emitted takes are
    bit-identical to K sequential single-cycle matches."""
    _inner, chunk_step = _make_steps(unroll)

    def cycle_step(carry, x):
        freeT, d_s = carry              # d_s: (nch, chunk) live demand
        arr, fadd, left, want_s, safe_s, big_s, crow_s = x
        d_s = d_s + arr
        freeT = freeT + fadd
        # drain-guard lower bound over the cycle's still-demanding
        # cohorts — same where/min arithmetic as the host precompute
        minreq = jnp.min(
            jnp.where((d_s > 0)[..., None], want_s, jnp.inf), axis=1)
        (freeT, _left), (takes, ran) = lax.scan(
            chunk_step, (freeT, left),
            (want_s, safe_s, big_s, d_s, crow_s, minreq))
        d_s = d_s - jnp.sum(takes, axis=2).astype(d_s.dtype)
        return (freeT, d_s), (takes, ran, freeT)

    def fn(freeT, d_s, arrivals, free_addT, budgets,
           want_s, safe_s, big_s, crow_s):
        # deltas scan over cycles; the per-chunk tensors are loop
        # constants (closed over via broadcast in xs would copy K-fold)
        def step(carry, x):
            arr, fadd, left = x
            return cycle_step(carry, (arr, fadd, left,
                                      want_s, safe_s, big_s, crow_s))

        (freeT, d_s), ys = lax.scan(
            step, (freeT, d_s), (arrivals, free_addT, budgets))
        takes, ran, free_per = ys
        return takes, ran, free_per

    # no buffer donation here: the per-cycle freeT snapshots are emitted
    # as scan ys, so the input buffers stay live for the whole dispatch
    return jax.jit(fn)


class JaxMatchmaker:
    """The XLA backend (`make_matchmaker("jax")`)."""

    name = "jax"

    def __init__(self, *, dtype: str = "float64", chunk: int = 64,
                 unroll: int = 4):
        if not HAVE_JAX:
            raise ImportError(
                "matchmaker='jax' needs the jax package; install jax or "
                "use matchmaker='numpy'")
        if dtype not in ("float64", "float32"):
            raise ValueError(f"dtype must be float64|float32, got {dtype!r}")
        self.dtype = dtype
        self.chunk = int(chunk)
        self.unroll = int(unroll)
        self._fn = _build_scan(self.chunk, self.unroll)
        self._fn_cycles = _build_cycles_scan(self.chunk, self.unroll)
        # compile-vs-execute telemetry: XLA retraces per padded-shape
        # bucket, so the first call on a fresh bucket pays the trace +
        # compile and every repeat hits the executable cache.  The
        # profiler reads `last_call` after each match.
        self._seen_buckets: set[tuple] = set()
        self.last_call: dict | None = None

    def _note_call(self, kind: str, bucket: tuple):
        compiled = bucket not in self._seen_buckets
        self._seen_buckets.add(bucket)
        self.last_call = {"kind": kind, "bucket": bucket,
                          "compiled": compiled}

    def _prep(self, p: MatchProblem, active=None):
        """Order-permuted, padded host arrays (pad cohorts have demand 0
        and pad workers have zero free capacity — both take nothing)."""
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        Cp = max(chunk, ((C + chunk - 1) // chunk) * chunk)
        Wp = max(_W_LANES, ((W + _W_LANES - 1) // _W_LANES) * _W_LANES)
        order = np.concatenate(
            [np.asarray(p.order, dtype=np.int64),
             np.arange(C, Cp, dtype=np.int64)])
        req_o = np.zeros((Cp, R))
        req_o[:C] = p.requests[order[:C]]
        d_o = np.zeros(Cp)
        d_o[:C] = p.demand[order[:C]]
        if active is not None:
            d_o[:C] *= active[order[:C]]
        crow_o = np.zeros((Cp, Wp), dtype=np.uint8)
        crow_o[:C, :W] = p.compat[order[:C]]
        freeT = np.zeros((R, Wp))
        freeT[:, :W] = p.free.T
        pos = req_o > 0
        safe = np.where(pos, req_o, 1.0)
        big = np.where(pos, 0.0, _ZERO_WANT_BIG)
        return order, req_o, d_o, crow_o, freeT, safe, big, Cp, Wp

    def match(self, p: MatchProblem, *, budget: int | None = None,
              active: np.ndarray | None = None) -> MatchPlan:
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        (order, req_o, d_o, crow_o, freeT, safe, big,
         Cp, Wp) = self._prep(p, active)
        # per-chunk componentwise-min request among demanding cohorts
        # (the drain guard's lower bound; inf where a chunk is empty)
        req_live = np.where((d_o > 0)[:, None], req_o, np.inf)
        chunk_min = req_live.reshape(-1, chunk, R).min(axis=1)
        nch = Cp // chunk
        left = math.inf if budget is None else float(budget)
        self._note_call("match", (nch, Wp, self.dtype))

        if self.dtype == "float64":
            with enable_x64():
                takes_j, freeT_j, ran_j = self._run(
                    jnp.float64, freeT, left, req_o, safe, big, d_o,
                    crow_o, chunk_min, nch, chunk, R, Wp)
                takes_j = np.asarray(takes_j)
                freeT_j = np.asarray(freeT_j)
                ran = np.asarray(ran_j)
        else:
            takes_j, freeT_j, ran_j = self._run(
                jnp.float32, freeT, left, req_o, safe, big, d_o,
                crow_o, chunk_min, nch, chunk, R, Wp)
            takes_j = np.asarray(takes_j)
            freeT_j = np.asarray(freeT_j, dtype=np.float64)
            ran = np.asarray(ran_j)

        # scatter back to original cohort rows — only chunks that ran
        # (skipped chunks are all-zero by construction)
        takes_flat = takes_j.reshape(Cp, Wp)
        takes = np.zeros((Cp, W), dtype=np.int64)
        live = np.nonzero(np.repeat(ran, chunk))[0]
        takes[order[live]] = takes_flat[live, :W]
        return MatchPlan(takes=takes[:C],
                         free_after=freeT_j[:, :W].T.copy())

    def match_cycles(self, p: MatchProblem,
                     deltas: list[CycleDelta]) -> list[MatchPlan]:
        """K fused negotiation cycles in ONE device dispatch — see
        `base.sequential_match_cycles` for the reference semantics this
        must (and does, bit-for-bit) reproduce.  The free matrix and the
        live demand never leave the device between cycles; only the
        staged deltas ship down and only the K plans ship back."""
        if not deltas:
            return []
        C, W = p.compat.shape
        R = p.requests.shape[1]
        chunk = self.chunk
        (order, req_o, d_o, crow_o, freeT, safe, big,
         Cp, Wp) = self._prep(p)
        nch = Cp // chunk
        K = len(deltas)
        self._note_call("match_cycles", (nch, Wp, K, self.dtype))

        arrivals = np.zeros((K, Cp))
        free_addT = np.zeros((K, R, Wp))
        budgets = np.empty(K)
        for k, d in enumerate(deltas):
            arrivals[k, :C] = np.asarray(d.arrivals, dtype=np.float64)[
                order[:C]]
            if d.free_add is not None:
                free_addT[k, :, :W] = np.asarray(d.free_add).T
            budgets[k] = math.inf if d.budget is None else float(d.budget)

        if self.dtype == "float64":
            with enable_x64():
                takes_j, ran_j, free_per = self._run_cycles(
                    jnp.float64, freeT, d_o, arrivals, free_addT,
                    budgets, req_o, safe, big, crow_o, nch, chunk, R, Wp)
                takes_j = np.asarray(takes_j)
                ran = np.asarray(ran_j)
                free_per = np.asarray(free_per)
        else:
            takes_j, ran_j, free_per = self._run_cycles(
                jnp.float32, freeT, d_o, arrivals, free_addT,
                budgets, req_o, safe, big, crow_o, nch, chunk, R, Wp)
            takes_j = np.asarray(takes_j)
            ran = np.asarray(ran_j)
            free_per = np.asarray(free_per, dtype=np.float64)

        plans: list[MatchPlan] = []
        for k in range(K):
            takes_flat = takes_j[k].reshape(Cp, Wp)
            takes = np.zeros((Cp, W), dtype=np.int64)
            live = np.nonzero(np.repeat(ran[k], chunk))[0]
            takes[order[live]] = takes_flat[live, :W]
            plans.append(MatchPlan(takes=takes[:C],
                                   free_after=free_per[k][:, :W].T.copy()))
        return plans

    def _run_cycles(self, dt, freeT, d_o, arrivals, free_addT, budgets,
                    req_o, safe, big, crow_o, nch, chunk, R, Wp):
        K = arrivals.shape[0]
        return self._fn_cycles(
            jnp.asarray(freeT, dtype=dt),
            jnp.asarray(d_o.reshape(nch, chunk), dtype=dt),
            jnp.asarray(arrivals.reshape(K, nch, chunk), dtype=dt),
            jnp.asarray(free_addT, dtype=dt),
            jnp.asarray(budgets, dtype=dt),
            jnp.asarray(req_o.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(safe.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(big.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(crow_o.reshape(nch, chunk, Wp)),   # uint8 mask
        )

    def _run(self, dt, freeT, left, req_o, safe, big, d_o, crow_o,
             chunk_min, nch, chunk, R, Wp):
        return self._fn(
            jnp.asarray(freeT, dtype=dt),
            jnp.asarray(left, dtype=dt),
            jnp.asarray(req_o.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(safe.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(big.reshape(nch, chunk, R), dtype=dt),
            jnp.asarray(d_o.reshape(nch, chunk), dtype=dt),
            jnp.asarray(crow_o.reshape(nch, chunk, Wp)),   # uint8 mask
            jnp.asarray(chunk_min, dtype=dt),
        )
