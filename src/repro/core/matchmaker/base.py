"""The Matchmaker protocol: pure array matchmaking behind one interface.

The negotiation cycle splits into two halves:

  * the *pure* half — given cohort demand, worker free capacity, and a
    compatibility mask, decide how many jobs of each cohort every worker
    absorbs (`Matchmaker.match`).  No queues, no claims, no ledgers: a
    `MatchProblem` of NumPy arrays in, a `MatchPlan` of NumPy arrays
    out.  Backends are swappable (`make_matchmaker("numpy"|"jax"|
    "scan")`) and must be *claim-for-claim identical* — the differential
    suite (tests/test_matchmaker_differential.py) pins this.
  * the *stateful* half — building the problem from live queues/workers
    (memoized ClassAd evals) and applying the plan back (queue.claim,
    worker.add_claim, accountant charges).  That stays in
    `core.worker.Collector`, identical regardless of backend.

Semantics contract (all backends): cohorts are processed in
``problem.order``; each cohort greedily takes ``min(fits, remaining
demand)`` from workers in INDEX order (the seed's first-match rule),
where ``fits = floor(min_r free_r/want_r + 1e-9)`` over the cohort's
positive requests — the exact arithmetic of the legacy vectorized
negotiator, so `floor(7.6/0.4 + eps) == 19` everywhere.  A zero-request
cohort fits anywhere, bounded by demand.  ``budget`` caps total claims
(fair-share hands out quantum-sized slices); ``active`` restricts the
pass to a subset of cohorts (one (schedd, user) group per slice) without
re-building the problem.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

#: Resource quantities a slot offers / a job requests, in matrix column
#: order.  The negotiator's free-resource matrices, the quantity sanity
#: in classad.symmetric_match, and the scan oracle's exhausted-worker
#: rule all index into this tuple.
RESOURCE_KEYS = ("cpus", "gpus", "memory", "disk", "chips", "hbm_gb")

#: Columns whose exhaustion retires a worker from the scan oracle's
#: candidate list (cpus, gpus, chips — the "countable" slot resources).
EXHAUSTIBLE_IDX = (0, 1, 4)

#: The eps added before floor() when converting free/want ratios into
#: whole job slots (7.6/0.4 is 18.999...96 in binary floats and must
#: count as 19 — the scan oracle never divides, so it would claim it).
FIT_EPS = 1e-9


@dataclasses.dataclass
class MatchProblem:
    """A pure matchmaking instance: C cohorts × W workers × R resources.

    Built once per negotiation cycle by `Collector._build_problem`;
    `free` and `demand` are threaded through successive fair-share
    slices (assign ``free = plan.free_after`` and decrement ``demand``
    by the per-cohort take sums between `match` calls).
    """
    keys: list          # per cohort: (queue index, cohort key)
    requests: np.ndarray      # (C, R) float64 — per-job request vector
    demand: np.ndarray        # (C,)  int64 — idle jobs in the cohort
    order: np.ndarray         # (C,)  int64 — cohort processing order
    free: np.ndarray          # (W, R) float64 — live free capacity
    capacity: np.ndarray      # (W, R) float64 — full-slot capacity
    compat: np.ndarray        # (C, W) bool — expression compatibility
    scan_order: np.ndarray | None = None
    #: per-JOB cohort indices in global FIFO (submit-time) order — only
    #: the scan oracle consumes this; (sum(demand),) int64.

    @property
    def n_cohorts(self) -> int:
        return int(self.compat.shape[0])

    @property
    def n_workers(self) -> int:
        return int(self.compat.shape[1])


@dataclasses.dataclass
class MatchPlan:
    """The pure result: how many jobs of cohort c worker w absorbs."""
    takes: np.ndarray         # (C, W) int64
    free_after: np.ndarray    # (W, R) float64

    @property
    def claimed(self) -> int:
        return int(self.takes.sum())

    def per_cohort(self) -> np.ndarray:
        return self.takes.sum(axis=1)


@dataclasses.dataclass
class CycleDelta:
    """Host-staged state change applied BEFORE one fused negotiation
    cycle: demand that arrived since the previous cycle, capacity that
    was returned (completions), and the cycle's claim budget.

    `match_cycles` semantics (every backend, and the shared
    `sequential_match_cycles` reference): starting from the problem's
    demand/free, for each delta in order apply ``demand += arrivals``
    and ``free += free_add``, solve one plain cycle (no ``active``
    mask — fair-share slices stay on the per-cycle path), then carry
    ``demand -= plan.per_cohort()`` and ``free = plan.free_after`` into
    the next cycle.  K cycles, K plans, bit-identical to K sequential
    `match` calls with the same deltas applied host-side."""
    arrivals: np.ndarray            # (C,) int64 — demand added
    free_add: np.ndarray | None = None   # (W, R) float64 — capacity back
    budget: int | None = None       # per-cycle claim cap


@runtime_checkable
class Matchmaker(Protocol):
    """Anything with a ``name`` and a pure ``match``; see the module
    docstring for the semantics every implementation must honour."""

    name: str

    def match(self, problem: MatchProblem, *,
              budget: int | None = None,
              active: np.ndarray | None = None) -> MatchPlan:
        """Solve one matchmaking pass.  Must NOT mutate the problem."""
        ...


def sequential_match_cycles(mm: "Matchmaker", problem: MatchProblem,
                            deltas: list[CycleDelta]) -> list[MatchPlan]:
    """The K-cycle reference semantics: K independent `match` calls with
    the deltas applied host-side between them.  Backends without a fused
    `match_cycles` route here; the fused jax path must be bit-identical
    to this loop (tests/test_fused_negotiation.py pins it)."""
    demand = np.asarray(problem.demand, dtype=np.int64).copy()
    free = np.array(problem.free, dtype=np.float64, copy=True)
    plans: list[MatchPlan] = []
    for d in deltas:
        demand = demand + np.asarray(d.arrivals, dtype=np.int64)
        if d.free_add is not None:
            free = free + d.free_add
        sub = dataclasses.replace(problem, demand=demand, free=free)
        plan = mm.match(sub, budget=d.budget)
        demand = demand - plan.per_cohort()
        free = plan.free_after
        plans.append(plan)
    return plans


def match_cycles(mm: "Matchmaker", problem: MatchProblem,
                 deltas: list[CycleDelta]) -> list[MatchPlan]:
    """Dispatch K consecutive cycles to the backend's fused
    implementation when it has one, else the sequential reference."""
    fused = getattr(mm, "match_cycles", None)
    if fused is not None:
        return fused(problem, deltas)
    return sequential_match_cycles(mm, problem, deltas)


def sequential_preview_many(mm: "Matchmaker", problem: MatchProblem,
                            frees: list[np.ndarray],
                            demands: list[np.ndarray] | None = None,
                            ) -> list[np.ndarray]:
    """The batched-preview reference semantics: N INDEPENDENT previews of
    the same cohort structure, candidate i solved against ``frees[i]``
    (and ``demands[i]`` when given, else the problem's demand), each
    returning only the per-cohort absorbed counts ``plan.per_cohort()``.
    Candidates do NOT carry state into each other — this is the
    provisioner asking "what WOULD each candidate pool shape absorb",
    not a fused multi-cycle negotiation.  Backends with a vectorised
    `preview_many` must match this loop exactly
    (tests/test_preview_many.py pins it against the numpy reference)."""
    out: list[np.ndarray] = []
    for i, f in enumerate(frees):
        sub = dataclasses.replace(
            problem, free=f,
            demand=problem.demand if demands is None else demands[i])
        out.append(mm.match(sub).per_cohort())
    return out


def preview_many(mm: "Matchmaker", problem: MatchProblem,
                 frees: list[np.ndarray],
                 demands: list[np.ndarray] | None = None,
                 ) -> list[np.ndarray]:
    """Dispatch a batch of independent previews to the backend's
    vectorised implementation when it has one (the jax backend evaluates
    all candidates in ONE jitted vmap dispatch), else the sequential
    reference."""
    fused = getattr(mm, "preview_many", None)
    if fused is not None:
        return fused(problem, frees, demands)
    return sequential_preview_many(mm, problem, frees, demands)


def cohort_fits(free: np.ndarray, want: np.ndarray,
                demand: int) -> np.ndarray:
    """How many `want`-sized jobs each worker row of `free` absorbs —
    the shared fits arithmetic (see FIT_EPS).  Zero-request cohorts fit
    anywhere, bounded by demand."""
    pos = want > 0
    if pos.any():
        fits = np.floor((free[:, pos] / want[pos]).min(axis=1) + FIT_EPS)
        return np.maximum(fits, 0.0)
    return np.full(free.shape[0], float(demand))


# -- registry ----------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Matchmaker]] = {}


def register_matchmaker(name: str, factory: Callable[..., Matchmaker]):
    """Register a backend factory under `name` (how to add a backend:
    implement `match`, register a factory, and run the differential
    suite against the numpy reference — see README 'Negotiation
    architecture')."""
    _REGISTRY[name] = factory


def matchmaker_names() -> list[str]:
    return sorted(_REGISTRY)


def make_matchmaker(spec: Any = "numpy", **kwargs) -> Matchmaker:
    """Resolve a backend: an instance passes through, a registered name
    is constructed (kwargs forwarded to the factory)."""
    if spec is None:
        spec = "numpy"
    if isinstance(spec, str):
        try:
            factory = _REGISTRY[spec]
        except KeyError:
            raise ValueError(
                f"unknown matchmaker {spec!r}; "
                f"registered: {matchmaker_names()}") from None
        return factory(**kwargs)
    if isinstance(spec, Matchmaker):
        return spec
    raise TypeError(f"matchmaker must be a name or Matchmaker instance, "
                    f"got {spec!r}")
