"""Differential oracle: the seed's per-job O(jobs × workers) scan.

`Collector.negotiate_scan` kept the seed's tick-era loop as the
baseline; this backend is that loop behind the `Matchmaker` interface,
operating on the pure problem arrays.  Jobs are visited one at a time
in global FIFO order (``problem.scan_order``), each claiming the first
candidate worker whose live free capacity covers the request
(``want <= free`` exactly, matching `classad.symmetric_match`'s
quantity sanity — the scan's arithmetic never divides).  A worker drops
off the candidate list once any declared countable resource
(cpus/gpus/chips) is exhausted, exactly as the seed did.

Useful as the ground truth in differential tests — never as the fast
path (it is the O(jobs × workers) baseline the vectorized backends are
measured against).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.matchmaker.base import (
    EXHAUSTIBLE_IDX, MatchPlan, MatchProblem,
)


class ScanMatchmaker:
    """The per-job FIFO oracle (`make_matchmaker("scan")`)."""

    name = "scan"

    def match(self, p: MatchProblem, *, budget: int | None = None,
              active: np.ndarray | None = None) -> MatchPlan:
        free = np.array(p.free, dtype=np.float64, copy=True)
        C, W = p.compat.shape
        takes = np.zeros((C, W), dtype=np.int64)
        if p.scan_order is not None:
            scan_order = p.scan_order
        else:
            # no per-job submit order provided: jobs of each cohort are
            # contiguous at the cohort's place in the processing order
            scan_order = np.repeat(p.order, p.demand[p.order])
        left = math.inf if budget is None else int(budget)
        # candidate workers in advertisement (index) order; a worker is
        # retired once any declared countable resource hits zero
        alive = [wi for wi in range(W)]
        given = np.zeros(C, dtype=np.int64)
        for c in scan_order:
            if left <= 0 or not alive:
                break
            if active is not None and not active[c]:
                continue
            if given[c] >= p.demand[c]:
                continue
            want = p.requests[c]
            matched = -1
            for wi in alive:
                if not p.compat[c, wi]:
                    continue
                if np.any(want > free[wi]):
                    continue
                matched = wi
                break
            if matched < 0:
                continue
            takes[c, matched] += 1
            given[c] += 1
            left -= 1
            free[matched] -= want
            exhausted = any(
                free[matched, r] <= 0
                for r in EXHAUSTIBLE_IDX if p.capacity[matched, r]
            )
            if exhausted:
                alive.remove(matched)
        return MatchPlan(takes=takes, free_after=free)
