"""Reference matchmaker: the legacy vectorized-NumPy negotiation core.

This is the claiming loop that lived inline in
`Collector._match_cohorts` (PR 3), made pure: per cohort a vectorized
fits row over the worker free matrix, then the seed's first-match walk
handing each worker ``min(fits, remaining)`` jobs in index order.  Every
other backend is differentially tested against this one.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.matchmaker.base import (
    MatchPlan, MatchProblem, cohort_fits,
)


class NumpyMatchmaker:
    """The reference implementation (`make_matchmaker("numpy")`)."""

    name = "numpy"

    def match(self, p: MatchProblem, *, budget: int | None = None,
              active: np.ndarray | None = None) -> MatchPlan:
        free = np.array(p.free, dtype=np.float64, copy=True)
        C, W = p.compat.shape
        takes = np.zeros((C, W), dtype=np.int64)
        left = math.inf if budget is None else int(budget)
        for c in p.order:
            if left <= 0:
                break
            if active is not None and not active[c]:
                continue
            d = int(p.demand[c])
            if d <= 0:
                continue
            d = min(d, left) if left != math.inf else d
            want = p.requests[c]
            fits = cohort_fits(free, want, d)
            if not fits.any():      # the legacy drained-pool fast path
                continue
            crow = p.compat[c]
            row = takes[c]
            remaining = d
            for wi in range(W):
                if remaining <= 0:
                    break
                k = int(fits[wi])
                if k <= 0 or not crow[wi]:
                    continue
                t = k if k < remaining else remaining
                row[wi] = t
                free[wi] -= want * t
                remaining -= t
            left -= d - remaining
        return MatchPlan(takes=takes, free_after=free)
