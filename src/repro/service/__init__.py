"""Long-running pool service over the discrete-event simulator.

The paper's provisioner is a daemon: it watches live schedds and grows/
shrinks a Kubernetes pool while users keep submitting.  This package
turns the repo's `Simulation` into exactly that — a process that accepts
streaming submissions, paces the event loop against wall-clock time,
exposes pool state over HTTP, survives kill/restart via full-state
snapshots, and reconfigures (add/drain backends and schedds) without a
restart.

  driver.py    WallClockDriver: paces the event loop at `speed`× real
               time (or as fast as possible) and injects concurrent
               operations only at quiescent instants
  pool.py      PoolService (the daemon brain) + PoolClient (in-process)
               + RemoteClient (urllib, for the CLI)
  http.py      stdlib-only JSON HTTP surface (submit/status/rm/metrics/
               snapshot/reconfigure)
  __main__.py  `python -m repro.service` CLI

Nothing here touches the decision logic: the provisioner, negotiator,
and backends run unmodified — the service only replaces the clock and
the submission surface, the same separation the wall-clock launch path
relies on.
"""
from repro.service.driver import WallClockDriver
from repro.service.pool import PoolClient, PoolService, RemoteClient

__all__ = [
    "PoolClient",
    "PoolService",
    "RemoteClient",
    "WallClockDriver",
]
