"""Pool-service CLI: serve a live pool, talk to one, or run the smoke.

    # serve the standard 3-provider federation at 60x real time
    python -m repro.service serve --standard --speed 60 --port 8080 --start

    # stream a generated day of demand into it at trace times
    python -m repro.service submit --url http://127.0.0.1:8080 \
        --preset diurnal --jobs 1000 --at-trace-times

    # watch it
    python -m repro.service status --url http://127.0.0.1:8080
    python -m repro.service metrics --url http://127.0.0.1:8080

    # telemetry: Prometheus scrape / Chrome trace (open in Perfetto)
    curl http://127.0.0.1:8080/metrics.prom
    python -m repro.service trace --url http://127.0.0.1:8080 \
        --path trace.json

    # full-state snapshot to disk; later: serve --resume pool.json
    python -m repro.service snapshot --url http://127.0.0.1:8080 \
        --path pool.json

    # retire a provider without restarting
    python -m repro.service drain-backend --url http://127.0.0.1:8080 \
        --name spot

    # end-to-end acceptance smoke (submit -> snapshot/kill/resume ->
    # runtime drain -> drained; equality vs the uninterrupted run)
    python -m repro.service smoke --jobs 10000 --budget-s 600

Exit codes: 0 ok; 1 bad usage; 2 smoke failure or budget exceeded.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.service.http import serve, serve_in_thread
from repro.service.pool import PoolClient, PoolService, RemoteClient
from repro.workload.compare import FEDERATION_INI
from repro.workload.generators import DAY_S, generate_preset
from repro.workload.trace import Trace

STANDARD_INI = FEDERATION_INI.format(routing="cheapest-first",
                                     onprem_nodes=4, cloud_max_nodes=24,
                                     spot_max_nodes=24)


def _print(doc) -> int:
    print(json.dumps(doc, indent=1))
    return 0


def _speed(args) -> float | None:
    return None if args.as_fast else args.speed


# -- serve --------------------------------------------------------------------
def _cmd_serve(args) -> int:
    if args.resume:
        svc = PoolService.resume(args.resume, speed=_speed(args))
        print(f"resumed from {args.resume} at t={svc.sim.now}")
    else:
        ini = STANDARD_INI if args.standard else None
        if args.ini:
            with open(args.ini) as f:
                ini = f.read()
        if ini is None:
            print("serve: need --ini FILE, --standard, or --resume SNAP",
                  file=sys.stderr)
            return 1
        schedds = args.schedds if args.schedds else None
        svc = PoolService(ini, schedds=schedds, fairshare=args.fairshare,
                          tick_s=args.tick_s,
                          negotiate_interval_s=args.negotiate_interval_s,
                          metrics_interval_s=args.metrics_interval_s,
                          seed=args.seed, speed=_speed(args))
    server = serve(svc, args.host, args.port)
    addr, port = server.server_address[:2]
    if args.start:
        svc.start()
    print(f"pool service on http://{addr}:{port} "
          f"(speed={svc.driver.speed}, driver "
          f"{'running' if svc.driver.running else 'held — POST /start'})")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        svc.stop()
    return 0


# -- client verbs -------------------------------------------------------------
def _records_from_args(args):
    if args.trace:
        return [r.to_obj() for r in Trace.load(args.trace).records]
    return [r.to_obj()
            for r in generate_preset(args.preset, args.jobs,
                                     seed=args.seed,
                                     duration_s=args.duration_s).records]


def _cmd_submit(args) -> int:
    rc = RemoteClient(args.url)
    return _print(rc.submit(_records_from_args(args), schedd=args.schedd,
                            at_trace_times=args.at_trace_times,
                            at=args.at))


def _cmd_client(args) -> int:
    rc = RemoteClient(args.url)
    verb = args.cmd
    if verb == "status":
        return _print(rc.status())
    if verb == "metrics":
        return _print(rc.metrics())
    if verb == "metrics-prom":
        print(rc.metrics_prom(), end="")
        return 0
    if verb == "trace":
        doc = rc.trace()
        if args.path:
            with open(args.path, "w") as f:
                json.dump(doc, f)
            print(f"{len(doc['traceEvents'])} events -> {args.path}")
            return 0
        return _print(doc)
    if verb == "job":
        return _print(rc.job_status(args.jid))
    if verb == "rm":
        return _print(rc.rm(args.jid))
    if verb == "snapshot":
        return _print(rc.snapshot(args.path))
    if verb == "drain-backend":
        return _print(rc.drain_backend(args.name, at=args.at))
    if verb == "add-backend":
        with open(args.ini) as f:
            return _print(rc.add_backend(f.read()))
    if verb == "add-schedd":
        return _print(rc.add_schedd(args.name, quota=args.quota))
    if verb == "drain-schedd":
        return _print(rc.drain_schedd(args.name, at=args.at))
    if verb == "start":
        return _print(rc.start(None if args.as_fast else args.speed))
    if verb == "shutdown":
        return _print(rc.shutdown())
    raise AssertionError(verb)


# -- the acceptance smoke -----------------------------------------------------
SMOKE_KW = dict(tick_s=30.0, negotiate_interval_s=60.0,
                metrics_interval_s=300.0, seed=0, speed=None)


def _smoke_reference(ini, trace, t_drain, max_t):
    """The uninterrupted oracle: same trace at trace times, same runtime
    drain, batch-driven as fast as possible."""
    svc = PoolService(ini, **SMOKE_KW)
    client = PoolClient(svc)
    client.submit(trace.records, at_trace_times=True, at=0.0)
    client.drain_backend("spot", at=t_drain)
    svc.run_until_drained(max_t)
    return svc


def _cmd_smoke(args) -> int:
    t0 = time.time()
    trace = generate_preset("diurnal", args.jobs, seed=args.seed)
    ini = STANDARD_INI
    t_drain, max_t = 30_000.0, 5e6
    fail = lambda msg: (print(f"SMOKE FAIL: {msg}", file=sys.stderr), 2)[1]

    # 1. uninterrupted reference run
    ref = _smoke_reference(ini, trace, t_drain, max_t)
    ref_jobs = ref.completed_stats().state_dict()
    ref_summary = ref.summary()
    wall_ref = time.time() - t0
    print(f"reference drained at t={ref.sim.now:.0f} "
          f"({ref_jobs['n']} jobs, wall {wall_ref:.1f}s)")

    # 2. live service over HTTP: submit, run, snapshot mid-run, kill
    svc = PoolService(ini, **SMOKE_KW)
    server, url = serve_in_thread(svc)
    rc = RemoteClient(url, timeout=120.0)
    if not rc.healthz().get("ok"):
        return fail("healthz not ok")
    r = rc.submit([rec.to_obj() for rec in trace.records],
                  at_trace_times=True, at=0.0)
    if r.get("scheduled") != len(trace.records):
        return fail(f"submit scheduled {r} != {len(trace.records)}")
    rc.drain_backend("spot", at=t_drain)
    rc.start(None)                      # as fast as possible
    t_snap = 10_000.0
    while True:
        st = rc.status()
        if st["t"] >= t_snap or st["drained"]:
            break
        time.sleep(0.02)
    snap_path = args.snapshot_path
    saved = rc.snapshot(snap_path)
    print(f"snapshot at t={saved['t']:.0f} -> {saved['path']}")
    rc.shutdown()                       # kill the first service
    server.server_close()

    # 3. resume from disk and drain the rest
    svc2 = PoolService.resume(snap_path, speed=None)
    server2, url2 = serve_in_thread(svc2)
    rc2 = RemoteClient(url2, timeout=120.0)
    rc2.start(None)
    deadline = time.time() + (args.budget_s or 3600.0)
    while True:
        st = rc2.status()
        if st["drained"]:
            break
        if time.time() > deadline:
            return fail(f"resumed run not drained in budget (t={st['t']})")
        time.sleep(0.02)
    svc2.stop()

    # 4. /metrics JSON is well-formed and carries the Fig 2/3 series
    m = rc2.metrics()
    for key in ("gauges", "backends", "series"):
        if key not in m:
            return fail(f"/metrics missing {key!r}")
    for key in ("idle_jobs", "running_jobs", "provisioned_cores",
                "cost_rate"):
        if key not in m["series"]:
            return fail(f"/metrics series missing {key!r}")
        if key not in m["gauges"]:
            return fail(f"/metrics gauges missing {key!r}")

    # 4b. telemetry surfaces: Prometheus text + Chrome trace over HTTP
    prom = rc2.metrics_prom()
    for needle in ("# TYPE repro_pool_idle_jobs gauge",
                   "# TYPE repro_job_wait_seconds histogram",
                   "# TYPE repro_cycle_phase_seconds histogram",
                   "repro_job_spans_total"):
        if needle not in prom:
            return fail(f"/metrics.prom missing {needle!r}")
    tr = rc2.trace()
    evs = tr.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return fail("/trace has no traceEvents")
    if any(not {"name", "ph", "pid"} <= set(e)
           or (e["ph"] != "M" and "ts" not in e) for e in evs):
        return fail("/trace events missing required keys")
    if not any(e.get("ph") == "X" and e.get("cat") == "job,run"
               for e in evs):
        return fail("/trace has no job run spans")
    print(f"telemetry: {len(prom.splitlines())} prom lines, "
          f"{len(evs)} trace events")
    rc2.shutdown()
    server2.server_close()

    # 5. equality with the uninterrupted run + conservation vs the trace
    got_jobs = svc2.completed_stats().state_dict()
    got_summary = svc2.summary()
    if st["detached_backends"] != ["spot"]:
        return fail(f"spot not detached: {st['detached_backends']}")
    if got_jobs != ref_jobs:
        return fail(f"completed stats diverge:\n ref {ref_jobs}\n "
                    f"got {got_jobs}")
    a = json.dumps(ref_summary, sort_keys=True, default=str)
    b = json.dumps(got_summary, sort_keys=True, default=str)
    if a != b:
        return fail("summary() diverges between uninterrupted and "
                    "snapshot/resume runs")
    stats = trace.stats()
    close = (lambda x, y:
             abs(x - y) <= 1e-6 * max(1.0, abs(x), abs(y)))
    if got_jobs["n"] != stats["n"]:
        return fail(f"completed {got_jobs['n']} != trace {stats['n']}")
    if not close(got_jobs["core_seconds"], stats["core_seconds"]):
        return fail("core-seconds conservation violated")
    if not close(got_jobs["gpu_seconds"], stats["gpu_seconds"]):
        return fail("gpu-seconds conservation violated")

    wall = time.time() - t0
    print(f"SMOKE OK: {got_jobs['n']} jobs streamed over HTTP, snapshot/"
          f"kill/resume at t={saved['t']:.0f}, spot drained at "
          f"t={t_drain:.0f}, equality + conservation hold "
          f"(wall {wall:.1f}s)")
    if args.budget_s is not None and wall > args.budget_s:
        print(f"FAIL: {wall:.1f}s > budget {args.budget_s}s",
              file=sys.stderr)
        return 2
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.service",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("serve", help="run a pool service")
    s.add_argument("--ini", default=None, help="federation INI file")
    s.add_argument("--standard", action="store_true",
                   help="use the standard 3-provider federation")
    s.add_argument("--resume", default=None, metavar="SNAPSHOT",
                   help="resume from a snapshot file")
    s.add_argument("--schedds", type=int, default=0,
                   help="flocking: N submit hosts (0 = single schedd)")
    s.add_argument("--fairshare", action="store_true")
    s.add_argument("--tick-s", type=float, default=30.0)
    s.add_argument("--negotiate-interval-s", type=float, default=60.0)
    s.add_argument("--metrics-interval-s", type=float, default=300.0)
    s.add_argument("--seed", type=int, default=0)
    s.add_argument("--speed", type=float, default=1.0,
                   help="simulated seconds per wall second")
    s.add_argument("--as-fast", action="store_true",
                   help="no pacing (idle between submissions)")
    s.add_argument("--host", default="127.0.0.1")
    s.add_argument("--port", type=int, default=8080)
    s.add_argument("--start", action="store_true",
                   help="start the clock immediately")
    s.set_defaults(fn=_cmd_serve)

    def _url(p):
        p.add_argument("--url", required=True)

    sm = sub.add_parser("submit", help="submit jobs to a served pool")
    _url(sm)
    sm.add_argument("--trace", default=None, help="JSONL/CSV trace file")
    sm.add_argument("--preset", default="diurnal",
                    choices=("diurnal", "poisson", "uniform-burst"))
    sm.add_argument("--jobs", type=int, default=100)
    sm.add_argument("--seed", type=int, default=0)
    sm.add_argument("--duration-s", type=float, default=DAY_S)
    sm.add_argument("--schedd", default=None)
    sm.add_argument("--at-trace-times", action="store_true",
                    help="schedule each record at base+arrival_s "
                         "instead of submitting everything now")
    sm.add_argument("--at", type=float, default=None)
    sm.set_defaults(fn=_cmd_submit)

    for verb, opts in (
        ("status", ()), ("metrics", ()), ("metrics-prom", ()),
        ("shutdown", ()),
        ("job", ("jid",)), ("rm", ("jid",)),
        ("snapshot", ("path",)),
        ("trace", ("tracepath",)),
        ("drain-backend", ("name", "at")),
        ("add-backend", ("bini",)),
        ("add-schedd", ("name", "quota")),
        ("drain-schedd", ("name", "at")),
        ("start", ("speed2",)),
    ):
        p = sub.add_parser(verb)
        _url(p)
        if "jid" in opts:
            p.add_argument("--jid", type=int, required=True)
        if "path" in opts:
            p.add_argument("--path", default=None,
                           help="save to this file on the SERVER "
                                "(inline JSON when omitted)")
        if "tracepath" in opts:
            p.add_argument("--path", default=None,
                           help="write Chrome trace JSON to this local "
                                "file (print inline when omitted)")
        if "name" in opts:
            p.add_argument("--name", required=True)
        if "at" in opts:
            p.add_argument("--at", type=float, default=None,
                           help="sim time to apply at (default: now)")
        if "bini" in opts:
            p.add_argument("--ini", required=True,
                           help="INI file with [backend:<name>] sections")
        if "quota" in opts:
            p.add_argument("--quota", type=float, default=1.0)
        if "speed2" in opts:
            p.add_argument("--speed", type=float, default=1.0)
            p.add_argument("--as-fast", action="store_true")
        p.set_defaults(fn=_cmd_client)

    k = sub.add_parser("smoke",
                       help="end-to-end acceptance: HTTP stream + "
                            "snapshot/kill/resume + runtime drain")
    k.add_argument("--jobs", type=int, default=10_000)
    k.add_argument("--seed", type=int, default=7)
    k.add_argument("--budget-s", type=float, default=None)
    k.add_argument("--snapshot-path", default="/tmp/pool_smoke_snap.json")
    k.set_defaults(fn=_cmd_smoke)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
