"""Wall-clock driver: paces a discrete-event Simulation in real time.

The event loop is a pure function of its heap — it has no clock of its
own.  This driver maps simulation time onto monotonic wall time
(`speed=N` runs N simulated seconds per real second; `speed=None` runs
as fast as possible) and fires events when their wall deadline arrives.

Concurrency model — single-writer, quiescent injection points:

  * ONE background thread owns the simulation.  Every outside operation
    (submit, status, snapshot, drain) is a closure handed to `call()`,
    which enqueues it and wakes the thread; the caller blocks until the
    thread has run it and returns (or re-raises) the result.
  * Injections run only BETWEEN timestamp groups: the thread fires every
    event sharing the current timestamp before servicing the queue, so
    an injected `Simulation.state_dict()` always sees a quiescent
    instant — the invariant its snapshot gate checks.
  * When the thread is not running, `call()` executes inline (after the
    same settle step), so tests and the as-fast batch path share one
    code path with the live service.

Pacing detail: the deadline for simulated time t is
``wall0 + (t - sim0)/speed``.  A late deadline (slow host, long
injection) fires immediately — the driver catches up rather than
stretching simulated cadences.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable


class _Injection:
    """One queued closure plus its completion signal."""

    __slots__ = ("fn", "done", "result", "error")

    def __init__(self, fn: Callable[[Any], Any]):
        self.fn = fn
        self.done = threading.Event()
        self.result: Any = None
        self.error: BaseException | None = None


class WallClockDriver:
    def __init__(self, sim, *, speed: float | None = 1.0,
                 idle_poll_s: float = 0.05):
        if speed is not None and speed <= 0:
            raise ValueError(f"speed must be positive or None, got {speed}")
        self.sim = sim
        self.speed = speed
        self.idle_poll_s = idle_poll_s
        self._cond = threading.Condition()
        self._queue: list[_Injection] = []
        self._thread: threading.Thread | None = None
        self._stop = False

    # -- lifecycle -----------------------------------------------------------
    @property
    def running(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    def start(self):
        if self.running:
            raise RuntimeError("driver already running")
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, name="pool-driver", daemon=True)
        self._thread.start()

    def stop(self, timeout: float | None = 30.0):
        """Graceful stop: the thread finishes the current timestamp group
        and drains queued injections before exiting, so the simulation is
        left quiescent (snapshot-safe)."""
        t = self._thread
        if t is None:
            return
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        t.join(timeout)
        if t.is_alive():
            raise RuntimeError("driver thread failed to stop in time")
        self._thread = None

    # -- injection -----------------------------------------------------------
    def call(self, fn: Callable[[Any], Any]) -> Any:
        """Run `fn(sim)` at the next quiescent instant and return its
        result (exceptions propagate to the caller).  Inline when the
        thread is not running."""
        if not self.running:
            self._settle()
            return fn(self.sim)
        inj = _Injection(fn)
        with self._cond:
            if self._stop:
                raise RuntimeError("driver is stopping")
            self._queue.append(inj)
            self._cond.notify_all()
        inj.done.wait()
        if inj.error is not None:
            raise inj.error
        return inj.result

    # -- event-loop mechanics ------------------------------------------------
    def _settle(self):
        """Fire every event due at or before the current simulated time —
        afterwards `loop.next_at() > sim.now` (or the heap is empty), the
        quiescence `state_dict()` requires.  A fresh simulation settles
        through its whole t=0 group here."""
        sim = self.sim
        while True:
            t = sim.loop.next_at()
            if t is None or t > sim.now:
                break
            self._fire_group(t)
        # injections may read pool state or schedule events inside a
        # deferred-negotiation window; flush any staged cycles so they
        # observe (and mutate) fully-applied claim state
        quiesce = getattr(sim, "quiesce_negotiation", None)
        if quiesce is not None:
            quiesce()

    def _fire_group(self, t: float):
        """Fire ALL events sharing timestamp `t` — injections never see a
        half-fired instant."""
        sim = self.sim
        while True:
            sim._advance_to(t)
            sim.loop.fire_next()
            nxt = sim.loop.next_at()
            if nxt is None or nxt > t:
                break
        sim.now = sim.loop.now

    def _drain_injections(self) -> bool:
        with self._cond:
            pending, self._queue = self._queue, []
        if not pending:
            return False
        self._settle()
        for inj in pending:
            try:
                inj.result = inj.fn(self.sim)
            except BaseException as e:  # propagate to the caller, not us
                inj.error = e
            finally:
                inj.done.set()
        return True

    def _idle(self) -> bool:
        """Nothing left that time itself will change: every queue drained
        and no external events pending.  Periodic timers alone don't
        count — in as-fast mode they would otherwise spin the simulated
        clock toward infinity between submissions."""
        sim = self.sim
        return sim.pool_queue.drained() and sim._external_pending == 0

    def _run(self):
        wall0 = time.monotonic()
        sim0 = self.sim.now
        while True:
            had_work = self._drain_injections()
            with self._cond:
                if self._stop and not self._queue:
                    break
            if had_work:
                continue
            t = self.sim.loop.next_at()
            if t is None or (self.speed is None and self._idle()):
                with self._cond:
                    if not self._queue and not self._stop:
                        self._cond.wait(self.idle_poll_s)
                continue
            if self.speed is not None:
                deadline = wall0 + (t - sim0) / self.speed
                late = time.monotonic() >= deadline
                if not late:
                    with self._cond:
                        if not self._queue and not self._stop:
                            self._cond.wait(min(
                                max(deadline - time.monotonic(), 0.0),
                                0.25))
                    continue   # re-check injections/stop before firing
            self._fire_group(t)
        # leave quiescent: finish the instant we stopped inside of
        self._settle()
        self._drain_injections()
