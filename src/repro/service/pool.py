"""PoolService: the long-running pool daemon, plus its two clients.

A `PoolService` owns one `Simulation` (built from the same INI format the
compare harness uses), a `WallClockDriver` pacing it, and the streaming
bookkeeping the batch harness never needed:

  * per-schedd `CompletedStats` aggregators (queues run with
    ``keep_completed=False`` so a week of arrivals never accumulates Job
    objects) plus a bounded terminal-state index for `condor_q`-style
    lookups of finished jobs
  * a serializable pending-operation ledger: submissions scheduled at
    trace times and delayed reconfigurations (drain-at-t) are kept as
    plain records, so a snapshot can carry them even though the event
    loop itself only holds closures — `resume()` re-schedules them
  * snapshot/resume: ``snapshot()`` wraps `Simulation.state_dict()` with
    the service-level state above; ``PoolService.resume(state)`` rebuilds
    the simulation from the stored config (re-adding runtime-added
    backends first), restores it, and re-arms the pending ledger — a
    killed service continues exactly where the uninterrupted one would be

Every public method routes through the driver's quiescent injection
point, so the HTTP layer and in-process callers can hit a LIVE paced
pool from any thread.  `PoolClient` is the in-process client (same
surface as `RemoteClient`, the urllib one in this module, and the HTTP
endpoints in http.py).
"""
from __future__ import annotations

import itertools
import json
import urllib.request
from collections import OrderedDict
from typing import Any, Iterable

from repro.core import Simulation, load_ini
from repro.core.backend import build_backends
from repro.core.metrics import CompletedStats, summarize_backends, timeline
from repro.workload.compare import SERIES_KEYS
from repro.workload.trace import TraceRecord

# condor_history analogue: remember the last N terminal jobs, not all
TERMINAL_INDEX_MAX = 20_000


class PoolService:
    def __init__(self, ini: str, *, schedds=None, fairshare: bool = False,
                 tick_s: float = 30.0, negotiate_interval_s: float = 60.0,
                 metrics_interval_s: float = 300.0, seed: int = 0,
                 speed: float | None = 1.0, telemetry: bool = True):
        # everything needed to rebuild an identical Simulation at
        # resume() — the snapshot stores this verbatim
        self._config: dict[str, Any] = {
            "ini": ini,
            "schedds": schedds,
            "fairshare": bool(fairshare),
            "tick_s": tick_s,
            "negotiate_interval_s": negotiate_interval_s,
            "metrics_interval_s": metrics_interval_s,
            "seed": seed,
            "speed": speed,
            "telemetry": bool(telemetry),
        }
        self.sim = self._build_sim()
        self.completed: dict[str, CompletedStats] = {}
        self._terminal: OrderedDict[int, dict] = OrderedDict()
        self._wire_queues()
        self._seq = itertools.count()
        self._pending: dict[int, dict] = {}     # seq -> {at, kind, payload}
        self._added_backend_ini: list[str] = []
        from repro.service.driver import WallClockDriver
        self.driver = WallClockDriver(self.sim, speed=speed)

    # -- construction --------------------------------------------------------
    def _build_sim(self) -> Simulation:
        c = self._config
        cfg = load_ini(c["ini"])
        return Simulation.from_config(
            cfg, tick_s=c["tick_s"],
            negotiate_interval_s=c["negotiate_interval_s"],
            metrics_interval_s=c["metrics_interval_s"],
            seed=c["seed"], schedds=c["schedds"],
            fairshare=True if c["fairshare"] else None,
            telemetry=c.get("telemetry", True))

    def _wire_queues(self):
        """Streaming completion stats + terminal index on every queue not
        yet wired (base queues, then runtime-added schedds)."""
        for q in self.sim.queues:
            if q.name in self.completed:
                continue
            cs = CompletedStats()
            self.completed[q.name] = cs

            def hook(job, _cs=cs):
                _cs.observe(job)
                self._remember(job.jid, "completed", job.completed_at)

            q.keep_completed = False
            q.add_complete_hook(hook)

    def _remember(self, jid: int, state: str, t: float):
        self._terminal[int(jid)] = {"state": state, "t": t}
        while len(self._terminal) > TERMINAL_INDEX_MAX:
            self._terminal.popitem(last=False)

    def _call(self, fn):
        return self.driver.call(fn)

    # -- the pending-operation ledger ----------------------------------------
    def _schedule_op(self, at: float, kind: str, payload: dict,
                     seq: int | None = None):
        """Schedule a serializable operation at sim time `at`.  The loop
        holds only the firing closure; the (at, kind, payload) record in
        `_pending` is what a snapshot carries and resume() re-schedules."""
        if seq is None:
            seq = next(self._seq)
        self._pending[seq] = {"at": at, "kind": kind, "payload": payload}

        def fire(sim, now):
            self._pending.pop(seq, None)
            self._dispatch(sim, now, kind, payload)

        self.sim.at(at, fire, name=f"svc:{kind}")

    def _dispatch(self, sim, now: float, kind: str, payload: dict):
        if kind == "submit":
            rec = TraceRecord.from_obj(payload["record"])
            sim.queue_named(payload["schedd"]).submit(rec.to_job(), now)
        elif kind == "drain_backend":
            sim.drain_backend(payload["name"])
        elif kind == "drain_schedd":
            sim.drain_schedd(payload["name"])
        else:
            raise ValueError(f"unknown pending op {kind!r}")

    # -- submission surface --------------------------------------------------
    def submit(self, records: Iterable[TraceRecord | dict], *,
               schedd=None, at_trace_times: bool = False,
               at: float | None = None) -> dict:
        """Submit jobs.  Default: every record enters the queue at the
        CURRENT sim time (`condor_submit` now), returning the jids.  With
        `at_trace_times=True` each record is scheduled at
        ``base + arrival_s`` (base = `at`, default now) — the streaming
        analogue of a trace replay, snapshot-safe via the ledger."""
        recs = [r if isinstance(r, TraceRecord) else TraceRecord.from_obj(r)
                for r in records]
        for r in recs:
            r.validate()

        def op(sim):
            q = sim.queue_named(schedd)
            if getattr(q, "draining", False):
                raise ValueError(f"schedd {q.name!r} is draining")
            if not at_trace_times:
                jids = [q.submit(r.to_job(), sim.now) for r in recs]
                return {"jids": jids, "t": sim.now, "schedd": q.name}
            base = sim.now if at is None else float(at)
            for r in recs:
                self._schedule_op(base + r.arrival_s, "submit",
                                  {"schedd": q.name, "record": r.to_obj()})
            return {"scheduled": len(recs), "base_t": base,
                    "schedd": q.name}

        return self._call(op)

    def rm(self, jid: int) -> dict:
        """condor_rm: drop the job wherever it is — a running job's claim
        is released on its worker, an idle one just leaves the queue."""

        def op(sim):
            for q in sim.queues:
                job = q._jobs.get(jid)
                if job is None:
                    continue
                if job.claimed_by is not None:
                    w = sim.collector.workers.get(job.claimed_by)
                    if w is not None:
                        w.drop_claim(jid)
                q.remove(jid, sim.now)
                self._remember(jid, "removed", sim.now)
                return {"jid": jid, "removed": True, "schedd": q.name}
            return {"jid": jid, "removed": False,
                    "terminal": self._terminal.get(int(jid))}

        return self._call(op)

    # -- observation ---------------------------------------------------------
    def status(self) -> dict:
        def op(sim):
            schedds = {
                q.name: {
                    "idle": q.n_idle(),
                    "running": q.n_running(),
                    "completed": self.completed[q.name].n,
                    "draining": bool(getattr(q, "draining", False)),
                }
                for q in sim.queues
            }
            drained = (sim.drained() and sim._external_pending == 0
                       and not self._pending)
            return {
                "t": sim.now,
                "drained": drained,
                "pending_ops": len(self._pending),
                "schedds": schedds,
                "completed": sum(cs.n for cs in self.completed.values()),
                "backends": [self._backend_health(b)
                             for b in sim.backends],
                "detached_backends": [b.name
                                      for b in sim.detached_backends],
                "driver": {"running": self.driver.running,
                           "speed": self.driver.speed},
            }

        return self._call(op)

    @staticmethod
    def _backend_health(b) -> dict:
        health = getattr(b, "health", None)
        return health() if health is not None else {"name": b.name}

    def job_status(self, jid: int) -> dict:
        def op(sim):
            for q in sim.queues:
                job = q._jobs.get(jid)
                if job is not None:
                    return {"jid": jid, "state": job.state.value,
                            "schedd": q.name,
                            "claimed_by": job.claimed_by}
            rec = self._terminal.get(int(jid))
            if rec is not None:
                return {"jid": jid, **rec}
            return {"jid": jid, "state": "unknown"}

        return self._call(op)

    def metrics(self) -> dict:
        """Live gauges + per-backend cost/waste attribution + per-user
        fair-share (EUP) + the downsampled Fig 2/3-style series — the
        /metrics JSON document."""

        def op(sim):
            now = sim.now
            sim._flush_accounting()
            every = sim.backends + sim.detached_backends
            out: dict[str, Any] = {
                "t": now,
                "gauges": {
                    "idle_jobs": sim.pool_queue.n_idle(),
                    "running_jobs": sim.pool_queue.n_running(),
                    "completed_jobs": sum(cs.n
                                          for cs in self.completed.values()),
                    "pending_pods": len(sim.cluster_view.pending_pods()),
                    "running_pods": len(sim.cluster_view.running_pods()),
                    "ready_workers": len(sim.collector.alive_workers(now)),
                    "provisioned_cores": sum(
                        n.capacity.get("cpu", 0)
                        for b in sim.backends
                        for n in b.cluster.nodes.values()),
                    "cost_rate": sum(b.cost_rate() for b in sim.backends),
                    "cost_total": sum(b.stats.cost_total for b in every),
                },
                "backends": summarize_backends(every),
                "series": timeline(sim.recorder, SERIES_KEYS,
                                   max_points=200),
            }
            if sim.accountant is not None:
                out["fairshare"] = sim.accountant.snapshot(now)
            return out

        return self._call(op)

    def metrics_prom(self) -> str:
        """Prometheus text exposition (format 0.0.4) — the /metrics.prom
        body.  Collect hooks read the live pool at a quiescent instant."""
        return self._call(lambda sim: sim.prometheus_text())

    def trace(self) -> dict:
        """Chrome trace-event JSON document (the /trace body).  Raises
        ValueError when the pool was built with telemetry=False."""
        return self._call(lambda sim: sim.telemetry.chrome_trace())

    def summary(self) -> dict:
        return self._call(lambda sim: sim.summary())

    def completed_stats(self) -> CompletedStats:
        """Pool-wide completion aggregate (merged across schedds)."""
        def op(sim):
            total = CompletedStats()
            for cs in self.completed.values():
                total.merge(cs)
            return total

        return self._call(op)

    # -- reconfiguration -----------------------------------------------------
    def drain_backend(self, name: str, *, at: float | None = None) -> dict:
        def op(sim):
            if at is not None and at > sim.now:
                self._schedule_op(float(at), "drain_backend",
                                  {"name": name})
                return {"backend": name, "drain_at": float(at)}
            sim.drain_backend(name)
            return {"backend": name, "draining": True, "t": sim.now}

        return self._call(op)

    def add_backend(self, ini: str) -> dict:
        """Attach the backend(s) declared by `[backend:<name>]` sections
        of an INI snippet.  The snippet is remembered so resume() can
        re-create the backend before restoring its state."""

        def op(sim):
            names = self._add_backends_from_ini(ini)
            self._added_backend_ini.append(ini)
            return {"added": names, "t": sim.now}

        return self._call(op)

    def _add_backends_from_ini(self, ini: str) -> list[str]:
        cfg = load_ini(ini)
        if not cfg.backends:
            raise ValueError("no [backend:<name>] sections in snippet")
        names = []
        for b in build_backends(cfg):
            self.sim.add_backend(b)
            names.append(b.name)
        return names

    def add_schedd(self, name: str, *, quota: float = 1.0) -> dict:
        def op(sim):
            sim.add_schedd(name, quota=quota)
            self._wire_queues()
            return {"schedd": name, "quota": quota, "t": sim.now}

        return self._call(op)

    def drain_schedd(self, name: str, *, at: float | None = None) -> dict:
        def op(sim):
            if at is not None and at > sim.now:
                self._schedule_op(float(at), "drain_schedd",
                                  {"name": name})
                return {"schedd": name, "drain_at": float(at)}
            sim.drain_schedd(name)
            return {"schedd": name, "draining": True, "t": sim.now}

        return self._call(op)

    def detach_schedd(self, name: str) -> dict:
        def op(sim):
            sim.detach_schedd(name)
            return {"schedd": name, "detached": True, "t": sim.now}

        return self._call(op)

    # -- lifecycle -----------------------------------------------------------
    def start(self, *, speed: float | None = "unchanged"):
        if speed != "unchanged":
            self.driver.speed = speed
        self.driver.start()

    def stop(self):
        if self.driver.running:
            self.driver.stop()

    def run_until_drained(self, max_t: float = 1e6):
        """As-fast batch drive (driver must not be running) — the same
        semantics as `Simulation.run_until_drained`, ledger included
        (pending ops count as external events)."""
        if self.driver.running:
            raise RuntimeError("stop the driver before batch-driving")
        self.sim.run_until_drained(max_t)

    # -- snapshot / resume ---------------------------------------------------
    def snapshot(self) -> dict:
        """Full-state snapshot: the simulation's state_dict wrapped with
        the service-level state (config, completion aggregates, terminal
        index, pending-operation ledger, runtime-added backend INIs)."""

        def op(sim):
            return {
                "service": {
                    "version": 1,
                    "config": dict(self._config),
                    "added_backend_ini": list(self._added_backend_ini),
                    "pending": [{"seq": seq, **entry}
                                for seq, entry
                                in sorted(self._pending.items())],
                    "completed": {n: cs.state_dict()
                                  for n, cs in self.completed.items()},
                    "terminal": [[jid, rec]
                                 for jid, rec in self._terminal.items()],
                },
                "sim": sim.state_dict(allow_pending_external=True),
            }

        return self._call(op)

    def save_snapshot(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f)
        return {"path": path, "t": snap["sim"]["t"]}

    @classmethod
    def resume(cls, state: dict | str, *,
               speed: float | None = "unchanged") -> "PoolService":
        """Rebuild a service from a snapshot (dict or file path) such
        that it continues exactly where the uninterrupted run would be.
        The driver is NOT started — call start() when ready."""
        if isinstance(state, str):
            with open(state) as f:
                state = json.load(f)
        svc_state = state["service"]
        c = dict(svc_state["config"])
        if speed != "unchanged":
            c["speed"] = speed
        svc = cls(c["ini"], schedds=c["schedds"],
                  fairshare=c["fairshare"], tick_s=c["tick_s"],
                  negotiate_interval_s=c["negotiate_interval_s"],
                  metrics_interval_s=c["metrics_interval_s"],
                  seed=c["seed"], speed=c["speed"],
                  telemetry=c.get("telemetry", True))
        # runtime-added backends must exist before restore() can load
        # their state (and possibly re-detach them)
        for ini in svc_state["added_backend_ini"]:
            svc._add_backends_from_ini(ini)
            svc._added_backend_ini.append(ini)
        svc.sim.restore(state["sim"])
        svc._wire_queues()           # wire schedds added at runtime
        for name, cs_state in svc_state["completed"].items():
            if name not in svc.completed:
                raise ValueError(f"snapshot has stats for unknown "
                                 f"schedd {name!r}")
            svc.completed[name].load_state(cs_state)
        svc._terminal = OrderedDict(
            (int(jid), rec) for jid, rec in svc_state["terminal"])
        pending = svc_state["pending"]
        for entry in pending:        # seq order == original schedule order
            svc._schedule_op(entry["at"], entry["kind"], entry["payload"],
                             seq=int(entry["seq"]))
        next_seq = (max(int(e["seq"]) for e in pending) + 1
                    if pending else 0)
        svc._seq = itertools.count(next_seq)
        return svc


class PoolClient:
    """In-process client: the same verbs the HTTP surface exposes, bound
    directly to a PoolService (each call still goes through the driver's
    quiescent injection point, so it is safe from any thread)."""

    def __init__(self, service: PoolService):
        self.service = service

    def submit(self, records, **kw) -> dict:
        return self.service.submit(records, **kw)

    def status(self) -> dict:
        return self.service.status()

    def job_status(self, jid: int) -> dict:
        return self.service.job_status(jid)

    def rm(self, jid: int) -> dict:
        return self.service.rm(jid)

    def metrics(self) -> dict:
        return self.service.metrics()

    def metrics_prom(self) -> str:
        return self.service.metrics_prom()

    def trace(self) -> dict:
        return self.service.trace()

    def snapshot(self) -> dict:
        return self.service.snapshot()

    def drain_backend(self, name: str, **kw) -> dict:
        return self.service.drain_backend(name, **kw)

    def add_backend(self, ini: str) -> dict:
        return self.service.add_backend(ini)

    def add_schedd(self, name: str, **kw) -> dict:
        return self.service.add_schedd(name, **kw)

    def drain_schedd(self, name: str, **kw) -> dict:
        return self.service.drain_schedd(name, **kw)


class RemoteClient:
    """urllib client for a served pool — the CLI's transport.  Mirrors
    PoolClient's surface; every method returns the decoded JSON body."""

    def __init__(self, url: str, timeout: float = 30.0):
        self.url = url.rstrip("/")
        self.timeout = timeout

    def _get(self, path: str) -> dict:
        with urllib.request.urlopen(self.url + path,
                                    timeout=self.timeout) as r:
            return json.loads(r.read().decode())

    def _get_text(self, path: str) -> str:
        with urllib.request.urlopen(self.url + path,
                                    timeout=self.timeout) as r:
            return r.read().decode()

    def _post(self, path: str, body: dict) -> dict:
        req = urllib.request.Request(
            self.url + path, data=json.dumps(body).encode(),
            headers={"Content-Type": "application/json"}, method="POST")
        with urllib.request.urlopen(req, timeout=self.timeout) as r:
            return json.loads(r.read().decode())

    def healthz(self) -> dict:
        return self._get("/healthz")

    def status(self) -> dict:
        return self._get("/status")

    def metrics(self) -> dict:
        return self._get("/metrics")

    def metrics_prom(self) -> str:
        return self._get_text("/metrics.prom")

    def trace(self) -> dict:
        return self._get("/trace")

    def job_status(self, jid: int) -> dict:
        return self._get(f"/job?jid={int(jid)}")

    def submit(self, records, *, schedd=None, at_trace_times=False,
               at=None) -> dict:
        recs = [r.to_obj() if isinstance(r, TraceRecord) else r
                for r in records]
        body = {"records": recs, "at_trace_times": at_trace_times}
        if schedd is not None:
            body["schedd"] = schedd
        if at is not None:
            body["at"] = at
        return self._post("/submit", body)

    def rm(self, jid: int) -> dict:
        return self._post("/rm", {"jid": int(jid)})

    def snapshot(self, path: str | None = None) -> dict:
        return self._post("/snapshot", {"path": path} if path else {})

    def drain_backend(self, name: str, at: float | None = None) -> dict:
        body: dict[str, Any] = {"name": name}
        if at is not None:
            body["at"] = at
        return self._post("/drain-backend", body)

    def add_backend(self, ini: str) -> dict:
        return self._post("/add-backend", {"ini": ini})

    def add_schedd(self, name: str, quota: float = 1.0) -> dict:
        return self._post("/add-schedd", {"name": name, "quota": quota})

    def drain_schedd(self, name: str, at: float | None = None) -> dict:
        body: dict[str, Any] = {"name": name}
        if at is not None:
            body["at"] = at
        return self._post("/drain-schedd", body)

    def start(self, speed: float | None = None) -> dict:
        return self._post("/start", {"speed": speed})

    def shutdown(self) -> dict:
        return self._post("/shutdown", {})
