"""Stdlib-only JSON HTTP surface for a PoolService.

ThreadingHTTPServer + BaseHTTPRequestHandler — no third-party web
framework.  Handler threads are safe because every service verb funnels
through the wall-clock driver's quiescent injection point; the HTTP
layer is a thin JSON codec over PoolService.

  GET  /healthz        liveness + current sim time
  GET  /status         queue depths, backends, driver state
  GET  /metrics        gauges + per-backend cost/waste + EUP + series
  GET  /metrics.prom   Prometheus text exposition (text/plain; 0.0.4)
  GET  /trace          Chrome trace-event JSON (telemetry must be on)
  GET  /job?jid=N      one job's state (live or terminal index)
  POST /submit         {"records": [...], "schedd"?, "at_trace_times"?,
                        "at"?} -> jids / scheduled count
  POST /rm             {"jid": N}
  POST /snapshot       {"path"?} -> save to path, or return the full
                        snapshot document inline
  POST /drain-backend  {"name", "at"?}
  POST /add-backend    {"ini": "[backend:x]\\n..."}
  POST /add-schedd     {"name", "quota"?}
  POST /drain-schedd   {"name", "at"?}
  POST /start          {"speed"?}   start the wall-clock driver
  POST /stop           {}           pause it (quiescent)
  POST /shutdown       {}           stop driver and HTTP server

Errors map to 400 (bad request / ValueError / KeyError) or 404 (unknown
route) with a JSON {"error": ...} body.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from repro.service.pool import PoolService


class _Handler(BaseHTTPRequestHandler):
    # the server instance carries .service (see serve())
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):     # quiet; the CLI prints its own
        pass

    @property
    def service(self) -> PoolService:
        return self.server.service  # type: ignore[attr-defined]

    def _send(self, code: int, obj) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, code: int, text: str,
                   content_type: str = "text/plain; charset=utf-8") -> None:
        body = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        if n == 0:
            return {}
        return json.loads(self.rfile.read(n).decode() or "{}")

    def _route(self, handler) -> None:
        try:
            self._send(200, handler())
        except (ValueError, KeyError, TypeError) as e:
            self._send(400, {"error": f"{type(e).__name__}: {e}"})

    # -- GET -----------------------------------------------------------------
    def do_GET(self):
        url = urlparse(self.path)
        svc = self.service
        if url.path == "/healthz":
            self._route(lambda: {"ok": True,
                                 "t": svc.status()["t"]})
        elif url.path == "/status":
            self._route(svc.status)
        elif url.path == "/metrics":
            self._route(svc.metrics)
        elif url.path == "/metrics.prom":
            try:
                self._send_text(
                    200, svc.metrics_prom(),
                    "text/plain; version=0.0.4; charset=utf-8")
            except (ValueError, KeyError, TypeError) as e:
                self._send(400, {"error": f"{type(e).__name__}: {e}"})
        elif url.path == "/trace":
            self._route(svc.trace)
        elif url.path == "/job":
            q = parse_qs(url.query)
            self._route(lambda: svc.job_status(int(q["jid"][0])))
        else:
            self._send(404, {"error": f"no route {url.path!r}"})

    # -- POST ----------------------------------------------------------------
    def do_POST(self):
        url = urlparse(self.path)
        svc = self.service
        try:
            body = self._body()
        except json.JSONDecodeError as e:
            self._send(400, {"error": f"bad JSON body: {e}"})
            return
        if url.path == "/submit":
            self._route(lambda: svc.submit(
                body.get("records") or [],
                schedd=body.get("schedd"),
                at_trace_times=bool(body.get("at_trace_times", False)),
                at=body.get("at")))
        elif url.path == "/rm":
            self._route(lambda: svc.rm(int(body["jid"])))
        elif url.path == "/snapshot":
            path = body.get("path")
            self._route((lambda: svc.save_snapshot(path)) if path
                        else svc.snapshot)
        elif url.path == "/drain-backend":
            self._route(lambda: svc.drain_backend(
                body["name"], at=body.get("at")))
        elif url.path == "/add-backend":
            self._route(lambda: svc.add_backend(body["ini"]))
        elif url.path == "/add-schedd":
            self._route(lambda: svc.add_schedd(
                body["name"], quota=float(body.get("quota", 1.0))))
        elif url.path == "/drain-schedd":
            self._route(lambda: svc.drain_schedd(
                body["name"], at=body.get("at")))
        elif url.path == "/start":
            def start():
                speed = body.get("speed", "unchanged")
                svc.start(speed=speed)
                return {"running": True, "speed": svc.driver.speed}
            self._route(start)
        elif url.path == "/stop":
            def stop():
                svc.stop()
                return {"running": False}
            self._route(stop)
        elif url.path == "/shutdown":
            def shutdown():
                svc.stop()
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
                return {"ok": True}
            self._route(shutdown)
        else:
            self._send(404, {"error": f"no route {url.path!r}"})


def serve(service: PoolService, host: str = "127.0.0.1",
          port: int = 0) -> ThreadingHTTPServer:
    """Bind the service on (host, port); port 0 picks an ephemeral one
    (read it back from ``server.server_address``).  Call
    ``server.serve_forever()`` — or run it on a thread via
    `serve_in_thread` — and POST /shutdown (or server.shutdown()) to
    stop."""
    server = ThreadingHTTPServer((host, port), _Handler)
    server.daemon_threads = True
    server.service = service  # type: ignore[attr-defined]
    return server


def serve_in_thread(service: PoolService, host: str = "127.0.0.1",
                    port: int = 0):
    """Start the HTTP server on a daemon thread; returns
    (server, base_url)."""
    server = serve(service, host, port)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    addr, bound_port = server.server_address[:2]
    return server, f"http://{addr}:{bound_port}"
