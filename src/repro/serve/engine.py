"""Serving engine: batched prefill/decode over the sharded model.

``make_prefill_step`` / ``make_decode_step`` build the pjit-ready pure
functions the dry-run lowers (decode_32k / long_500k cells lower
``serve_step`` = one decode token against a seq_len KV cache, per the
assignment).  ``ServeEngine`` is the host-side loop used by the examples
and by the provisioner's serve workers: it batches queued requests,
prefills them into free cache rows, decodes round-robin, and reports queue
depth — the demand signal the provisioner scales on (paper §2: "jobs
waiting for resources").

Continuous batching, engine-style: each cache row is a slot; finished
sequences free their slot immediately and the next queued request is
prefilled into it while other rows keep decoding.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.parallel.sharding import ShardingRules, constrainer

PyTree = Any


def make_prefill_step(cfg: ModelConfig, mesh, rules: ShardingRules):
    constrain = constrainer(rules, mesh)

    def prefill_step(params, batch, cache):
        return model_lib.prefill(
            params, cfg, batch, cache, mesh=mesh, constrain=constrain
        )

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh, rules: ShardingRules):
    constrain = constrainer(rules, mesh)

    def decode_step(params, tokens_t, cache, lengths):
        return model_lib.decode_step(
            params, cfg, tokens_t, cache, lengths, mesh=mesh,
            constrain=constrain,
        )

    return decode_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # int32 (len,)
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    # filled on completion
    output: list | None = None
    finished_at: float = 0.0


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class ServeEngine:
    """Host loop: queue -> slots -> prefill/decode. Single-process; the
    multi-worker serve path shards the *batch rows* of one engine across
    the provisioned worker group's mesh."""

    def __init__(self, cfg: ModelConfig, params: PyTree, *,
                 batch_slots: int = 4, max_seq: int = 256, mesh=None,
                 rules: ShardingRules | None = None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        mesh = mesh if mesh is not None else jax.sharding.Mesh(
            np.array(jax.devices()[:1]), ("data",)
        )
        from repro.parallel.sharding import rules_for
        rules = rules or rules_for(cfg, "decode")
        self._prefill_one = jax.jit(make_prefill_step(cfg, mesh, rules))
        self._decode = jax.jit(make_decode_step(cfg, mesh, rules))
        self.cache = model_lib.init_cache(cfg, batch_slots, max_seq)
        self.lengths = jnp.zeros((batch_slots,), jnp.int32)
        self.last_tok = jnp.zeros((batch_slots, 1), jnp.int32)
        self._reqs: dict[int, Request] = {}

    # -- demand signal (paper §2) -----------------------------------------
    def queue_depth(self) -> int:
        return len(self.queue)

    def busy_slots(self) -> int:
        return sum(1 for s in self.slots if s.rid >= 0)

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    # -- engine tick --------------------------------------------------------
    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.rid >= 0 or not self.queue:
                continue
            req = self.queue.popleft()
            self._reqs[req.rid] = req
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            # per-row prefill: run a batch-1 prefill into a fresh cache and
            # splice the row in (host-side; fine at example scale)
            row_cache = model_lib.init_cache(self.cfg, 1, self.max_seq)
            logits, row_cache, row_len = self._prefill_one(
                self.params, {"tokens": prompt}, row_cache
            )
            self.cache = jax.tree_util.tree_map(
                lambda full, row: full.at[:, i:i + 1].set(row), self.cache,
                row_cache,
            )
            self.lengths = self.lengths.at[i].set(row_len[0])
            nxt = jnp.argmax(logits[0]).astype(jnp.int32)
            self.last_tok = self.last_tok.at[i, 0].set(nxt)
            slot.rid = req.rid
            slot.remaining = req.max_new_tokens - 1
            slot.tokens = [int(nxt)]

    def _retire(self):
        for slot in self.slots:
            if slot.rid >= 0 and slot.remaining <= 0:
                req = self._reqs.pop(slot.rid)
                req.output = list(slot.tokens)
                req.finished_at = time.time()
                self.done[req.rid] = req
                slot.rid = -1
                slot.tokens = []

    def step(self) -> int:
        """One engine tick. Returns number of active slots."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.rid >= 0]
        if active:
            logits, self.cache, self.lengths = self._decode(
                self.params, self.last_tok, self.cache, self.lengths
            )
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self.last_tok = nxt[:, None]
            for i in active:
                slot = self.slots[i]
                slot.tokens.append(int(nxt[i]))
                slot.remaining -= 1
        self._retire()
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> int:
        ticks = 0
        while (self.queue or self.busy_slots()) and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks
