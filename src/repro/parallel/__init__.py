from repro.parallel.sharding import (
    ShardingRules,
    rules_for,
    logical_to_spec,
    spec_tree,
    named_sharding_tree,
    constrainer,
)
