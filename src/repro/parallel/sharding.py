"""Logical-axis → mesh-axis sharding rules.

Model code annotates tensors with *logical* axis names (see models/layers.py
for the vocabulary); this module maps them onto the physical mesh axes
("pod", "data", "model") and materializes PartitionSpec / NamedSharding
trees for pjit.

Rule presets per (arch family, workload):

  base        — megatron-style TP: heads/mlp/vocab → "model", batch →
                ("pod","data"); weights otherwise replicated.
  fsdp        — base + embed → "data": every weight matrix has exactly one
                axis on "model" and its d_model axis on "data", so weight
                state is fully sharded over the whole mesh (needed for ≥8B
                dense archs and all optimizer states).
  ep          — MoE: expert axis → "data" (expert parallelism; the a2a path
                in models/moe.py matches), mlp → "model", embed → "data"
                (FSDP for the dense trunk).
  ssm         — ssm/heads axes → "model", embed → "data" (FSDP).
  decode      — inference: KV/state batch stays on ("pod","data"); weights
                as base/fsdp but *embed never sharded* (no FSDP gather per
                step); long-context adds seq → "data" sequence parallelism.

Activation logical axes (constrainer): batch → ("pod","data"),
heads_act/kv_act/mlp_act/ssm_heads → "model", seq → None (or "data" in
sequence-parallel sections), embed → None.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# mesh axes that shard the batch (data parallel), in nesting order
BATCH_AXES = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping: logical axis name -> mesh axis (str | tuple | None)."""

    rules: dict[str, Any]
    name: str = "custom"

    def mesh_axes(self, logical: str | None, mesh: Mesh):
        if logical is None:
            return None
        ax = self.rules.get(logical, None)
        if ax is None:
            return None
        if isinstance(ax, tuple):
            present = tuple(a for a in ax if a in mesh.shape)
            return present if present else None
        return ax if ax in mesh.shape else None


def _weight_rules(
    *, fsdp: bool, expert_axis: str | None = None
) -> dict[str, Any]:
    r: dict[str, Any] = {
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "ssm": "model",
        "embed": "data" if fsdp else None,
        "expert": expert_axis,
        "conv": None,
        "layers": None,
        # activations
        "batch": BATCH_AXES,
        "batch_logits": BATCH_AXES,   # batch axes for the CE logits
        "seq": None,
        "heads_act": "model",
        "kv_act": "model",
        "mlp_act": "model",
        "ssm_heads": "model",
        "vocab_act": "model",
    }
    return r


_PRESETS: dict[str, ShardingRules] = {
    "base": ShardingRules(_weight_rules(fsdp=False), "base"),
    "fsdp": ShardingRules(_weight_rules(fsdp=True), "fsdp"),
    "ep": ShardingRules(_weight_rules(fsdp=True, expert_axis="data"), "ep"),
    "decode": ShardingRules(_weight_rules(fsdp=False), "decode"),
    # long-context decode: cache/activation seq over "data" (sequence-
    # parallel), weights like the EP/FSDP preset — experts MUST stay
    # sharded or a 400B MoE's weights blow the per-chip HBM at B=1
    "decode_sp": ShardingRules(
        {**_weight_rules(fsdp=True, expert_axis="data"),
         "seq": "data", "kv_seq": "data"},
        "decode_sp",
    ),
    # beyond-paper perf preset (§Perf): ZeRO-3 — batch data-parallel over
    # the WHOLE mesh, weights/optimizer fully sharded (embed→data,
    # ff/heads→model), activations unconstrained.  Replaces per-layer TP
    # activation all-reduces (O(B·S·d) per layer) with per-layer weight
    # all-gathers (O(params/chips)) — a large win whenever
    # B_loc·S·d  >  layer_params/chips.
    "zero3": ShardingRules(
        {**_weight_rules(fsdp=True),
         "batch": ("pod", "data", "model"),
         "heads_act": None, "kv_act": None, "mlp_act": None,
         "ssm_heads": None},
        "zero3",
    ),
    # zero3 for MoE: experts stay on "data" (EP all-to-all within the data
    # ring), dense trunk/batch as zero3
    "zero3_ep": ShardingRules(
        {**_weight_rules(fsdp=True, expert_axis="data"),
         "batch": ("pod", "data", "model"),
         "heads_act": None, "kv_act": None, "mlp_act": None,
         "ssm_heads": None},
        "zero3_ep",
    ),
}


def preset(name: str) -> ShardingRules:
    return _PRESETS[name]


def rules_for(cfg, workload: str) -> ShardingRules:
    """Pick the rule preset for (model config, workload).

    workload: "train" | "prefill" | "decode" | "decode_long"

    Training default is the §Perf-winning zero3 preset for attention-based
    non-MoE archs (measured 3–13× lower collective term than TP/FSDP at
    train_4k shapes — see EXPERIMENTS.md §Perf).  MoE keeps the EP preset
    (the expert all-to-all wants tokens resident on the "data" ring), and
    SSM stacks keep TP (zero3 measured 4× WORSE there: the SSD state
    einsums reshard pathologically under full-mesh batch sharding).  The
    paper-era baselines remain available as presets ("base"/"fsdp").
    """
    if workload == "train":
        if cfg.moe is not None:
            return _PRESETS["ep"]
        if cfg.family in ("ssm", "hybrid"):
            if cfg.param_count_estimate() >= 4_000_000_000:
                return _PRESETS["fsdp"]
            return _PRESETS["base"]
        return _PRESETS["zero3"]
    if workload in ("decode", "prefill"):
        if cfg.moe is not None:
            return _PRESETS["ep"]
        return _PRESETS["decode"]
    if workload == "decode_long":
        return _PRESETS["decode_sp"]
    raise ValueError(f"unknown workload {workload}")


def logical_to_spec(
    axes: tuple[str | None, ...], rules: ShardingRules, mesh: Mesh
) -> P:
    parts = []
    used: set[str] = set()
    for lg in axes:
        ax = rules.mesh_axes(lg, mesh)
        # a mesh axis may appear at most once in a spec
        if ax is not None:
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in flat):
                ax = None
            else:
                used.update(flat)
        parts.append(ax)
    return P(*parts)


def spec_tree(axes_tree: PyTree, rules: ShardingRules, mesh: Mesh) -> PyTree:
    """Map a tree of logical-axes tuples to a tree of PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: logical_to_spec(axes, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def named_sharding_tree(axes_tree: PyTree, rules: ShardingRules,
                        mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        spec_tree(axes_tree, rules, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def spec_for(shape: tuple[int, ...], axes: tuple[str | None, ...],
             rules: ShardingRules, mesh: Mesh) -> P:
    """Shape-aware spec: drops axes whose dim is not divisible by the mesh
    axis product (pjit in_shardings require exact divisibility)."""
    spec = logical_to_spec(axes, rules, mesh)
    parts = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            parts.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        parts.append(ax if dim % size == 0 else None)
    return P(*parts)


def param_sharding_tree(param_tree: PyTree, rules: ShardingRules,
                        mesh: Mesh) -> PyTree:
    """NamedSharding tree from a tree of Param leaves (shape-aware)."""
    from repro.models.param import is_param

    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, spec_for(p.shape, p.axes, rules, mesh)),
        param_tree,
        is_leaf=is_param,
    )


def constrainer(rules: ShardingRules, mesh: Mesh):
    """Returns constrain(x, logical_axes) for in-graph activation hints.

    An axis constraint is dropped when the dim is not divisible by the
    mesh-axis product — forcing GSPMD to shard 12 heads 16 ways triggers
    "involuntary full rematerialization" (replicate + re-partition copies),
    which is strictly worse than leaving the dim to sharding propagation.
    """

    def constrain(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
        if mesh.empty:
            return x
        spec = logical_to_spec(axes, rules, mesh)
        parts = []
        dropped: list[str] = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                parts.append(None)
                continue
            axs = list(ax) if isinstance(ax, tuple) else [ax]
            # tuple-prefix fallback: a 256-row batch on a 512-chip mesh
            # still shards over the ("pod","data") prefix
            while axs:
                size = 1
                for a in axs:
                    size *= mesh.shape[a]
                if dim % size == 0:
                    break
                dropped.append(axs.pop())
            parts.append(tuple(axs) if len(axs) > 1 else
                         (axs[0] if axs else None))
        # Sequence-parallel fallback: when a heads axis cannot shard (e.g.
        # 40 heads on model=16), GSPMD would otherwise REPLICATE the whole
        # attention computation across that mesh axis — give the freed
        # axis to the seq dim instead (context parallelism).
        for ax in dropped:
            for i, lg in enumerate(axes):
                if (lg == "seq" and parts[i] is None
                        and x.shape[i] % mesh.shape[ax] == 0):
                    parts[i] = ax
                    break
        if all(p is None for p in parts):
            # a fully-replicated constraint is a no-op at best and crashes
            # the partitioner inside partial-manual shard_map regions
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*parts))
        )

    return constrain


def batch_spec(mesh: Mesh, *extra: str | None) -> P:
    """PartitionSpec for (batch, *extra) arrays: batch over ("pod","data")."""
    present = tuple(a for a in BATCH_AXES if a in mesh.shape)
    return P(present if present else None, *extra)
