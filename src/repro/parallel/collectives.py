"""Hand-scheduled collectives (shard_map) for the distributed optimizer.

compressed_psum_tree — int8 gradient all-reduce with stochastic rounding:
  each DP replica quantizes its local gradient shard to int8 against a
  per-tensor fp32 scale (amax / 127), all-reduces the int8 payload (4x
  fewer bytes on the wire than fp32, 2x fewer than bf16), and dequantizes.
  Stochastic rounding makes the quantizer unbiased, so the *mean* gradient
  over N replicas converges to the true mean (variance ~ scale²/12/N).
  The scale itself is psum-maxed first (one tiny fp32 collective) so all
  replicas share a common codebook — required for the int32 accumulation
  to be exact.

  This is gated per-config (`grad_compression: int8`) and targets the
  cross-pod DCN hop where link bandwidth, not FLOPs, dominates the roofline
  collective term.

bucketed_psum — flatten a pytree into fixed-size fp32 buckets and psum
  bucket-by-bucket: gives XLA visibility to overlap the first buckets'
  all-reduce with the tail of the backward pass (latency hiding), and is
  the unit at which compression is applied.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def _stochastic_round_int8(x: jax.Array, scale: jax.Array,
                           key: jax.Array) -> jax.Array:
    """Unbiased int8 quantization: floor(x/s + u), u ~ U[0,1)."""
    y = x.astype(jnp.float32) / jnp.maximum(scale, 1e-30)
    u = jax.random.uniform(key, x.shape, jnp.float32)
    q = jnp.floor(y + u)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def compressed_psum(
    g: jax.Array,
    axis_names: tuple[str, ...],
    key: jax.Array,
) -> jax.Array:
    """int8-compressed mean over `axis_names` (inside shard_map)."""
    n = 1
    for ax in axis_names:
        n *= jax.lax.axis_size(ax)
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    amax = jax.lax.pmax(amax, axis_names)           # shared codebook
    scale = amax / 127.0
    q = _stochastic_round_int8(g, scale, key)
    # int8 payload on the wire; accumulate exactly in int32
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype)


def compressed_psum_tree(
    grads: PyTree,
    mesh: Mesh,
    spec_tree: PyTree,
    *,
    axis_names: tuple[str, ...] = ("pod",),
    seed: jax.Array | None = None,
) -> PyTree:
    """Mean-reduce every leaf over `axis_names` with int8 compression.

    Leaves stay sharded per `spec_tree` on the remaining axes; only the
    reduction axes' values are exchanged.  Used for the cross-pod gradient
    sync where jnp-level psum would ship bf16.
    """
    present = tuple(a for a in axis_names if a in mesh.shape)
    if not present:
        return grads
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    specs, _ = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    if seed is None:
        seed = jnp.zeros((), jnp.uint32)

    out_leaves = []
    for i, (leaf, spec) in enumerate(zip(leaves, specs)):
        def body(g, *, _i=i):
            key = jax.random.fold_in(
                jax.random.PRNGKey(0), jnp.uint32(_i) + seed
            )
            return compressed_psum(g, present, key)

        # run per-leaf so each keeps its own sharding spec
        out_leaves.append(
            jax.shard_map(
                body, mesh=mesh, in_specs=(spec,), out_specs=spec,
                check_vma=False,
            )(leaf)
        )
    return jax.tree_util.tree_unflatten(treedef, out_leaves)


def psum_scalar(x: jax.Array, mesh: Mesh) -> jax.Array:
    """Mean of a replicated scalar over the whole mesh (metrics)."""
    return x  # replicated scalars are already global under pjit


def reduce_scatter_matmul_hint(x: jax.Array) -> jax.Array:
    """Marker for XLA latency-hiding scheduler (no-op at jnp level): the
    dry-run perf pass flips `--xla_tpu_enable_async_collective_fusion`
    flags instead; kept for API stability."""
    return x
