"""Deterministic, sharded synthetic token pipeline.

Produces (tokens, labels) batches from a counter-based PRNG: batch `i` is a
pure function of (seed, i), so any worker — including one that just
restarted after preemption — regenerates exactly the byte-identical batch
stream from the checkpointed step counter.  That property is what makes the
provisioner's kill-and-restart fault model exact: no data loss, no data
reorder (EXPERIMENTS.md preemption benches rely on it).

The "text" is a mixture of Zipf-ish unigram draws and short repeated
motifs, so the loss curve has learnable structure (repetition) instead of
uniform noise; enough for convergence smoke tests.

Sharding: ``global_batch`` rows are laid out so row r belongs to DP shard
``r // (global_batch // n_dp)``; each host materializes only its shard and
``jax.make_array_from_process_local_data`` (or plain device_put on a
single-process mesh) assembles the global array.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.parallel.sharding import batch_spec


@dataclasses.dataclass
class SyntheticTokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # fixed motif bank (shared across batches; part of the "dataset")
        self.motifs = rng.integers(
            0, self.vocab_size, size=(self.n_motifs, self.motif_len),
            dtype=np.int64,
        )
        # Zipf-ish unigram distribution over a capped head of the vocab
        head = min(self.vocab_size, 4096)
        w = 1.0 / np.arange(1, head + 1)
        self.head = head
        self.unigram = w / w.sum()

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Batch for global step `step` (pure function of seed+step)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )
        B, S = self.global_batch, self.seq_len
        toks = rng.choice(self.head, size=(B, S + 1), p=self.unigram)
        # overwrite random spans with motifs (learnable repetition)
        n_spans = max(1, S // (4 * self.motif_len))
        for b in range(B):
            for _ in range(n_spans):
                m = rng.integers(0, self.n_motifs)
                start = rng.integers(0, max(S + 1 - self.motif_len, 1))
                toks[b, start:start + self.motif_len] = self.motifs[m]
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def jax_batch_at(self, step: int, mesh=None) -> dict[str, jax.Array]:
        np_batch = self.batch_at(step)
        if mesh is None:
            return {k: jnp.asarray(v) for k, v in np_batch.items()}
        sharding = jax.sharding.NamedSharding(mesh, batch_spec(mesh, None))
        return {
            k: jax.device_put(v, sharding) for k, v in np_batch.items()
        }


def make_batch_specs(cfg: ModelConfig, mesh):
    """PartitionSpec tree for a training batch of this model family."""
    specs = {
        "tokens": batch_spec(mesh, None),
        "labels": batch_spec(mesh, None),
    }
    if cfg.encoder is not None:
        specs["frames"] = batch_spec(mesh, None, None)
    if cfg.frontend is not None:
        specs["patches"] = batch_spec(mesh, None, None)
    return specs


def stub_modality_inputs(cfg: ModelConfig, batch: int, rng_seed: int = 0):
    """Precomputed frame/patch embeddings for audio/VLM archs (the modality
    frontend is a stub per the assignment: input_specs provides these)."""
    rng = np.random.default_rng(rng_seed)
    out = {}
    if cfg.encoder is not None:
        out["frames"] = rng.standard_normal(
            (batch, cfg.encoder.n_frames, cfg.d_model)
        ).astype(np.float32)
    if cfg.frontend is not None:
        out["patches"] = rng.standard_normal(
            (batch, cfg.frontend.n_prefix, cfg.frontend.d_input)
        ).astype(np.float32)
    return out
