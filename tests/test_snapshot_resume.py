"""Snapshot/resume correctness: JSON round-trips, the
snapshot->restore->snapshot fixed point, the differential guarantee
(an interrupted run continues EXACTLY like the uninterrupted one), the
state_dict preconditions, and the service-level ledger resume."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (  # noqa: E402
    NodeTemplate, ProvisionerConfig, Simulation, gpu_job, onprem_nodes,
)
from repro.service import PoolClient, PoolService  # noqa: E402

CAP = {"cpu": 16, "gpu": 4, "memory": 64, "disk": 256}


def build(seed=3):
    """Flocking + fair-share + autoscaling sim — exercises every
    serialized subsystem (queues, accountant, workers, backends,
    provisioner, recorder, rng)."""
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    return Simulation(cfg, nodes=onprem_nodes(2, gpus=4, cpus=16),
                      node_template=NodeTemplate(capacity=dict(CAP)),
                      max_nodes=8, schedds=2, fairshare=True,
                      tick_s=5.0, negotiate_interval_s=15.0, seed=seed)


def seed_jobs(sim):
    for i in range(40):
        sim.submit_jobs(10.0 * i,
                        [gpu_job(300.0 + 20.0 * (i % 7),
                                 gpus=1 + (i % 2))],
                        schedd=i % 2)


def canon(obj) -> str:
    return json.dumps(obj, sort_keys=True, default=str)


# -- round trip + fixed point ------------------------------------------------

def test_state_dict_json_round_trips_and_is_fixed_point():
    sim = build()
    seed_jobs(sim)
    sim.run(400.0)
    state = json.loads(json.dumps(sim.state_dict()))
    sim2 = build()
    sim2.restore(state)
    state2 = json.loads(json.dumps(sim2.state_dict()))
    assert canon(state2) == canon(state)


# -- the differential guarantee ----------------------------------------------

def test_interrupted_run_matches_uninterrupted():
    ref = build()
    seed_jobs(ref)
    ref.run(400.0)
    cut = build()
    seed_jobs(cut)
    cut.run(400.0)
    state = json.loads(json.dumps(cut.state_dict()))

    resumed = build()       # fresh process: nothing shared with `cut`
    resumed.restore(state)

    ref.run_until_drained(20000.0)
    resumed.run_until_drained(20000.0)
    assert canon(resumed.summary()) == canon(ref.summary())
    assert resumed.recorder.series == ref.recorder.series
    assert resumed.now == ref.now


# -- preconditions -----------------------------------------------------------

def test_state_dict_requires_quiescence():
    sim = build()
    seed_jobs(sim)
    with pytest.raises(ValueError):
        sim.state_dict()    # fresh sim: the whole t=0 group is due
    sim.run(400.0)          # past the last seeded arrival (t=390)
    sim.state_dict()        # after run(): quiescent, fine


def test_state_dict_gates_pending_external_events():
    sim = build()
    sim.run(50.0)
    sim.at(500.0, lambda s, now: None)
    with pytest.raises(ValueError):
        sim.state_dict()
    sim.state_dict(allow_pending_external=True)


def test_state_dict_requires_event_engine():
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    sim = Simulation(cfg, nodes=onprem_nodes(2, gpus=4, cpus=16),
                     engine="tick", tick_s=5.0)
    with pytest.raises(ValueError):
        sim.state_dict()


def test_restore_requires_fresh_sim():
    sim = build()
    seed_jobs(sim)
    sim.run(400.0)
    state = sim.state_dict()
    with pytest.raises(ValueError):
        sim.restore(state)  # non-fresh target


def test_restore_refuses_flocking_mismatch():
    sim = build()
    seed_jobs(sim)
    sim.run(400.0)
    state = sim.state_dict()
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    plain = Simulation(cfg, nodes=onprem_nodes(2, gpus=4, cpus=16),
                       tick_s=5.0)
    with pytest.raises(ValueError):
        plain.restore(state)


# -- service-level resume (pending-op ledger) --------------------------------

SERVICE_INI = """\
[provision]
submit_interval_s=30
idle_timeout_s=240
startup_delay_s=15

[backend:onprem]
kind=static
nodes=2
capacity_dict=cpu:8,gpu:4,memory:64,disk:256

[backend:cloud]
kind=autoscale
capacity_dict=cpu:8,gpu:4,memory:64,disk:256
max_nodes=4
node_hourly_cost=1.0
provision_delay_s=30
scale_down_delay_s=120
"""

RECORDS = [{"arrival_s": 40.0 * i, "runtime_s": 300.0 + 10.0 * (i % 5),
            "cpus": 1 + i % 3, "user": f"user{i % 3:02d}"}
           for i in range(30)]


def mk_service():
    return PoolService(SERVICE_INI, tick_s=5.0,
                       negotiate_interval_s=15.0,
                       metrics_interval_s=60.0, speed=None)


def test_service_resume_with_pending_arrivals_matches_reference():
    ref = mk_service()
    PoolClient(ref).submit(RECORDS, at_trace_times=True, at=0.0)
    ref.run_until_drained()

    cut = mk_service()
    PoolClient(cut).submit(RECORDS, at_trace_times=True, at=0.0)
    cut.sim.run(400.0)      # mid-run: arrivals still in the ledger
    snap = json.loads(json.dumps(cut.snapshot()))
    assert any(e["kind"] == "submit" for e in snap["service"]["pending"])

    resumed = PoolService.resume(snap)
    resumed.run_until_drained()
    assert canon(resumed.summary()) == canon(ref.summary())
    assert (canon(resumed.completed_stats().state_dict())
            == canon(ref.completed_stats().state_dict()))
    assert resumed.status()["drained"]
