"""Preview observability pins (ISSUE 10 satellites 1+2).

  * `repro_matchmaker_jit_compiles_total` is labelled by entry path —
    the dedicated vmapped preview dispatch ("preview") compiles its own
    executable, separately from the negotiation-cycle jit ("cycle") —
    and `phase_totals()` exposes both the per-path split and the
    pre-label all-paths total;
  * `repro_preview_legacy_total` counts previews forced onto the legacy
    live-offer walk by quantity-reading expressions;
  * the legacy walk's documented error bound — over-count at most one
    cohort slice (`fits(live free)`) per worker, under-count never —
    pinned deterministically and on randomized threshold pools.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.classad import ClassAdExpr
from repro.core.jobqueue import Job, JobQueue
from repro.core.matchmaker import HAVE_JAX
from repro.core.worker import Collector, Worker

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")


def add_worker(col, name, ad, start="true", booted=0.0):
    w = Worker(name=name, ad=dict(ad), start_expr=ClassAdExpr(start),
               startup_delay=0.0)
    w.booted_at = booted
    col.advertise(w)
    return w


def n_claimed(q):
    return sum(1 for j in q.jobs() if j.claimed_by)


# -- satellite 1: path-labelled jit-compile counter ---------------------------

@needs_jax
def test_jit_compiles_labelled_by_entry_path():
    col = Collector(matchmaker="jax", telemetry=True)
    prof = col.profiler
    assert prof is not None
    for i in range(3):
        add_worker(col, f"w{i}", {"cpus": 8, "memory": 32})
    q = JobQueue()
    for i in range(20):
        q.submit(Job(ad={"request_cpus": 1 + i % 2, "request_memory": 2},
                     runtime_s=60), float(i))

    col.preview(q, 0.0)          # fresh preview bucket -> XLA trace
    by_path = prof.phase_totals()["jit_compiles_by_path"]
    assert by_path.get("preview", 0) >= 1
    n_preview = by_path.get("preview", 0)

    col.preview(q, 0.0)          # warm bucket: no new trace
    by_path = prof.phase_totals()["jit_compiles_by_path"]
    assert by_path.get("preview", 0) == n_preview

    col.run_cycle(q, 0.0)        # negotiation jit is a separate program
    totals = prof.phase_totals()
    by_path = totals["jit_compiles_by_path"]
    assert by_path.get("cycle", 0) >= 1
    # the pre-label surface stays the all-paths total
    assert totals["jit_compiles"] == sum(by_path.values())


# -- satellite 2: legacy-walk counter -----------------------------------------

def test_preview_legacy_counter_counts_quantity_forced_walks():
    col = Collector(matchmaker="numpy")
    add_worker(col, "w0", {"cpus": 8, "memory": 32})
    q = JobQueue()
    q.submit(Job(ad={"request_cpus": 1}, runtime_s=60), 0.0)
    assert col.preview_legacy == 0
    col.preview(q, 0.0)                      # quantity-blind: fast path
    assert col.preview_legacy == 0

    col2 = Collector(matchmaker="numpy")
    add_worker(col2, "w0", {"cpus": 8, "memory": 32}, start="cpus >= 2")
    col2.preview(q, 0.0)                     # START reads offered cpus
    assert col2.preview_legacy == 1
    # a batched candidate preview is still ONE forced walk
    col2.preview_candidates(q, 0.0, frees=[np.array([[8., 0, 32, 0, 0, 0]]),
                                           np.array([[4., 0, 32, 0, 0, 0]])])
    assert col2.preview_legacy == 2


# -- satellite 2: the documented error bound ----------------------------------

def quantity_pool(n_jobs=4):
    """The shrinking-offer classic: 'gpus >= 2' on a 4-GPU slot admits
    only 3 one-GPU claims live (4->3->2, then the offer of 1 fails
    START), but a dry run evaluating the FULL ad admits the whole
    cohort slice."""
    q = JobQueue()
    for _ in range(n_jobs):
        q.submit(Job(ad={"request_gpus": 1}, runtime_s=10), 0.0)
    col = Collector()
    add_worker(col, "w0", {"cpus": 8, "gpus": 4}, start="gpus >= 2")
    return q, col


def test_preview_legacy_error_bound_deterministic():
    qa, ca = quantity_pool()
    (per_q,) = ca.preview(qa, 0.0)
    assert ca.preview_legacy == 1
    previewed = sum(per_q.values())
    assert previewed == 4         # one full cohort slice, stale verdict

    qb, cb = quantity_pool()
    actual = cb.run_cycle(qb, 0.0)
    assert actual == 3            # live offers shrink 4 -> 3 -> 2 -> fail
    over = previewed - actual
    assert over == 1
    # the documented bound: over-count <= the first mis-admitted slice,
    # fits(live free) jobs, per worker — here fits(4 gpus, 1/job) = 4
    assert 0 < over <= 4


def test_preview_legacy_never_undercounts_threshold_pools():
    """Monotone (>= threshold) quantity expressions: preview >= actual,
    and over-count per pool stays under the per-worker slice bound."""
    rng = np.random.default_rng(59)
    for trial in range(10):
        n_workers = int(rng.integers(1, 5))
        thresholds = [int(rng.integers(1, 4)) for _ in range(n_workers)]
        caps = [int(rng.integers(2, 9)) for _ in range(n_workers)]

        def build():
            col = Collector()
            for i in range(n_workers):
                add_worker(col, f"w{i}", {"cpus": caps[i], "memory": 64},
                           start=f"cpus >= {thresholds[i]}")
            q = JobQueue()
            for c in range(int(rng.integers(1, 4))):
                for _ in range(int(rng.integers(1, 7))):
                    q.submit(Job(ad={"request_cpus": 1 + c % 2,
                                     "request_memory": 1 + c},
                                 runtime_s=30), float(c))
            return q, col

        state = rng.bit_generator.state
        qa, ca = build()
        rng.bit_generator.state = state      # identical twin pool
        qb, cb = build()
        (per_q,) = ca.preview(qa, 0.0)
        previewed = sum(per_q.values())
        actual = cb.run_cycle(qb, 0.0)
        assert previewed >= actual, f"trial={trial} under-count"
        # loose form of the bound: one slice of at most cap jobs/worker
        assert previewed - actual <= sum(caps), f"trial={trial}"
