"""Event-driven core (core/events.py + Simulation engine="event"):
exact-timestamp firing, drift-free cadences, mid-tick accounting, and
parity with the seed tick loop."""
import pytest

from repro.core import (
    EventLoop, Job, JobQueue, Collector, ProvisionerConfig, Simulation,
    Worker, gpu_job, onprem_nodes,
)
from repro.core.classad import ClassAdExpr


def mk_sim(n_nodes=2, gpus=8, engine="event", **kw):
    cfg = ProvisionerConfig(
        submit_interval_s=kw.pop("submit_interval_s", 30),
        idle_timeout_s=kw.pop("idle_timeout_s", 120),
        startup_delay_s=kw.pop("startup_delay_s", 10),
    )
    return Simulation(cfg, nodes=onprem_nodes(n_nodes, gpus=gpus),
                      engine=engine, **kw)


# ---------------------------------------------------------------------------
# EventLoop unit behaviour
# ---------------------------------------------------------------------------

def test_events_fire_at_exact_timestamps_in_order():
    loop = EventLoop()
    log = []
    loop.schedule(12.5, lambda t: log.append(("a", t)))
    loop.schedule(3.0, lambda t: log.append(("b", t)))
    loop.schedule(12.5, lambda t: log.append(("c", t)), priority=-1)
    loop.run_until(20.0)
    # exact times, (time, priority, insertion) order
    assert log == [("b", 3.0), ("c", 12.5), ("a", 12.5)]
    assert loop.now == 20.0


def test_periodic_cadence_has_no_float_drift():
    """k-th firing lands at first + k*interval by MULTIPLICATION — summing
    0.3 a thousand times would already be off by >1e-13."""
    loop = EventLoop()
    times = []
    loop.every(0.3, times.append, first=0.0)
    loop.run_until(300.0)
    assert len(times) == 1001
    for k, t in enumerate(times):
        assert t == k * 0.3          # bit-exact, not approx


def test_cancelled_events_do_not_fire():
    loop = EventLoop()
    log = []
    h = loop.schedule(5.0, lambda t: log.append(t))
    p = loop.every(2.0, lambda t: log.append(("p", t)), first=2.0)
    h.cancel()
    loop.run_until(4.0)
    p.cancel()
    loop.run_until(10.0)
    assert log == [("p", 2.0), ("p", 4.0)]


def test_periodic_cancelling_itself_leaves_no_phantom_event():
    loop = EventLoop()
    fired = []
    handle = loop.every(5.0, lambda t: (fired.append(t),
                                        handle.cancel() if t >= 10 else None),
                        first=5.0)
    loop.run_until(100.0)
    assert fired == [5.0, 10.0]
    assert loop.next_at() is None        # nothing re-armed after cancel


def test_utilization_never_exceeds_one_after_midtick_stop():
    """alive and busy integrate in the SAME lazy windows: a pod stopped
    at t=7.5 between ticks must not push busy past alive."""
    from repro.core import KubeCluster, Node, Pod
    c = KubeCluster([Node(name="n0", capacity={"cpu": 4, "gpu": 1})])
    c.create_pod(Pod(name="p0", request={"cpu": 4, "gpu": 1}), now=0.0)
    c.schedule(0.0)
    c.tick_accounting(5.0, 5.0)
    c.delete_pod("p0", 7.5, "preempted")
    assert c.utilization("gpu") <= 1.0 + 1e-9
    cap, busy = c.resource_seconds("gpu")
    assert abs(busy - 7.5) < 1e-9 and abs(cap - 7.5) < 1e-9


def test_scheduling_in_the_past_rejected():
    loop = EventLoop()
    loop.run_until(10.0)
    with pytest.raises(ValueError):
        loop.schedule(5.0, lambda t: None)


def test_pre_hook_runs_before_each_event():
    """The simulation integrates continuous state up to t before an event
    at t observes the world."""
    loop = EventLoop()
    seen = []
    loop.schedule(4.0, lambda t: seen.append(("evt", t)))
    loop.schedule(7.5, lambda t: seen.append(("evt", t)))
    loop.run_until(10.0, pre=lambda t: seen.append(("pre", t)))
    assert seen == [("pre", 4.0), ("evt", 4.0), ("pre", 7.5), ("evt", 7.5)]


# ---------------------------------------------------------------------------
# Satellite: negotiation-interval drift
# ---------------------------------------------------------------------------

def test_negotiation_cadence_exact_when_interval_not_tick_multiple():
    """Regression: the seed's `_last_negotiate = now` fired at 0,21,42,...
    with tick_s=7 / interval=15; the event loop pins last + interval."""
    sim = mk_sim(tick_s=7, negotiate_interval_s=15)
    times = []
    orig = sim.collector.run_cycle

    def spy(queue, now):
        times.append(now)
        return orig(queue, now)

    sim.collector.run_cycle = spy
    sim.run(100)
    assert times == [0, 15, 30, 45, 60, 75, 90]


def test_tick_engine_still_drifts_documenting_the_seed_bug():
    sim = mk_sim(tick_s=7, negotiate_interval_s=15, engine="tick")
    times = []
    orig = sim.collector.scan_cycle

    def spy(queue, now):
        times.append(now)
        return orig(queue, now)

    sim.collector.scan_cycle = spy
    sim.run(100)
    assert times == [0, 21, 42, 63, 84]   # quantized to tick multiples


def test_reconcile_cadence_exact():
    sim = mk_sim(tick_s=7, submit_interval_s=30)
    times = []
    orig = sim.provisioner.reconcile
    sim.provisioner.reconcile = lambda now: (times.append(now),
                                             orig(now))[1]
    sim.run(100)
    assert times == [0, 30, 60, 90]


# ---------------------------------------------------------------------------
# Satellite: late event firing / mid-tick accounting
# ---------------------------------------------------------------------------

def test_external_event_fires_at_exact_mid_tick_time():
    sim = mk_sim(tick_s=5)
    fired = []
    sim.at(12.5, lambda s, now: fired.append(now))
    sim.run(20)
    assert fired == [12.5]


def test_mid_tick_spot_reclaim_accounted_at_scheduled_time():
    """A reclaim at t=137.5 must see job progress up to EXACTLY 137.5:
    pod placed at t=0, startd boots at 10, claim at the t=15 negotiation,
    so the attempt has run 122.5s — all of it wasted (no checkpoints)."""
    sim = mk_sim(n_nodes=1, startup_delay_s=10, tick_s=5)
    sim.submit_jobs(0, [gpu_job(300, gpus=1)])
    sim.inject_pod_preemption(137.5, frac=1.0)
    sim.run_until_drained(max_t=10000)
    assert sim.queue.drained()
    (job,) = sim.queue.completed_log
    assert job.preempt_count == 1
    assert job.attempt_started_at > 137.5     # re-claimed after the reclaim
    assert abs(job.wasted_s - 122.5) < 1e-6
    assert sim.backends[0].stats.pods_reclaimed == 1


def test_job_completions_land_at_exact_fractional_times():
    sim = mk_sim(n_nodes=1, startup_delay_s=10, tick_s=5)
    sim.submit_jobs(0, [gpu_job(123.4, gpus=1)])
    sim.run_until_drained(max_t=10000)
    (job,) = sim.queue.completed_log
    # claim at the t=15 negotiation; finish exactly 123.4s later
    assert job.started_at == 15.0
    assert abs(job.completed_at - (15.0 + 123.4)) < 1e-9


# ---------------------------------------------------------------------------
# Vectorized negotiator vs the seed scan (differential oracle)
# ---------------------------------------------------------------------------

def _pool(n_workers, gpus=4):
    col = Collector()
    for i in range(n_workers):
        w = Worker(name=f"w{i}",
                   ad={"cpus": 8, "gpus": gpus, "memory": 64, "disk": 64},
                   start_expr=ClassAdExpr(None), startup_delay=0.0)
        w.booted_at = 0.0
        col.advertise(w)
    return col


def _jobs(queue, shapes):
    for gpus, cpus in shapes:
        queue.submit(Job(ad={"request_cpus": cpus, "request_gpus": gpus,
                             "request_memory": 4, "request_disk": 8},
                         runtime_s=100), now=0.0)


def test_vectorized_matches_scan_when_capacity_plentiful():
    shapes = [(1, 1)] * 10 + [(2, 2)] * 5 + [(4, 4)] * 3
    qa, qb = JobQueue(), JobQueue()
    _jobs(qa, shapes)
    _jobs(qb, shapes)
    ca, cb = _pool(10), _pool(10)
    na = ca.run_cycle(qa, 0.0)
    nb = cb.scan_cycle(qb, 0.0)
    assert na == nb == len(shapes)
    assert qa.n_idle() == qb.n_idle() == 0
    # identical per-worker load profile (sorted claim counts)
    la = sorted(len(w.claimed) for w in ca.workers.values())
    lb = sorted(len(w.claimed) for w in cb.workers.values())
    assert la == lb


def test_vectorized_matches_scan_under_contention_single_cohort():
    shapes = [(1, 1)] * 50                    # one cohort, 50 jobs
    qa, qb = JobQueue(), JobQueue()
    _jobs(qa, shapes)
    _jobs(qb, shapes)
    ca, cb = _pool(3, gpus=4), _pool(3, gpus=4)   # 12 slots
    na = ca.run_cycle(qa, 0.0)
    nb = cb.scan_cycle(qb, 0.0)
    assert na == nb == 12
    # FIFO: the 12 earliest-submitted jobs were the ones claimed
    claimed_a = sorted(j.jid for w in ca.workers.values()
                       for j in w.claimed.values())
    claimed_b = sorted(j.jid for w in cb.workers.values()
                       for j in w.claimed.values())
    assert claimed_a == claimed_b == list(range(12))


def test_quantity_referencing_start_expr_reevaluated_per_claim():
    """'gpus >= 2' on a 4-GPU slot admits only 3 one-GPU jobs (the offer
    shrinks 4->3->2->1); block-claiming all 4 would violate the START
    policy.  Vectorized and scan negotiators must agree."""
    def pool():
        q = JobQueue()
        for _ in range(4):
            q.submit(Job(ad={"request_gpus": 1}, runtime_s=10), now=0.0)
        col = Collector()
        w = Worker(name="w0", ad={"cpus": 8, "gpus": 4},
                   start_expr=ClassAdExpr("gpus >= 2"), startup_delay=0.0)
        w.booted_at = 0.0
        col.advertise(w)
        return q, col, w

    qa, ca, wa = pool()
    qb, cb, wb = pool()
    assert ca.run_cycle(qa, 0.0) == 3
    assert cb.scan_cycle(qb, 0.0) == 3
    assert len(wa.claimed) == len(wb.claimed) == 3


def test_late_external_event_fires_on_next_advance():
    """Seed semantics: scheduling an event at/before `now` is accepted
    and fires as soon as the clock moves (not a ValueError)."""
    sim = mk_sim(tick_s=5)
    sim.run(100)
    fired = []
    sim.at(50, lambda s, now: fired.append(now))
    sim.run(110)
    assert fired == [100.0]


def test_tick_engine_quantizes_completions_like_the_seed():
    """The baseline oracle must keep the seed's now+dt completion grain."""
    sim = mk_sim(n_nodes=1, startup_delay_s=10, tick_s=5, engine="tick")
    sim.submit_jobs(0, [gpu_job(123.4, gpus=1)])
    sim.run_until_drained(max_t=10000)
    (job,) = sim.queue.completed_log
    assert job.completed_at % 5 == 0          # a tick boundary, not 138.4


def test_start_expr_respected_by_vectorized_negotiator():
    q = JobQueue()
    q.submit(Job(ad={"request_gpus": 1, "priority_user": False},
                 runtime_s=10), now=0.0)
    q.submit(Job(ad={"request_gpus": 1, "priority_user": True},
                 runtime_s=10), now=0.0)
    col = Collector()
    w = Worker(name="w0", ad={"cpus": 8, "gpus": 8},
               start_expr=ClassAdExpr("priority_user == True"),
               startup_delay=0.0)
    w.booted_at = 0.0
    col.advertise(w)
    assert col.run_cycle(q, 0.0) == 1
    (job,) = w.claimed.values()
    assert job.ad["priority_user"] is True


def test_tick_engine_accounts_full_node_uptime_like_the_seed():
    """The baseline oracle integrated [now, now+dt] forward: after
    run(100) a static node has 100s of alive time, not 95."""
    for engine in ("tick", "event"):
        sim = mk_sim(n_nodes=1, engine=engine, tick_s=5)
        sim.run(100)
        node = next(iter(sim.cluster.nodes.values()))
        assert node.alive_s == 100.0, (engine, node.alive_s)


def test_idle_timeout_clock_starts_at_exact_completion_time():
    """Job finishes mid-segment at t=138.4; with idle_timeout=120 the
    worker must live until >= 258.4, so it terminates at the t=260
    boundary — a segment-start idle clock would kill it at 255."""
    sim = mk_sim(n_nodes=1, startup_delay_s=10, tick_s=5,
                 idle_timeout_s=120)
    sim.submit_jobs(0, [gpu_job(123.4, gpus=1)])
    sim.run_until_drained(max_t=10000)
    sim.run(sim.now + 500)
    (w,) = sim.all_workers
    assert w.terminated
    # booted at 10; must survive past completion (138.4) + timeout (120)
    assert 10.0 + w.alive_s >= 138.4 + 120


def test_one_release_pays_one_sort_then_fast_path_returns():
    q = JobQueue()
    for i in range(100):
        q.submit(Job(ad={"request_gpus": 1}, runtime_s=50), float(i))
    (key,) = [k for k, _ in q.idle_cohorts()]
    early = q.cohort_jobs_sorted(key)[0]
    q.claim(early.jid, "w0", 200.0)
    q.release(early.jid, 210.0)          # re-enters behind newer jids
    assert key in q._cohort_unsorted
    order = [j.jid for j in q.cohort_jobs_sorted(key)]
    assert order == sorted(order)
    assert key not in q._cohort_unsorted  # dict rebuilt in order
    # insertion order is FIFO again: no further sorts flagged
    assert [j.jid for j in q.cohort_jobs_sorted(key)] == order


def test_idle_clock_never_predates_worker_boot():
    """A worker booted mid-segment must get a full idle_timeout of real
    idleness before self-terminating."""
    from repro.core import Collector, Worker
    from repro.core.worker import advance_workers
    col, q = Collector(), JobQueue()
    w = Worker(name="w0", ad={"cpus": 1, "gpus": 1},
               start_expr=ClassAdExpr(None), idle_timeout=10.0)
    w.booted_at = 15.0
    col.advertise(w)
    advance_workers(col, q, None, 0.0, 20.0)
    assert w.idle_since == 15.0          # boot time, not segment start
    advance_workers(col, q, None, 20.0, 2.0)
    assert not w.terminated              # only 7s idle so far
    advance_workers(col, q, None, 22.0, 3.0)
    assert w.terminated                  # 15 + 10 <= 25


def test_summary_reads_accounting_flushed_to_now():
    """run()/summary() between backend ticks must not report node
    integrals stale by a partial tick (or 0/0 utilization)."""
    sim = mk_sim(n_nodes=1, tick_s=5)
    sim.run(13.0)
    node = next(iter(sim.cluster.nodes.values()))
    assert node.alive_s == 13.0
    sim2 = mk_sim(n_nodes=1, tick_s=5)
    sim2.run(3.0)
    s = sim2.summary()
    cap, _busy = sim2.cluster.resource_seconds("gpu")
    assert cap > 0                       # provisioned seconds visible
    assert 0.0 <= s["gpu_utilization"] <= 1.0


def test_cost_accrual_matches_exact_node_uptime():
    """A billed node added mid-run is charged from its add time to the
    flush point — not back-billed for the interval before it existed,
    and not missing the final partial interval."""
    from repro.core import KubeBackend, KubeCluster, Node, ProvisionerConfig
    cluster = KubeCluster([], name="cloud")
    b = KubeBackend("cloud", cluster, node_hourly_cost=3600.0)  # $1/s/node
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=10)
    sim = Simulation(cfg, backends=[b], tick_s=5)
    sim.at(60.0, lambda s, now: cluster.add_node(
        Node(name="n0", capacity={"cpu": 4, "gpu": 1}), now))
    sim.run(137.5)
    node = cluster.nodes["n0"]
    assert node.alive_s == 77.5
    assert abs(b.stats.cost_total - 77.5) < 5.0 + 1e-9   # ≤1 tick slack
    assert b.stats.cost_total > 72.4                      # no lost tail


def test_vectorized_negotiate_falls_back_on_foreign_queue():
    """A queue exposing only the seed surface must still negotiate."""
    class SeedQueue:
        def __init__(self):
            self.inner = JobQueue()
            self.claimed = []

        def idle_jobs(self):
            return self.inner.idle_jobs()

        def claim(self, jid, worker, now):
            self.claimed.append(jid)
            return self.inner.claim(jid, worker, now)

    q = SeedQueue()
    q.inner.submit(Job(ad={"request_gpus": 1}, runtime_s=10), 0.0)
    col = Collector()
    w = Worker(name="w0", ad={"cpus": 4, "gpus": 4},
               start_expr=ClassAdExpr(None), startup_delay=0.0)
    w.booted_at = 0.0
    col.advertise(w)
    assert col.run_cycle(q, 0.0) == 1
    assert q.claimed == [0]


def test_first_pods_place_at_t0_like_the_seed():
    """The t=0 reconcile's pods must be scheduled by a t=0 priming pass,
    not wait for the first periodic backend tick at t=tick_s."""
    sim = mk_sim(n_nodes=1, startup_delay_s=10, tick_s=5)
    sim.submit_jobs(0, [gpu_job(100, gpus=1)])
    sim.run(1)
    placed = sim.cluster.running_pods()
    assert placed and placed[0].started_at == 0.0


def test_backend_without_schedule_on_hook_still_ticks():
    """A ScalingBackend implementing only the documented Protocol (no
    event-loop registration hook) must work under engine='event'."""
    from repro.core import KubeBackend, KubeCluster, ProvisionerConfig

    class MinimalBackend(KubeBackend):
        schedule_on = None            # protocol surface only

    b = MinimalBackend("min", KubeCluster(
        onprem_nodes(2, gpus=8, prefix="min"), name="min"))
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=10)
    sim = Simulation(cfg, backends=[b], tick_s=5, engine="event")
    sim.submit_jobs(0, [gpu_job(100, gpus=1) for _ in range(5)])
    sim.run_until_drained(max_t=10000)
    assert sim.queue.drained()
    assert len(sim.queue.completed_log) == 5


# ---------------------------------------------------------------------------
# Engine parity + federation at moderate scale
# ---------------------------------------------------------------------------

def _campaign(engine):
    sim = mk_sim(n_nodes=4, engine=engine, tick_s=5)
    sim.submit_jobs(0, [gpu_job(300, gpus=1) for _ in range(40)])
    sim.submit_jobs(600, [gpu_job(150, gpus=2) for _ in range(10)])
    sim.run_until_drained(max_t=30000)
    return sim


def test_event_engine_matches_tick_engine_outcomes():
    ev, tk = _campaign("event"), _campaign("tick")
    assert ev.queue.drained() and tk.queue.drained()
    se, st_ = ev.summary(), tk.summary()
    assert set(se) == set(st_)                       # same summary schema
    assert se["jobs"]["n"] == st_["jobs"]["n"] == 50
    assert se["jobs"]["preemptions"] == st_["jobs"]["preemptions"] == 0
    # same work done on the same pool: utilization within a few ticks
    assert abs(se["gpu_utilization"] - st_["gpu_utilization"]) < 0.1
    # drain times agree to within a couple of control-plane periods
    assert abs(ev.now - tk.now) <= 60


def test_federated_event_engine_drains_and_keeps_summary_schema():
    from repro.core import (
        KubeBackend, KubeCluster, NodeAutoscaler, NodeTemplate,
    )
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=10)
    onprem = KubeBackend("onprem", KubeCluster(
        onprem_nodes(2, gpus=8, prefix="onprem"), name="onprem"))
    cloud_cluster = KubeCluster([], name="cloud")
    tmpl = NodeTemplate(capacity={"cpu": 64, "gpu": 8, "memory": 512,
                                  "disk": 1024},
                        provision_delay_s=60, scale_down_delay_s=120)
    cloud = KubeBackend("cloud", cloud_cluster,
                        NodeAutoscaler(cloud_cluster, tmpl, max_nodes=8,
                                       prefix="cloud-np"))
    spot_cluster = KubeCluster([], name="spot")
    spot = KubeBackend("spot", spot_cluster,
                       NodeAutoscaler(spot_cluster, tmpl, max_nodes=8,
                                      prefix="spot-np"),
                       spot=True)
    sim = Simulation(cfg, backends=[onprem, cloud, spot], tick_s=5,
                     engine="event")
    sim.submit_jobs(0, [gpu_job(200, gpus=1) for _ in range(300)])
    sim.inject_pod_preemption(400, frac=0.3, backend="spot")
    sim.run_until_drained(max_t=50000)
    assert sim.queue.drained()
    s = sim.summary()
    assert set(s) >= {"jobs", "workers", "pods_submitted",
                      "gpu_utilization", "cost_total", "backends"}
    assert s["jobs"]["n"] == 300
    assert set(s["backends"]) == {"onprem", "cloud", "spot"}
    for name in ("onprem", "cloud", "spot"):
        assert set(s["backends"][name]) >= {
            "pods_submitted", "pods_reclaimed", "cost", "waste_fraction",
            "gpu_utilization", "gpu_seconds_provisioned",
            "gpu_seconds_busy", "live_nodes", "spot"}
    # per-backend series recorded on the metrics cadence
    assert set(sim.recorder.backends_recorded()) == {
        "onprem", "cloud", "spot"}
