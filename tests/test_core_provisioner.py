"""Provisioning-logic invariants (paper §2): deficit accounting, grouping,
self-termination, preemption resilience, two-level scaling."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    Collector, Job, JobQueue, KubeCluster, Node, NodeAutoscaler,
    NodeTemplate, PodPhase, Provisioner, ProvisionerConfig, Simulation,
    gpu_job, onprem_nodes,
)
from repro.core.groups import group_jobs, signature_of
from repro.core.simulation import TimedEvent


def mk_sim(n_nodes=4, gpus=8, **cfg_kw):
    cfg = ProvisionerConfig(
        submit_interval_s=cfg_kw.pop("submit_interval_s", 30),
        idle_timeout_s=cfg_kw.pop("idle_timeout_s", 120),
        startup_delay_s=cfg_kw.pop("startup_delay_s", 30),
        **cfg_kw,
    )
    return Simulation(cfg, nodes=onprem_nodes(n_nodes, gpus=gpus), tick_s=5)


# ---------------------------------------------------------------------------
# C1: reconciliation never over-submits
# ---------------------------------------------------------------------------

def test_deficit_is_capped_by_demand():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(600) for _ in range(10)])
    sim.run(300)
    # pods submitted must never exceed the job count (idempotent deficit)
    assert sim.provisioner.stats.submitted <= 10


def test_reconcile_idempotent_at_fixed_demand():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(600) for _ in range(5)])
    sim.run(40)   # first reconcile happened
    before = sim.provisioner.stats.submitted
    # force extra reconciles without demand change: nothing new
    for _ in range(5):
        sim.provisioner.reconcile(sim.now)
    assert sim.provisioner.stats.submitted == before


def test_scales_to_zero_and_drains():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(300) for _ in range(6)])
    sim.run(3000)
    assert sim.queue.drained()
    # all workers must have self-terminated (C2) — no zombie pods
    assert not sim.collector.workers
    live = [p for p in sim.cluster.pods.values()
            if p.phase in (PodPhase.RUNNING, PodPhase.PENDING)]
    assert not live


def test_max_pods_limits_respected():
    sim = mk_sim(max_pods_per_group=3, max_total_pods=3)
    sim.submit_jobs(0, [gpu_job(600) for _ in range(20)])
    sim.run(200)
    assert sim.provisioner.stats.submitted <= 3


# ---------------------------------------------------------------------------
# C3: filter push-down
# ---------------------------------------------------------------------------

def test_filter_excludes_unmatching_jobs():
    sim = mk_sim(job_filter='can_run_prp == True')
    good = [gpu_job(300, extra_ad={"can_run_prp": True}) for _ in range(3)]
    bad = [gpu_job(300, extra_ad={"can_run_prp": False}) for _ in range(3)]
    sim.submit_jobs(0, good + bad)
    sim.run(2000)
    # only matching jobs were provisioned for and completed
    assert sim.provisioner.stats.submitted <= 3
    done = {j.jid for j in sim.queue.completed_log}
    assert len(done) == 3
    assert sim.queue.n_idle() == 3  # unmatched jobs stay idle forever


def test_workers_never_claim_filtered_jobs():
    """Even when a non-matching job is the only idle one, the pushed-down
    START policy blocks the claim (C3 symmetry)."""
    sim = mk_sim(job_filter='priority_user == True',
                 idle_timeout_s=40)
    sim.submit_jobs(0, [gpu_job(100, extra_ad={"priority_user": True})])
    sim.submit_jobs(10, [gpu_job(100, extra_ad={"priority_user": False})])
    sim.run(3000)
    assert len(sim.queue.completed_log) == 1
    assert sim.queue.n_idle() == 1


# ---------------------------------------------------------------------------
# C4: requirement grouping
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(
    st.tuples(st.integers(1, 4), st.integers(0, 2),
              st.sampled_from([2, 4, 8, 16])),
    min_size=1, max_size=20))
def test_grouping_partition_property(reqs):
    """Property: grouping is a partition — every job in exactly one group,
    and all jobs in a group share the signature."""
    jobs = [Job(ad={"request_cpus": c, "request_gpus": g,
                    "request_memory": m}) for c, g, m in reqs]
    for i, j in enumerate(jobs):
        j.jid = i
    groups = group_jobs(jobs)
    seen = set()
    for sig, members in groups.items():
        for j in members:
            assert j.jid not in seen
            seen.add(j.jid)
            assert signature_of(j) == sig
    assert seen == {j.jid for j in jobs}


def test_heterogeneous_jobs_get_separate_pods():
    """1-GPU and 4-GPU jobs must spawn pods of both shapes (the paper's
    motivation vs uniform HPA)."""
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(300, gpus=1) for _ in range(3)]
                    + [gpu_job(300, gpus=4) for _ in range(2)])
    sim.run(500)
    shapes = {p.request.get("gpu") for p in sim.cluster.pods.values()}
    shapes |= {p[1] for p in []}  # keep set usage obvious
    assert {1.0, 4.0} <= shapes or sim.queue.drained()


# ---------------------------------------------------------------------------
# C2: self-termination timing
# ---------------------------------------------------------------------------

def test_idle_timeout_respected():
    sim = mk_sim(idle_timeout_s=100)
    sim.submit_jobs(0, [gpu_job(50)])
    sim.run(1000)
    w = sim.all_workers[0]
    # worker stayed alive ≈ job time + idle timeout (within a few ticks)
    assert 100 <= w.alive_s <= 50 + 100 + 30


# ---------------------------------------------------------------------------
# §5: preemption
# ---------------------------------------------------------------------------

def test_preempted_jobs_rescheduled_and_complete():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(400) for _ in range(8)])
    sim.inject_pod_preemption(200, frac=0.5)
    sim.run(5000)
    assert sim.queue.drained()
    s = sim.summary()
    assert s["jobs"]["n"] == 8
    assert s["jobs"]["preemptions"] >= 1
    assert s["jobs"]["wasted_s"] > 0       # §5: preemption costs some work


def test_checkpointing_jobs_waste_less():
    """Jobs that self-checkpoint (our JAX training jobs) lose only the
    tail since the last boundary."""
    def run(ckpt):
        sim = mk_sim()
        sim.submit_jobs(0, [gpu_job(400, checkpoint_interval_s=ckpt)
                            for _ in range(4)])
        sim.inject_pod_preemption(300, frac=1.0)
        sim.run(5000)
        return sim.summary()["jobs"]["wasted_s"]

    w_ckpt = run(50)
    w_none = run(None)
    assert w_ckpt < w_none


def test_node_failure_tolerated():
    sim = mk_sim(n_nodes=3)
    sim.submit_jobs(0, [gpu_job(300) for _ in range(6)])
    sim.inject_node_failure(150)
    sim.run(5000)
    assert sim.queue.drained()


# ---------------------------------------------------------------------------
# §6: two-level autoscaling (pods drive nodes)
# ---------------------------------------------------------------------------

def test_node_autoscaler_tracks_demand_and_scales_down():
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=60,
                            startup_delay_s=10)
    tmpl = NodeTemplate(capacity={"cpu": 64, "gpu": 7, "memory": 512,
                                  "disk": 1024},
                        provision_delay_s=60, scale_down_delay_s=120)
    sim = Simulation(cfg, nodes=[], node_template=tmpl, max_nodes=16,
                     tick_s=5)
    # paper's GKE test: 1-GPU pods onto 7-GPU nodes
    sim.submit_jobs(0, [gpu_job(600, gpus=1) for _ in range(20)])
    sim.run(1200)
    assert sim.autoscaler.provisioned_total >= 3   # scaled up
    sim.run(8000)
    assert sim.queue.drained()
    assert sim.autoscaler.live_nodes() == 0        # scaled back to zero
    assert sim.autoscaler.deprovisioned_total == \
        sim.autoscaler.provisioned_total
    # deprovision waste exists but bounded (paper: "close to minimum")
    assert 0 < sim.autoscaler.waste_fraction() < 0.6


# ---------------------------------------------------------------------------
# Preview memoization: the dry-run packing is cached on
# (idle-queue version, free-capacity digest) and invalidated by either
# ---------------------------------------------------------------------------

def test_preview_memo_hits_when_nothing_changed():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(600) for _ in range(4)])
    sim.run(35)    # a couple of reconciles have populated the cache
    p = sim.provisioner
    assert p.preview_misses >= 1
    p.reconcile(sim.now)        # may miss: workers became ready since t=30
    hits0, misses0 = p.preview_hits, p.preview_misses
    p.reconcile(sim.now)        # identical queue + identical free matrix
    assert p.preview_hits == hits0 + 1
    assert p.preview_misses == misses0


def test_preview_memo_invalidated_by_new_demand():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(600) for _ in range(4)])
    sim.run(35)
    p = sim.provisioner
    p.reconcile(sim.now)                        # warm the cache at now
    misses0 = p.preview_misses
    sim.queue.submit(gpu_job(600), sim.now)     # bumps idle_version
    p.reconcile(sim.now)
    assert p.preview_misses == misses0 + 1


# ---------------------------------------------------------------------------
# Free-matrix digest memo: the preview key reuses each worker's cached
# capacity digest (dirty-flagged on claim changes) instead of re-hashing
# every free vector per poll
# ---------------------------------------------------------------------------

def test_free_digest_cached_until_claims_change():
    sim = mk_sim()
    sim.submit_jobs(0, [gpu_job(600) for _ in range(4)])
    sim.run(200)   # workers booted and claimed; several reconciles ran
    p = sim.provisioner
    assert p.digest_misses >= 1          # first look at each worker hashes
    hits0, misses0 = p.digest_hits, p.digest_misses
    p.reconcile(sim.now)
    p.reconcile(sim.now)
    # no claim changed between the polls: every ready worker hits
    assert p.digest_hits > hits0
    assert p.digest_misses == misses0


def test_free_digest_invalidated_by_claim_change():
    from repro.core.worker import Worker
    from repro.core.classad import ClassAdExpr

    w = Worker(name="w0", ad={"cpus": 8, "memory": 32},
               start_expr=ClassAdExpr("True"))
    w.booted_at = 0.0
    rev0 = w.free_rev
    d0 = w.free_digest()
    assert w.free_digest() == d0 and w.free_rev == rev0   # cached
    job = gpu_job(60)
    job.jid = 1
    w.add_claim(job)
    assert w.free_rev > rev0
    assert w.free_digest() != d0         # re-hashed after the claim
    w.drop_claim(job.jid)
    assert w.free_digest() == d0         # capacity restored -> same digest


# ---------------------------------------------------------------------------
# Incremental deficits: idle-hook counters replace the per-cycle recount
# and must agree with the retired dry-run scan exactly
# ---------------------------------------------------------------------------

def test_incremental_deficits_match_oracle_live():
    """`debug_exact_deficits` asserts counts == the full-recount oracle
    inside every reconcile; a heterogeneous drain must never trip it."""
    sim = mk_sim()
    sim.provisioner.debug_exact_deficits = True
    sim.submit_jobs(0, [gpu_job(300) for _ in range(6)])
    sim.submit_jobs(10, [Job(ad={"request_cpus": 2, "request_memory": 4,
                                 "runtime_s": 200.0}) for _ in range(8)])
    sim.run(3000)
    assert sim.queue.drained()
    p = sim.provisioner
    groups, by_schedd, legacy = p._idle_group_counts(sim.now)
    assert not legacy and not groups     # drained pool counts to zero
    assert not p._inc_counts


def test_incremental_counts_track_idle_transitions():
    sim = mk_sim()
    p = sim.provisioner
    sim.submit_jobs(0, [gpu_job(600) for _ in range(5)])
    sim.run(10)    # reconcile ran -> counters rebuilt and hooked
    total = sum(sum(per.values()) for per in p._inc_counts.values())
    assert total == 5                    # all five still idle
    sim.run(600)   # workers boot, claims land -> idle leaves decrement
    total = sum(sum(per.values()) for per in p._inc_counts.values())
    assert total == len(list(sim.queue.idle_jobs()))


def test_idle_hook_fires_on_enter_and_leave():
    q = JobQueue()
    events = []
    q.add_idle_hook(lambda job, delta: events.append((job.jid, delta)))
    j = Job(ad={"request_cpus": 1, "request_memory": 1, "runtime_s": 5.0})
    q.submit(j, 0.0)
    assert events == [(j.jid, +1)]
    q.claim(j.jid, "w0", 1.0)
    assert events == [(j.jid, +1), (j.jid, -1)]
    q.release(j.jid, 2.0)                # back to idle
    assert events[-1] == (j.jid, +1)
    assert q.idle_seq == 3
