"""Multi-device semantics tests. Each test runs in a SUBPROCESS with
xla_force_host_platform_device_count set (the main pytest process must
keep seeing 1 device), asserting:

  * EP (all-to-all) MoE dispatch == dense reference dispatch
  * int8-compressed cross-pod psum ≈ exact mean (unbiased, bounded err)
  * sharded train step == single-device train step (bitwise-ish)
  * elastic checkpoint restore onto a different mesh preserves values
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, n_dev: int = 8):
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_ep_matches_dense():
    run_sub("""
        from repro.configs import reduced_config
        from repro.models import moe as moe_mod
        from repro.models.param import materialize
        import dataclasses
        cfg = reduced_config("llama4-scout-17b-a16e")
        # capacity high enough that no tokens drop in either path
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=4, capacity_factor=8.0))
        p = materialize(moe_mod.init_moe(cfg), jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32)
        y_dense, aux_d = moe_mod.moe_forward_dense(p, cfg, x)
        with jax.set_mesh(mesh):
            xs = jax.device_put(x, NamedSharding(mesh, P("data")))
            ps = jax.tree.map(lambda a: jax.device_put(a, NamedSharding(
                mesh, P())), p)
            ps["gate"] = jax.device_put(p["gate"], NamedSharding(
                mesh, P("data", None, "model")))
            ps["up"] = jax.device_put(p["up"], NamedSharding(
                mesh, P("data", None, "model")))
            ps["down"] = jax.device_put(p["down"], NamedSharding(
                mesh, P("data", "model", None)))
            y_ep, aux_e = jax.jit(
                lambda p_, x_: moe_mod.moe_forward_ep(p_, cfg, x_, mesh)
            )(ps, xs)
        err = float(jnp.max(jnp.abs(y_ep - y_dense)))
        aerr = abs(float(aux_e) - float(aux_d))
        assert err < 1e-4, ("EP mismatch", err)
        # aux is a LOCAL load-balance estimate under EP (mean of per-shard
        # f·P products) — close to, but not equal to, the global estimate
        assert aerr < 0.1, ("aux mismatch", aerr)
        print("EP OK", err)
    """)


def test_compressed_psum_unbiased():
    run_sub("""
        from repro.parallel.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 256), jnp.float32)
        def body(gl):
            key = jax.random.PRNGKey(3)
            return compressed_psum(gl, ("pod",), key)
        out = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("pod"),
                      out_specs=P("pod"), check_vma=False))(g)
        exact = jnp.mean(g, axis=0, keepdims=True)
        # every shard holds the same mean estimate; error bounded by the
        # quantization step (amax/127)
        step = float(jnp.max(jnp.abs(g))) / 127.0
        err = float(jnp.max(jnp.abs(out[0:1] - exact)))
        assert err <= step, (err, step)
        print("compressed psum OK", err, step)
    """)


def test_sharded_train_step_matches_single_device():
    run_sub("""
        from repro.configs import reduced_config
        from repro.models import model as model_lib
        from repro.models.param import materialize, axes_tree
        from repro.parallel.sharding import rules_for
        from repro.train.optimizer import OptimizerConfig
        from repro.train.train_step import (make_train_step,
            init_train_state, state_shardings)
        from repro.data.pipeline import SyntheticTokenPipeline

        cfg = reduced_config("granite-8b")
        opt = OptimizerConfig(lr=1e-3)
        pipe = SyntheticTokenPipeline(cfg.vocab_size, 32, 8, seed=1)
        batch = pipe.jax_batch_at(0)

        # single-device ground truth
        mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                     ("data", "model"))
        rules = rules_for(cfg, "train")
        params = materialize(model_lib.init_model(cfg),
                             jax.random.PRNGKey(0))
        st0 = init_train_state(params, opt, jax.random.PRNGKey(0))
        f1 = make_train_step(cfg, opt, mesh1, rules, remat="none")
        with jax.set_mesh(mesh1):
            st1, m1 = jax.jit(f1)(st0, batch)

        # 4x2 sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        ptree = model_lib.init_model(cfg)
        sh = state_shardings(ptree, rules, mesh)
        stS = jax.device_put(st0, sh)
        fS = make_train_step(cfg, opt, mesh, rules, remat="none")
        with jax.set_mesh(mesh):
            st2, m2 = jax.jit(fS)(stS, batch)
        d_loss = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d_loss < 1e-4, d_loss
        l1 = jax.tree.leaves(st1.params)
        l2 = jax.tree.leaves(st2.params)
        errs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                np.asarray(b, np.float32)))) for a, b in zip(l1, l2)]
        assert max(errs) < 2e-2, max(errs)
        print("sharded step OK", d_loss, max(errs))
    """)


def test_elastic_restore_across_meshes():
    run_sub("""
        import tempfile
        from repro.checkpoint.manager import CheckpointManager
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_mode=False)

        mesh4 = jax.make_mesh((4,), ("data",))
        t4 = jax.device_put(tree, NamedSharding(mesh4, P("data")))
        mgr.save(1, t4)

        mesh8 = jax.make_mesh((8,), ("data",))
        tgt = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype),
                           tree)
        out = mgr.restore(1, tgt, jax.tree.map(
            lambda _: NamedSharding(mesh8, P("data")), tree))
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(tree["w"]))
        assert len(out["w"].sharding.device_set) == 8
        print("elastic restore OK")
    """)


def test_int8_compressed_train_step_close_to_exact():
    run_sub("""
        from repro.configs import reduced_config
        from repro.models import model as model_lib
        from repro.models.param import materialize
        from repro.parallel.sharding import rules_for
        from repro.train.optimizer import OptimizerConfig
        from repro.train.train_step import (make_train_step,
            init_train_state, state_shardings)
        from repro.data.pipeline import SyntheticTokenPipeline

        cfg = reduced_config("qwen2-1.5b")
        opt = OptimizerConfig(lr=1e-3)
        pipe = SyntheticTokenPipeline(cfg.vocab_size, 32, 8, seed=1)
        batch = pipe.jax_batch_at(0)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        # int8 compression composes with the TP ("base") preset; FSDP's
        # weight all-gather and zero3's batch-over-model sharding both trip
        # an XLA subgroup-manual partitioner check (upstream limitation) —
        # see make_train_step's guard
        from repro.parallel.sharding import preset
        rules = preset("base")
        params = materialize(model_lib.init_model(cfg),
                             jax.random.PRNGKey(0))
        st0 = init_train_state(params, opt, jax.random.PRNGKey(0))
        ptree = model_lib.init_model(cfg)
        sh = state_shardings(ptree, rules, mesh)
        st0 = jax.device_put(st0, sh)

        f_exact = make_train_step(cfg, opt, mesh, rules, remat="none")
        f_comp = make_train_step(cfg, opt, mesh, rules, remat="none",
                                 grad_compression="int8")
        with jax.set_mesh(mesh):
            st1, m1 = jax.jit(f_exact)(st0, batch)
            st2, m2 = jax.jit(f_comp)(st0, batch)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-5, d  # loss computed pre-update: must agree
        # updates differ only by quantization noise
        errs = [float(jnp.max(jnp.abs(np.asarray(a, np.float32) -
                np.asarray(b, np.float32))))
                for a, b in zip(jax.tree.leaves(st1.params),
                                jax.tree.leaves(st2.params))]
        assert max(errs) < 5e-2, max(errs)
        print("int8 compressed step OK", max(errs))
    """)


def test_sequence_parallel_attention_matches_single_device():
    """Archs whose head count does not divide the TP axis route attention
    through the shard_map sequence-parallel path — must be numerically
    identical to the unsharded computation."""
    run_sub("""
        import dataclasses
        from repro.configs import reduced_config
        from repro.models import model as model_lib
        from repro.models.param import materialize
        from repro.parallel.sharding import rules_for, constrainer
        from repro.data.pipeline import SyntheticTokenPipeline

        cfg = reduced_config("granite-8b")
        cfg = dataclasses.replace(cfg, n_heads=6, n_kv_heads=2, d_head=16)
        assert cfg.n_heads % 4 != 0  # will not divide model=4
        params = materialize(model_lib.init_model(cfg),
                             jax.random.PRNGKey(0))
        pipe = SyntheticTokenPipeline(cfg.vocab_size, 32, 8, seed=1)
        batch = pipe.jax_batch_at(0)

        loss_ref, _ = model_lib.loss_fn(params, cfg, batch, remat="none")

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = rules_for(cfg, "train")
        constrain = constrainer(rules, mesh)
        with jax.set_mesh(mesh):
            loss_sp, _ = jax.jit(
                lambda p, b: model_lib.loss_fn(
                    p, cfg, b, mesh=mesh, constrain=constrain,
                    remat="none")
            )(params, batch)
        d = abs(float(loss_ref) - float(loss_sp))
        assert d < 1e-4, d
        print("SP attention OK", d)
    """)
