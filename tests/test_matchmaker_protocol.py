"""Matchmaker protocol surface: registry, selection plumbing, the
LRU-bounded eval caches, and the deprecation shims (ISSUE 6 tentpole +
satellites 1/3)."""
import json
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.classad import ClassAdExpr
from repro.core.config import ProvisionerConfig, dump_ini, load_ini
from repro.core.jobqueue import Job, JobQueue
from repro.core.matchmaker import (
    HAVE_JAX, MatchPlan, MatchProblem, Matchmaker, NumpyMatchmaker,
    ScanMatchmaker, make_matchmaker, matchmaker_names,
)
from repro.core.simulation import Simulation
from repro.core.worker import Collector, LRUCache, Worker


def mk_problem(requests, demand, free, compat=None, order=None):
    requests = np.asarray(requests, dtype=np.float64)
    demand = np.asarray(demand, dtype=np.int64)
    free = np.asarray(free, dtype=np.float64)
    C, W = len(demand), len(free)
    if compat is None:
        compat = np.ones((C, W), dtype=bool)
    return MatchProblem(
        keys=[(0, i) for i in range(C)], requests=requests,
        demand=demand,
        order=np.arange(C, dtype=np.int64) if order is None
        else np.asarray(order, dtype=np.int64),
        free=free.copy(), capacity=free.copy(),
        compat=np.asarray(compat, dtype=bool))


def mk_pool(n_workers=3, cpus=4, matchmaker=None):
    col = Collector(matchmaker=matchmaker)
    for i in range(n_workers):
        w = Worker(name=f"w{i}", ad={"cpus": cpus, "memory": 16},
                   start_expr=ClassAdExpr("true"))
        w.booted_at = 0.0
        col.advertise(w)
    return col


def mk_queue(n=10, **ad):
    q = JobQueue()
    base = {"request_cpus": 1}
    base.update(ad)
    for i in range(n):
        q.submit(Job(ad=dict(base), runtime_s=60), float(i))
    return q


# -- registry / selection ----------------------------------------------------

def test_registry_lists_all_backends():
    names = matchmaker_names()
    assert {"numpy", "scan", "jax"} <= set(names)


def test_make_matchmaker_resolution():
    assert make_matchmaker().name == "numpy"
    assert make_matchmaker(None).name == "numpy"
    assert make_matchmaker("scan").name == "scan"
    inst = NumpyMatchmaker()
    assert make_matchmaker(inst) is inst
    with pytest.raises(ValueError, match="unknown matchmaker"):
        make_matchmaker("no-such-backend")
    with pytest.raises(TypeError):
        make_matchmaker(42)


def test_backends_satisfy_protocol():
    assert isinstance(NumpyMatchmaker(), Matchmaker)
    assert isinstance(ScanMatchmaker(), Matchmaker)


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
def test_jax_backend_config_validation():
    from repro.core.matchmaker import JaxMatchmaker
    assert isinstance(JaxMatchmaker(), Matchmaker)
    with pytest.raises(ValueError, match="dtype"):
        JaxMatchmaker(dtype="float16")


def test_collector_accepts_instance_and_name():
    assert mk_pool().matchmaker.name == "numpy"
    assert mk_pool(matchmaker="scan").matchmaker.name == "scan"
    inst = NumpyMatchmaker()
    assert Collector(matchmaker=inst).matchmaker is inst


def test_simulation_matchmaker_param_and_ini():
    cfg = ProvisionerConfig()
    sim = Simulation(cfg, nodes=[])
    assert sim.collector.matchmaker.name == "numpy"
    # the INI key flows through Simulation -> Collector
    cfg2 = load_ini("[provision]\nmatchmaker=scan\n")
    assert cfg2.matchmaker == "scan"
    sim2 = Simulation(cfg2, nodes=[])
    assert sim2.collector.matchmaker.name == "scan"
    # explicit arg wins over the config
    sim3 = Simulation(cfg2, nodes=[], matchmaker="numpy")
    assert sim3.collector.matchmaker.name == "numpy"
    # dump/load round-trip keeps the key
    assert load_ini(dump_ini(cfg2)).matchmaker == "scan"


# -- pure semantics ----------------------------------------------------------

def test_numpy_budget_and_active_masks():
    p = mk_problem(requests=[[1.0], [1.0]], demand=[5, 5], free=[[8.0]])
    mm = NumpyMatchmaker()
    full = mm.match(p)
    assert full.claimed == 8 and full.per_cohort().tolist() == [5, 3]
    capped = mm.match(p, budget=3)
    assert capped.claimed == 3 and capped.per_cohort().tolist() == [3, 0]
    only2 = mm.match(p, active=np.array([False, True]))
    assert only2.per_cohort().tolist() == [0, 5]
    # the problem is never mutated
    assert p.free.tolist() == [[8.0]] and p.demand.tolist() == [5, 5]


def test_plan_free_after_consistent():
    p = mk_problem(requests=[[2.0, 1.0]], demand=[3],
                   free=[[5.0, 10.0], [4.0, 1.0]])
    plan = NumpyMatchmaker().match(p)
    spent = plan.takes.T.astype(float) @ p.requests
    np.testing.assert_allclose(plan.free_after, p.free - spent)


def test_fits_eps_fractional_requests():
    # 7.6/0.4 is 18.999...96 in binary floats; the eps must count it 19
    p = mk_problem(requests=[[0.4]], demand=[30], free=[[7.6]])
    assert NumpyMatchmaker().match(p).claimed == 19


def test_plan_application_preserves_fifo_identity():
    """Claims land on FIFO jobs dealt to workers in index order — the
    exact (job, worker) pairs of the legacy walk."""
    col = mk_pool(n_workers=2, cpus=2)
    q = mk_queue(n=5)
    assert col.run_cycle(q, 0.0) == 4
    jid_to_worker = {j.jid: j.claimed_by
                     for j in q.jobs() if j.claimed_by}
    assert jid_to_worker == {0: "w0", 1: "w0", 2: "w1", 3: "w1"}


# -- deprecation shims (satellite 1) -----------------------------------------

def test_deprecated_shims_warn_and_delegate():
    col = mk_pool()
    q = mk_queue(n=6)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        n = col.negotiate(q, 0.0)
        col.preview_matches([q], 0.0)
        col.negotiate_scan(q, 0.0)
    assert n == 6
    cats = [r.category for r in rec]
    assert cats.count(DeprecationWarning) == 3
    assert "run_cycle" in str(rec[0].message)


def test_negotiate_cycle_alias_does_not_warn():
    col = mk_pool()
    q = mk_queue(n=3)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert col.negotiate_cycle([q], 0.0) == 3
    assert not [r for r in rec if r.category is DeprecationWarning]


# -- LRU caches (satellite 3) ------------------------------------------------

def test_lru_cache_eviction_order():
    c = LRUCache(3)
    for k in "abc":
        c.put(k, k.upper())
    assert c.get("a") == "A"          # refreshes a
    c.put("d", "D")                    # evicts b (least recent)
    assert "b" not in c
    assert "a" in c and "c" in c and "d" in c
    assert len(c) == 3


def test_lru_cache_invalidate_predicate():
    c = LRUCache(10)
    for i in range(6):
        c.put(("cohort", i % 2, i), i)
    assert c.invalidate(lambda k: k[1] == 0) == 3
    assert len(c) == 3
    assert c.invalidate() == 3
    assert len(c) == 0


def test_collector_match_cache_bounded_lru():
    col = mk_pool(n_workers=1)
    col._match_cache.maxsize = 2
    for i in range(4):
        q = mk_queue(n=1, request_memory=i + 1)
        col.preview([q], 0.0)
    assert len(col._match_cache) <= 2


def test_invalidate_cohort_drops_entries():
    col = mk_pool(n_workers=2)
    qa = mk_queue(n=2, request_memory=1)
    qb = mk_queue(n=2, request_memory=2)
    col.preview([qa], 0.0)
    col.preview([qb], 0.0)
    assert len(col._match_cache) == 2      # one per (cohort, shape)
    rep = next(iter(qa.idle_cohorts()))[0]
    assert col.invalidate_cohort(rep) == 1
    assert len(col._match_cache) == 1
    assert col.invalidate_cohort() == 1    # the rest
    assert len(col._match_cache) == 0


def test_snapshot_json_round_trips():
    """Plans/problems built by the collector survive a JSON round-trip of
    the summary path (the bench writes them out)."""
    col = mk_pool()
    q = mk_queue(n=4)
    prev = col.preview([q], 0.0)
    assert json.loads(json.dumps([{str(k): v for k, v in d.items()}
                                  for d in prev]))
