"""ClassAd expression language + symmetric matchmaking (paper C3)."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.classad import ClassAdExpr, UNDEFINED, symmetric_match


def test_basic_comparisons():
    e = ClassAdExpr("request_gpus >= 1 and request_memory <= 64")
    assert e.evaluate({"request_gpus": 2, "request_memory": 16})
    assert not e.evaluate({"request_gpus": 0, "request_memory": 16})


def test_paper_example_attributes():
    """Attributes from the paper's Fig 1 INI (GLIDEIN_Site etc.)."""
    e = ClassAdExpr('GLIDEIN_Site == "SDSC-PRP" and gpu_type in '
                    '("A100", "A40", "V100")')
    assert e.evaluate({"GLIDEIN_Site": "SDSC-PRP", "gpu_type": "A100"})
    assert not e.evaluate({"GLIDEIN_Site": "SDSC-PRP",
                           "gpu_type": "K80"})


def test_my_target_scoping():
    """HTCondor scoping: bare names resolve MY first, then TARGET."""
    e = ClassAdExpr("TARGET.cpus >= MY.request_cpus")
    assert e.evaluate({"request_cpus": 4}, {"cpus": 8})
    assert not e.evaluate({"request_cpus": 16}, {"cpus": 8})
    e2 = ClassAdExpr("cpus >= request_cpus")  # cpus only in target
    assert e2.evaluate({"request_cpus": 4}, {"cpus": 8})


def test_undefined_semantics():
    """Missing attributes are UNDEFINED: falsy, comparisons False."""
    e = ClassAdExpr("nonexistent_attr > 5")
    assert not e.evaluate({})
    assert not ClassAdExpr("nonexistent_attr == nonexistent_attr"
                           ).evaluate({})


def test_injection_rejected():
    for bad in ("().__class__", "open('/etc/passwd')",
                "[x for x in range(3)]", "lambda: 1",
                "__import__('os')", "my.__dict__",
                "nonexistent_attr is not None"):
        with pytest.raises(ValueError):
            ClassAdExpr(bad)


def test_empty_expr_vacuously_true():
    assert ClassAdExpr("").evaluate({"anything": 1})
    assert ClassAdExpr(None).evaluate({})
    assert ClassAdExpr("True").evaluate({})


@settings(max_examples=100, deadline=None)
@given(
    want=st.integers(0, 8), have=st.integers(0, 8),
    mem_w=st.integers(1, 64), mem_h=st.integers(1, 64),
)
def test_symmetric_match_resource_sanity(want, have, mem_w, mem_h):
    """Property: a job never matches an offer with fewer resources,
    regardless of expressions (the quantity guard)."""
    job = {"request_gpus": want, "request_memory": mem_w}
    offer = {"gpus": have, "memory": mem_h}
    ok = symmetric_match(job, offer)
    assert ok == (want <= have and mem_w <= mem_h)


def test_filter_pushdown_symmetry():
    """The SAME expression used provisioner-side (job ad as MY) and
    worker-side (worker ad as MY, job as TARGET) must agree on matches —
    the paper's C3 push-down guarantee."""
    flt = 'TARGET.arch == "mamba2-1.3b" if False else arch == "mamba2-1.3b"'
    f = ClassAdExpr('arch == "mamba2-1.3b"')
    job_good = {"arch": "mamba2-1.3b", "request_gpus": 1}
    job_bad = {"arch": "qwen3-32b", "request_gpus": 1}
    offer = {"gpus": 4}
    # provisioner side: evaluate over job ad
    assert f.evaluate(job_good)
    assert not f.evaluate(job_bad)
    # worker side: START expr, worker=MY, job=TARGET; arch missing from
    # worker ad so it resolves in TARGET (the job) — same verdicts
    assert symmetric_match(job_good, offer, start_expr=f)
    assert not symmetric_match(job_bad, offer, start_expr=f)
