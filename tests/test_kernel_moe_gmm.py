"""Grouped-matmul kernel vs oracle + tile-map properties (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.moe_gmm.kernel import gmm_pallas, tile_expert_map
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import expert_of_row, gmm_reference

CASES = [
    # E, K, N, BT, sizes (BT-aligned), tail padding rows
    (4, 256, 512, 128, [256, 128, 0, 384], 256),
    (2, 64, 64, 128, [128, 128], 0),
    (8, 128, 256, 128, [0, 0, 1024, 0, 0, 0, 0, 0], 128),
    (3, 100, 96, 64, [64, 192, 64], 64),   # unaligned K/N
]


@pytest.mark.parametrize("E,K,N,BT,sizes,tail", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_oracle(rng, E, K, N, BT, sizes, tail, dtype):
    T = sum(sizes) + tail
    lhs = jnp.asarray(rng.standard_normal((T, K)), dtype)
    rhs = jnp.asarray(rng.standard_normal((E, K, N)), dtype)
    gs = jnp.asarray(sizes, jnp.int32)
    out = gmm_pallas(lhs, rhs, gs, block_t=BT, interpret=True)
    ref = gmm_reference(lhs, rhs, gs)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_ops_xla_path_matches_oracle(rng):
    """ops.gmm on CPU routes to lax.ragged_dot; check against oracle with
    UNALIGNED group sizes (the kernel path requires alignment; the XLA
    path must not)."""
    E, K, N = 4, 32, 48
    sizes = [7, 0, 13, 21]
    T = sum(sizes) + 5
    lhs = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
    rhs = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
    gs = jnp.asarray(sizes, jnp.int32)
    out = gmm(lhs, rhs, gs)
    ref = gmm_reference(lhs, rhs, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    sizes=st.lists(st.integers(0, 8), min_size=1, max_size=8),
    bt=st.sampled_from([2, 4, 8]),
)
def test_tile_expert_map_property(sizes, bt):
    """Property: tile_expert_map agrees with expert_of_row at every tile
    start when groups are bt-aligned."""
    sizes_aligned = [s * bt for s in sizes]
    total = sum(sizes_aligned)
    n_tiles = max(1, (total + 2 * bt) // bt)
    gs = jnp.asarray(sizes_aligned, jnp.int32)
    tmap = np.asarray(tile_expert_map(gs, n_tiles, bt))
    emap = np.asarray(expert_of_row(gs, n_tiles * bt))
    for t in range(n_tiles):
        assert tmap[t] == emap[t * bt]
