"""Pool-service subsystem tests: wall-clock driver, in-process and HTTP
clients, runtime reconfiguration (drain/add backends and schedds), and
the drained-backend-schedules-zero-further-events regression."""
import sys
import time
import urllib.error
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.service import PoolClient, PoolService, WallClockDriver  # noqa: E402
from repro.service.http import serve_in_thread  # noqa: E402
from repro.service.pool import RemoteClient  # noqa: E402
from repro.workload.trace import TraceRecord  # noqa: E402

# small 2-provider federation so tests drain in well under a second of
# wall time when batch-driven
SERVICE_INI = """\
[provision]
submit_interval_s=30
idle_timeout_s=240
startup_delay_s=15

[backend:onprem]
kind=static
nodes=2
capacity_dict=cpu:8,gpu:4,memory:64,disk:256

[backend:cloud]
kind=autoscale
capacity_dict=cpu:8,gpu:4,memory:64,disk:256
max_nodes=4
node_hourly_cost=1.0
provision_delay_s=30
scale_down_delay_s=120
"""

BURST_INI = """\
[backend:burst]
kind=autoscale
capacity_dict=cpu:8,gpu:4,memory:64,disk:256
max_nodes=4
node_hourly_cost=1.0
provision_delay_s=30
scale_down_delay_s=120
"""


def rec(runtime_s=120.0, arrival_s=0.0, **kw):
    return TraceRecord(arrival_s=arrival_s, runtime_s=runtime_s, **kw)


def mk_service(**kw):
    kw.setdefault("tick_s", 5.0)
    kw.setdefault("negotiate_interval_s", 15.0)
    kw.setdefault("metrics_interval_s", 60.0)
    kw.setdefault("speed", None)
    return PoolService(SERVICE_INI, **kw)


# -- submission surface ------------------------------------------------------

def test_submit_now_runs_to_completion():
    svc = mk_service()
    c = PoolClient(svc)
    r = c.submit([rec(runtime_s=300.0) for _ in range(8)])
    assert len(r["jids"]) == 8
    assert c.job_status(r["jids"][0])["state"] in ("idle", "running")
    svc.run_until_drained()
    st = c.status()
    assert st["drained"]
    assert st["completed"] == 8
    assert c.job_status(r["jids"][0])["state"] == "completed"
    assert svc.completed_stats().n == 8


def test_at_trace_times_goes_through_pending_ledger():
    svc = mk_service()
    c = PoolClient(svc)
    r = c.submit([{"arrival_s": 100.0 * (i + 1), "runtime_s": 200.0}
                  for i in range(4)], at_trace_times=True, at=0.0)
    assert r["scheduled"] == 4
    st = c.status()
    assert st["pending_ops"] == 4
    assert not st["drained"]          # pending arrivals block drained
    svc.run_until_drained()
    st = c.status()
    assert st["pending_ops"] == 0
    assert st["drained"] and st["completed"] == 4


def test_rm_idle_and_running_job():
    svc = mk_service()
    c = PoolClient(svc)
    jids = c.submit([rec(runtime_s=5000.0) for _ in range(2)])["jids"]
    svc.sim.run(120.0)                # past startup: jobs are running
    assert c.job_status(jids[0])["state"] == "running"
    out = c.rm(jids[0])
    assert out["removed"]
    assert c.job_status(jids[0])["state"] == "removed"
    again = c.rm(jids[0])             # second rm: gone, terminal record
    assert not again["removed"]
    assert again["terminal"]["state"] == "removed"
    c.rm(jids[1])
    svc.run_until_drained()
    assert c.status()["drained"]
    assert svc.completed_stats().n == 0


def test_submit_validation_rejects_bad_record():
    svc = mk_service()
    with pytest.raises(Exception):
        svc.submit([{"arrival_s": 0.0, "runtime_s": -5.0}])


# -- wall-clock driver -------------------------------------------------------

def test_driver_paced_time_warp_drains_while_polling():
    svc = mk_service(speed=5000.0)
    c = PoolClient(svc)
    svc.start()
    try:
        assert svc.driver.running
        c.submit([rec(runtime_s=60.0) for _ in range(3)])
        deadline = time.monotonic() + 30.0
        st = {}
        while time.monotonic() < deadline:
            st = c.status()           # concurrent injection while running
            if st["drained"] and st["completed"] == 3:
                break
            time.sleep(0.02)
        assert st.get("drained") and st.get("completed") == 3, st
    finally:
        svc.stop()
    # graceful stop leaves the sim quiescent -> snapshot just works
    snap = svc.snapshot()
    assert snap["sim"]["t"] == svc.sim.now


def test_driver_as_fast_idles_when_drained():
    svc = mk_service(speed=None)
    c = PoolClient(svc)
    svc.start()
    try:
        c.submit([rec(runtime_s=60.0)])
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            if c.status()["drained"]:
                break
            time.sleep(0.02)
        t1 = c.status()["t"]
        time.sleep(0.25)
        t2 = c.status()["t"]
        # periodic timers alone must not spin the simulated clock
        assert t2 == t1
        # a late submission wakes it back up
        c.submit([rec(runtime_s=30.0)])
        deadline = time.monotonic() + 30.0
        st = {}
        while time.monotonic() < deadline:
            st = c.status()
            if st["drained"] and st["completed"] == 2:
                break
            time.sleep(0.02)
        assert st.get("completed") == 2
    finally:
        svc.stop()


def test_driver_inline_call_settles_fresh_sim():
    svc = mk_service()
    # a fresh sim has a full t=0 event group pending; call() must settle
    # it so an immediate snapshot sees a quiescent instant
    snap = svc.snapshot()
    assert snap["sim"]["t"] == 0.0


def test_driver_rejects_bad_speed():
    svc = mk_service()
    with pytest.raises(ValueError):
        WallClockDriver(svc.sim, speed=0.0)
    with pytest.raises(RuntimeError):
        svc.start()
        try:
            svc.start()               # double-start
        finally:
            svc.stop()


# -- HTTP surface ------------------------------------------------------------

def test_http_round_trip():
    svc = mk_service()
    server, url = serve_in_thread(svc)
    try:
        rc = RemoteClient(url)
        assert rc.healthz()["ok"]
        r = rc.submit([rec(runtime_s=300.0).to_obj() for _ in range(5)])
        assert len(r["jids"]) == 5
        svc.run_until_drained()
        st = rc.status()
        assert st["drained"] and st["completed"] == 5
        assert rc.job_status(r["jids"][0])["state"] == "completed"
        m = rc.metrics()
        for key in ("gauges", "backends", "series"):
            assert key in m
        for g in ("idle_jobs", "running_jobs", "provisioned_cores",
                  "cost_rate", "cost_total"):
            assert g in m["gauges"]
        for s in ("idle_jobs", "running_jobs", "provisioned_cores",
                  "cost_rate"):
            assert s in m["series"]
        with pytest.raises(urllib.error.HTTPError) as e404:
            rc._get("/no-such-route")
        assert e404.value.code == 404
        with pytest.raises(urllib.error.HTTPError) as e400:
            rc._post("/rm", {})       # missing jid -> KeyError -> 400
        assert e400.value.code == 400
    finally:
        server.shutdown()
        server.server_close()


# -- runtime reconfiguration -------------------------------------------------

def test_drained_backend_schedules_zero_further_events():
    """Satellite regression: once a backend is drained and detached, NO
    further events fire for it — no ticks, no heap entries."""
    svc = mk_service()
    c = PoolClient(svc)
    c.submit([rec(runtime_s=400.0) for _ in range(30)])
    svc.sim.run(300.0)                # let cloud scale up / claim work
    cloud = svc.sim.backend("cloud")
    c.drain_backend("cloud")
    assert cloud.draining and not cloud.healthy()
    svc.run_until_drained()
    # detach happens on the backend's next tick after its last pod ends
    svc.sim.run(svc.sim.now + 2 * svc.sim.tick_s)
    assert [b.name for b in svc.sim.detached_backends] == ["cloud"]
    assert all(b.name != "cloud" for b in svc.sim.backends)
    # instrument the detached backend and run well past several tick
    # cadences: it must never be ticked again
    calls = []
    cloud.tick = lambda *a, **kw: calls.append(a)
    live = [e for e in svc.sim.loop._heap
            if not e[3].cancelled and "backend:cloud" in (e[3].name or "")]
    assert live == []
    svc.sim.run(svc.sim.now + 20 * svc.sim.tick_s)
    assert calls == []
    # the detached backend still appears in the pool summary
    assert "cloud" in svc.sim.summary()["backends"]


def test_add_backend_at_runtime_rebases_billing():
    svc = mk_service()
    c = PoolClient(svc)
    c.submit([rec(runtime_s=600.0) for _ in range(40)])
    svc.sim.run(600.0)
    t_add = svc.sim.now
    r = c.add_backend(BURST_INI)
    assert r["added"] == ["burst"]
    b = svc.sim.backend("burst")
    assert b._cost_t == t_add         # no billing from epoch 0
    svc.run_until_drained()
    assert all(n.created_at >= t_add for n in b.cluster.nodes.values())
    assert svc.completed_stats().n == 40
    # duplicate add is refused
    with pytest.raises(ValueError):
        svc.add_backend(BURST_INI)


def test_add_drain_detach_schedd_at_runtime():
    svc = PoolService(SERVICE_INI, schedds=2, fairshare=True,
                      tick_s=5.0, negotiate_interval_s=15.0,
                      metrics_interval_s=60.0)
    c = PoolClient(svc)
    c.add_schedd("schedd-extra", quota=0.5)
    assert "schedd-extra" in svc.status()["schedds"]
    c.submit([rec(runtime_s=120.0) for _ in range(3)],
             schedd="schedd-extra")
    c.drain_schedd("schedd-extra")
    assert svc.status()["schedds"]["schedd-extra"]["draining"]
    with pytest.raises(ValueError):
        c.submit([rec()], schedd="schedd-extra")
    svc.run_until_drained()
    st = svc.status()
    assert st["drained"]
    assert st["schedds"]["schedd-extra"]["completed"] == 3
    svc.detach_schedd("schedd-extra")
    assert "schedd-extra" not in svc.status()["schedds"]


def test_deferred_drain_via_ledger():
    svc = mk_service()
    c = PoolClient(svc)
    c.submit([rec(runtime_s=300.0) for _ in range(10)])
    out = c.drain_backend("cloud", at=200.0)
    assert out["drain_at"] == 200.0
    assert svc.status()["pending_ops"] == 1
    svc.run_until_drained()
    assert [b.name for b in svc.sim.detached_backends] == ["cloud"]
    assert svc.status()["pending_ops"] == 0
