"""Unit tests for the dry-run machinery that don't need 512 devices."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    ARCH_NAMES, SHAPES, all_cells, get_config, input_specs,
)
from repro.launch.dryrun import collective_bytes_from_hlo


def test_collective_parser_counts_shapes():
    hlo = """
      %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={{0,1}}
      %ag = bf16[4,256]{1,0} all-gather(%y), dimensions={0}
      %aa = bf16[8,64]{1,0} all-to-all(%z)
      %rs = f32[2,32]{1,0} reduce-scatter(%w)
      %cp = s32[10]{0} collective-permute(%v)
      %addish = f32[9]{0} add(%a, %b)
    """
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 2 * 16 * 128 * 4      # 2x payload model
    assert out["all-gather"] == 4 * 256 * 2
    assert out["all-to-all"] == 8 * 64 * 2
    assert out["reduce-scatter"] == 2 * 32 * 4
    assert out["collective-permute"] == 10 * 4
    assert out["total"] == sum(
        v for k, v in out.items() if k != "total")


def test_collective_parser_start_ops():
    hlo = "%s = f32[4,4]{1,0} all-reduce-start(%x)"
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 2 * 16 * 4


def test_all_cells_is_40_with_6_skips():
    cells = list(all_cells())
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    assert len(skips) == 6
    skip_archs = {c[0] for c in skips}
    assert skip_archs == {
        "whisper-medium", "qwen2-1.5b", "starcoder2-7b", "granite-8b",
        "qwen3-32b", "llava-next-mistral-7b",
    }
    for _, cell, runs, reason in cells:
        if not runs:
            assert cell.name == "long_500k"
            assert reason


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    """Every runnable (arch × shape) produces consistent abstract inputs
    without allocating anything."""
    from repro.configs import applicable

    cfg = get_config(arch)
    cell = SHAPES[shape]
    runs, _ = applicable(cfg, cell)
    if not runs:
        pytest.skip("documented skip")
    specs = input_specs(cfg, cell)
    if cell.kind == "train":
        B, S = specs["tokens"].shape
        assert B == cell.global_batch
        if cfg.frontend is not None:
            assert S + cfg.frontend.n_prefix == cell.seq_len
        else:
            assert S == cell.seq_len
        assert specs["labels"].shape == specs["tokens"].shape
    elif cell.kind == "decode":
        assert specs["tokens_t"].shape == (cell.global_batch, 1)
        # cache leaves must be abstract (no allocation)
        leaves = jax.tree_util.tree_leaves(specs["cache"])
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        # total KV capacity matches the assignment's seq_len per layer
        if cfg.mixer_kind(0) == "attn":
            k0 = specs["cache"]["slot0"]["self"]["k"]
            assert k0.shape[2] == cfg.kv_cache_len(0, cell.seq_len)


def test_decode_cache_bytes_sane():
    """Long-context cells must not implicitly allocate: the abstract cache
    for maverick long_500k is ~34 GB GLOBAL — fine as ShapeDtypeStructs,
    and the dry-run shards it 256 ways."""
    cfg = get_config("llama4-maverick-400b-a17b")
    specs = input_specs(cfg, SHAPES["long_500k"])
    total = sum(
        l.size * jnp.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(specs["cache"])
    )
    assert 10e9 < total < 100e9  # sanity: dominated by 12 global layers
