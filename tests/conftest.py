"""Test config. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see 1 CPU device (the dry-run sets 512 in its own process)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
