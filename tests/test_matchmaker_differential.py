"""Differential property tests: every matchmaker backend is claim-for-
claim identical (ISSUE 6 satellite 4).

Three layers:
  * pure problems — seeded-random `MatchProblem`s solved by numpy/jax/
    scan, takes matrices compared exactly (plus hypothesis-driven
    variants when the package is installed);
  * end-to-end collector — identical pools negotiated with
    `matchmaker="numpy"` vs `"jax"`, the (jid -> worker) claim maps must
    coincide;
  * flocking fair-share — a 3-schedd federation with quotas and priority
    factors, water-filled on both backends: identical splits, identical
    accountant books.
"""
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.classad import ClassAdExpr
from repro.core.fairshare import Accountant, ScheddSpec
from repro.core.jobqueue import Job, JobQueue
from repro.core.matchmaker import (
    HAVE_JAX, HAVE_PALLAS, MatchPlan, MatchProblem, NumpyMatchmaker,
    ScanMatchmaker, make_matchmaker,
)
from repro.core.worker import Collector, Worker

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
needs_pallas = pytest.mark.skipif(not HAVE_PALLAS,
                                  reason="jax/pallas not installed")

R = 6   # RESOURCE_KEYS width; column 0 is cpus


def random_problem(rng, *, C=None, W=None, fractional=False,
                   sparse_compat=True, gpus=True):
    C = C if C is not None else int(rng.integers(1, 40))
    W = W if W is not None else int(rng.integers(1, 30))
    requests = np.zeros((C, R))
    requests[:, 0] = rng.integers(1, 5, size=C)            # cpus >= 1
    requests[:, 2] = rng.integers(0, 9, size=C)            # memory
    if gpus:
        requests[:, 1] = rng.integers(0, 3, size=C)
    if fractional:
        requests[:, 0] += rng.choice([0.0, 0.25, 0.5], size=C)
        requests[:, 2] *= 0.4
    demand = rng.integers(1, 60, size=C).astype(np.int64)
    free = np.zeros((W, R))
    free[:, 0] = rng.integers(1, 17, size=W)
    free[:, 2] = rng.integers(0, 65, size=W)
    if gpus:
        free[:, 1] = rng.integers(0, 9, size=W)
    if fractional:
        free[:, 2] *= 0.4
    compat = (rng.random((C, W)) < 0.8 if sparse_compat
              else np.ones((C, W), dtype=bool))
    order = rng.permutation(C).astype(np.int64)
    return MatchProblem(
        keys=[(0, i) for i in range(C)], requests=requests,
        demand=demand, order=order, free=free, capacity=free.copy(),
        compat=np.asarray(compat, dtype=bool))


def assert_plans_equal(a: MatchPlan, b: MatchPlan, label: str):
    assert a.takes.shape == b.takes.shape
    np.testing.assert_array_equal(a.takes, b.takes, err_msg=label)
    np.testing.assert_allclose(a.free_after, b.free_after, atol=1e-7,
                               err_msg=label)


# -- pure problems: numpy vs jax ---------------------------------------------

@needs_jax
@pytest.mark.parametrize("fractional", [False, True])
def test_jax_identical_on_random_problems(fractional):
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(7 + fractional)
    for trial in range(40):
        p = random_problem(rng, fractional=fractional)
        assert_plans_equal(ref.match(p), jaxmm.match(p),
                           f"trial={trial} fractional={fractional}")


@needs_jax
def test_jax_identical_under_budget_and_active():
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(11)
    for trial in range(25):
        p = random_problem(rng)
        budget = int(rng.integers(1, 1 + int(p.demand.sum())))
        active = rng.random(p.n_cohorts) < 0.6
        assert_plans_equal(ref.match(p, budget=budget),
                           jaxmm.match(p, budget=budget),
                           f"budget trial={trial}")
        assert_plans_equal(ref.match(p, active=active),
                           jaxmm.match(p, active=active),
                           f"active trial={trial}")
        assert_plans_equal(ref.match(p, budget=budget, active=active),
                           jaxmm.match(p, budget=budget, active=active),
                           f"both trial={trial}")


@needs_jax
def test_jax_padding_boundaries():
    """Cohort/worker counts straddling the chunk (256) and lane (128)
    buckets — padding rows must take nothing."""
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(13)
    for C in (1, 255, 256, 257):
        for W in (1, 127, 128, 129):
            p = random_problem(rng, C=C, W=W)
            assert_plans_equal(ref.match(p), jaxmm.match(p),
                               f"C={C} W={W}")


@needs_jax
def test_jax_drain_guard_exact_when_pool_exhausts():
    """Demand >> supply: later chunks are skipped by the drain guard —
    skipping must be claim-exact, including zero-CPU-request cohorts
    (they disarm the guard)."""
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(17)
    p = random_problem(rng, C=600, W=4)
    assert_plans_equal(ref.match(p), jaxmm.match(p), "drain")
    p2 = random_problem(rng, C=600, W=4)
    p2.requests[300:, 0] = 0.0       # zero-cpu cohorts in late chunks
    assert_plans_equal(ref.match(p2), jaxmm.match(p2), "drain+zero-cpu")


# -- pure problems: pallas water-fill kernel (interpret mode) ----------------

@needs_pallas
@pytest.mark.parametrize("fractional", [False, True])
def test_pallas_interpret_identical_on_random_problems(fractional):
    """The Pallas kernel in interpret mode (what CPU CI runs) must be
    bit-identical to BOTH the jax scan and the numpy reference — the
    same float64 arithmetic in a different program shape."""
    pmm = make_matchmaker("pallas")
    jaxmm = make_matchmaker("jax")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(31 + fractional)
    for trial in range(12):
        p = random_problem(rng, fractional=fractional)
        plan_p = pmm.match(p)
        label = f"trial={trial} fractional={fractional}"
        np.testing.assert_array_equal(ref.match(p).takes, plan_p.takes,
                                      err_msg=label)
        plan_j = jaxmm.match(p)
        np.testing.assert_array_equal(plan_j.takes, plan_p.takes,
                                      err_msg=label)
        np.testing.assert_array_equal(plan_j.free_after, plan_p.free_after,
                                      err_msg=label + " free (bitwise)")


@needs_pallas
def test_pallas_interpret_budget_and_drain():
    """Claim budgets thread through the kernel's VMEM scalar, and the
    in-kernel drain guard must skip chunks claim-exactly when the pool
    exhausts (demand >> supply)."""
    pmm = make_matchmaker("pallas")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(37)
    for trial in range(8):
        p = random_problem(rng)
        budget = int(rng.integers(1, 1 + int(p.demand.sum())))
        assert_plans_equal(ref.match(p, budget=budget),
                           pmm.match(p, budget=budget),
                           f"budget trial={trial}")
    p = random_problem(rng, C=600, W=4)
    assert_plans_equal(ref.match(p), pmm.match(p), "drain")


@needs_pallas
def test_pallas_padding_boundaries():
    """Chunk/lane bucket edges plus the kernel's own resource-axis pad
    (6 -> 8 sublanes) — padding lanes must never constrain a fit."""
    pmm = make_matchmaker("pallas")
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(41)
    for C in (1, 63, 64, 65):
        for W in (1, 127, 128, 129):
            p = random_problem(rng, C=C, W=W)
            assert_plans_equal(ref.match(p), pmm.match(p), f"C={C} W={W}")


@needs_pallas
def test_collector_run_cycle_pallas_equals_numpy():
    for seed in range(3):
        ca, qa = build_pool("numpy", rng_seed=seed)
        cb, qb = build_pool("pallas", rng_seed=seed)
        assert ca.run_cycle(qa, 0.0) == cb.run_cycle(qb, 0.0)
        assert claim_map(qa) == claim_map(qb), f"seed={seed}"


# -- pure problems: numpy vs scan oracle -------------------------------------

def test_scan_oracle_matches_reference_cohort_contiguous():
    """With jobs visited cohort-contiguously in processing order, the
    per-job oracle and the vectorized walk make identical claims
    (integer resources; the oracle never divides).

    Restricted to cpu+memory pools: the seed oracle retires a worker
    once ANY declared countable resource exhausts (a gpu slot out of
    gpus stops taking cpu-only jobs), which the cohort walk — and real
    partitionable slots — do not.  When cpus are the only exhaustible
    resource, retirement coincides with nothing-fits and the two are
    identical; that documented divergence is why the scan stays an
    oracle, not a backend for mixed pools."""
    scan = ScanMatchmaker()
    ref = NumpyMatchmaker()
    rng = np.random.default_rng(23)
    for trial in range(30):
        p = random_problem(rng, gpus=False)
        assert_plans_equal(ref.match(p), scan.match(p), f"trial={trial}")


# -- hypothesis variants (skip cleanly when not installed) -------------------

@needs_jax
@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       fractional=st.booleans())
def test_hypothesis_jax_identical(seed, fractional):
    rng = np.random.default_rng(seed)
    p = random_problem(rng, fractional=fractional)
    assert_plans_equal(NumpyMatchmaker().match(p),
                       make_matchmaker("jax").match(p),
                       f"seed={seed}")


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hypothesis_scan_identical(seed):
    rng = np.random.default_rng(seed)
    p = random_problem(rng, gpus=False)
    assert_plans_equal(NumpyMatchmaker().match(p),
                       ScanMatchmaker().match(p), f"seed={seed}")


# -- end-to-end collector differential ---------------------------------------

def build_pool(matchmaker, rng_seed=0, n_workers=12, n_jobs=200,
               gpus=True):
    rng = np.random.default_rng(rng_seed)
    col = Collector(matchmaker=matchmaker)
    for i in range(n_workers):
        ad = {"cpus": int(rng.integers(2, 17)),
              "memory": int(rng.integers(8, 65))}
        g = int(rng.integers(0, 5))
        if gpus and g:
            ad["gpus"] = g
        w = Worker(name=f"w{i:02d}", ad=ad,
                   start_expr=ClassAdExpr("true"))
        w.booted_at = 0.0
        col.advertise(w)
    q = JobQueue()
    for i in range(n_jobs):
        ad = {
            "request_cpus": int(rng.integers(1, 5)),
            "request_memory": int(rng.integers(1, 9)),
            "user": f"u{int(rng.integers(0, 4))}",
        }
        g = int(rng.integers(0, 2))
        if gpus and g:
            ad["request_gpus"] = g
        q.submit(Job(ad=ad, runtime_s=60), float(i))
    return col, q


def claim_map(q):
    return {j.jid: j.claimed_by for j in q.jobs() if j.claimed_by}


@needs_jax
def test_collector_run_cycle_jax_equals_numpy():
    for seed in range(5):
        ca, qa = build_pool("numpy", rng_seed=seed)
        cb, qb = build_pool("jax", rng_seed=seed)
        na = ca.run_cycle(qa, 0.0)
        nb = cb.run_cycle(qb, 0.0)
        assert na == nb
        assert claim_map(qa) == claim_map(qb), f"seed={seed}"


def test_collector_run_cycle_scan_backend_equals_numpy():
    # cpu/memory pools only: see the scan-oracle docstring above
    for seed in range(3):
        ca, qa = build_pool("numpy", rng_seed=seed, gpus=False)
        cb, qb = build_pool("scan", rng_seed=seed, gpus=False)
        assert ca.run_cycle(qa, 0.0) == cb.run_cycle(qb, 0.0)
        assert claim_map(qa) == claim_map(qb), f"seed={seed}"


# -- flocking fair-share on both backends ------------------------------------

def build_federation(matchmaker, rng_seed=1):
    rng = np.random.default_rng(rng_seed)
    specs = [ScheddSpec(name="osg", quota=3.0,
                        priority_factors={"heavy": 4.0}),
             ScheddSpec(name="cms", quota=1.0),
             ScheddSpec(name="icecube", quota=2.0)]
    acct = Accountant()
    col = Collector(matchmaker=matchmaker)
    for i in range(16):
        w = Worker(name=f"w{i:02d}", ad={"cpus": 4, "memory": 32},
                   start_expr=ClassAdExpr("true"))
        w.booted_at = 0.0
        col.advertise(w)
    queues = []
    for spec in specs:
        q = JobQueue(name=spec.name)
        acct.set_quota(spec.name, spec.quota)
        for u, f in spec.priority_factors.items():
            acct.set_priority_factor(u, f)
        acct.attach_queue(spec.name, q)
        for i in range(40):
            q.submit(Job(ad={
                "request_cpus": int(rng.integers(1, 3)),
                "request_memory": int(rng.integers(1, 5)),
                "user": rng.choice(["alice", "bob", "heavy"]),
            }, runtime_s=300), float(i))
        queues.append(q)
    return col, queues, acct


@needs_jax
def test_flocking_fairshare_jax_equals_numpy():
    ca, qsa, aa = build_federation("numpy")
    cb, qsb, ab = build_federation("jax")
    na = ca.run_cycle(qsa, 0.0, accountant=aa, quantum=2)
    nb = cb.run_cycle(qsb, 0.0, accountant=ab, quantum=2)
    assert na == nb and na > 0
    for qa, qb in zip(qsa, qsb):
        assert claim_map(qa) == claim_map(qb), qa.name
    # identical books: same rates, same effective priorities
    sa, sb = aa.snapshot(0.0), ab.snapshot(0.0)
    assert sa == sb


@needs_jax
def test_flocking_fairshare_split_respects_quotas_both_backends():
    """The 3:1:2-quota pool split must come out identical (and quota-
    proportional) on both backends."""
    for mm in ("numpy", "jax"):
        col, queues, acct = build_federation(mm, rng_seed=3)
        col.run_cycle(queues, 0.0, accountant=acct, quantum=1)
        by_schedd = [sum(1 for j in q.jobs() if j.claimed_by)
                     for q in queues]
        if mm == "numpy":
            ref_split = by_schedd
        else:
            assert by_schedd == ref_split
        assert by_schedd[0] > by_schedd[1]    # quota 3 beats quota 1
