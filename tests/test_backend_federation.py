"""Backend-federation API: routing policies, `[backend:*]` INI parsing,
per-backend stats attribution, the single-backend compatibility adapter,
and the node-autoscaler headroom fix."""
import os
import subprocess
import sys

import pytest

from repro.core import (
    BackendConfig, KubeBackend, KubeCluster, Node, NodeAutoscaler,
    NodeTemplate, Pod, Provisioner, ProvisionerConfig, Simulation,
    build_backends, dump_ini, gpu_job, load_ini, make_routing_policy,
    onprem_nodes,
)
from repro.core.groups import GroupSignature

GPU1 = {"cpu": 1.0, "gpu": 1.0, "memory": 4.0, "disk": 8.0}


def static_backend(name, n_nodes=2, gpus=8, **kw):
    cluster = KubeCluster(
        onprem_nodes(n_nodes, gpus=gpus, prefix=name), name=name)
    return KubeBackend(name, cluster, **kw)


def elastic_backend(name, *, gpus=7, max_nodes=8, hourly=2.5, spot=False,
                    **kw):
    cluster = KubeCluster([], name=name)
    tmpl = NodeTemplate(
        capacity={"cpu": 64, "gpu": gpus, "memory": 512, "disk": 1024},
        provision_delay_s=60, scale_down_delay_s=120, hourly_cost=hourly)
    scaler = NodeAutoscaler(cluster, tmpl, max_nodes=max_nodes,
                            prefix=f"{name}-np")
    return KubeBackend(name, cluster, scaler, spot=spot, **kw)


def alloc_map(alloc):
    return {b.name: k for b, k in alloc}


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------

def test_fill_first_respects_declaration_order():
    onprem = static_backend("onprem", n_nodes=2, gpus=8)   # 16 slots
    cloud = elastic_backend("cloud")
    pol = make_routing_policy("fill-first")
    alloc = alloc_map(pol.split(10, GPU1, [onprem, cloud], 0.0))
    assert alloc == {"onprem": 10}


def test_fill_first_overflows_to_next_backend():
    onprem = static_backend("onprem", n_nodes=1, gpus=2)   # 2 slots
    cloud = elastic_backend("cloud")
    pol = make_routing_policy("fill-first")
    alloc = alloc_map(pol.split(10, GPU1, [onprem, cloud], 0.0))
    assert alloc == {"onprem": 2, "cloud": 8}


def test_cheapest_first_beats_declaration_order():
    cloud = elastic_backend("cloud", hourly=2.5)
    onprem = static_backend("onprem", n_nodes=2, gpus=8)   # sunk cost
    # fill-first would pick the cloud (declared first)...
    fill = alloc_map(make_routing_policy("fill-first").split(
        10, GPU1, [cloud, onprem], 0.0))
    assert fill == {"cloud": 10}
    # ...cheapest-first routes to the free on-prem capacity
    cheap = alloc_map(make_routing_policy("cheapest-first").split(
        10, GPU1, [cloud, onprem], 0.0))
    assert cheap == {"onprem": 10}
    assert onprem.marginal_pod_cost(GPU1) < cloud.marginal_pod_cost(GPU1)


def test_spot_with_fallback_prefers_spot_then_falls_back():
    ondemand = elastic_backend("ondemand", hourly=2.0)
    spot = elastic_backend("spot", hourly=0.5, spot=True, max_nodes=1)
    pol = make_routing_policy("spot-with-fallback")
    alloc = alloc_map(pol.split(10, GPU1, [ondemand, spot], 0.0))
    # spot absorbs one node's worth (7), the rest falls back to on-demand
    assert alloc == {"spot": 7, "ondemand": 3}


def test_spot_overflow_queues_on_fallback_not_spot():
    ondemand = elastic_backend("ondemand", max_nodes=1)    # 7 slots
    spot = elastic_backend("spot", spot=True, max_nodes=1)  # 7 slots
    pol = make_routing_policy("spot-with-fallback")
    alloc = alloc_map(pol.split(20, GPU1, [ondemand, spot], 0.0))
    # 6 pods exceed all headroom -> they queue on the reliable backend
    assert alloc == {"spot": 7, "ondemand": 3 + 4 + 6}


def test_weighted_spread_is_proportional():
    a = static_backend("a", n_nodes=4, gpus=8)
    b = static_backend("b", n_nodes=4, gpus=8)
    a.weight, b.weight = 3.0, 1.0
    pol = make_routing_policy("weighted-spread")
    alloc = alloc_map(pol.split(8, GPU1, [a, b], 0.0))
    assert alloc == {"a": 6, "b": 2}


def test_unknown_routing_policy_rejected():
    with pytest.raises(ValueError):
        make_routing_policy("round-robin-of-doom")


def test_headroom_accounts_for_pending_and_caps():
    b = static_backend("onprem", n_nodes=1, gpus=4)
    assert b.headroom(GPU1) == 4
    for i in range(3):
        b.cluster.create_pod(
            Pod(name=f"p{i}", request=dict(GPU1),
                labels={"owner": "prp-provisioner"}), now=0.0)
    assert b.headroom(GPU1) == 1          # 4 free minus 3 queued
    b.max_pods = 3
    assert b.headroom(GPU1) == 0          # provider-level pod cap


# ---------------------------------------------------------------------------
# [backend:*] INI parsing round-trip
# ---------------------------------------------------------------------------

FEDERATION_INI = """\
[provision]
submit_interval_s=30
idle_timeout_s=120
startup_delay_s=30
routing_policy=cheapest-first

[k8s]
priority_class=opportunistic

[backend:onprem]
kind=static
nodes=2
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024
node_labels_dict=gpu-type:A100

[backend:cloud]
kind=autoscale
capacity_dict=cpu:64,gpu:7,memory:512,disk:1024
max_nodes=6
node_hourly_cost=2.5
provision_delay_s=60
scale_down_delay_s=120
priority_class=production

[backend:spot]
kind=autoscale
spot=true
capacity_dict=cpu:64,gpu:8,memory:512,disk:1024
max_nodes=8
node_hourly_cost=0.8
pod_hourly_cost=0.05
weight=2.0
"""


def test_multibackend_ini_parses():
    cfg = load_ini(FEDERATION_INI)
    assert cfg.routing_policy == "cheapest-first"
    assert [b.name for b in cfg.backends] == ["onprem", "cloud", "spot"]
    onprem, cloud, spot = cfg.backends
    assert onprem.kind == "static" and onprem.nodes == 2
    assert onprem.node_labels == {"gpu-type": "A100"}
    assert cloud.kind == "autoscale" and cloud.max_nodes == 6
    assert cloud.node_hourly_cost == 2.5
    assert cloud.priority_class == "production"
    assert spot.spot is True and spot.weight == 2.0
    assert spot.pod_hourly_cost == 0.05


def test_ini_roundtrip_through_dump():
    cfg = load_ini(FEDERATION_INI)
    cfg2 = load_ini(dump_ini(cfg))
    assert cfg2.backends == cfg.backends
    assert cfg2.routing_policy == cfg.routing_policy
    assert cfg2.max_total_pods == cfg.max_total_pods
    assert cfg2.priority_class == cfg.priority_class


def test_paper_fig1_ini_still_single_backend():
    from repro.core import PAPER_EXAMPLE_INI
    cfg = load_ini(PAPER_EXAMPLE_INI)
    assert cfg.backends == ()             # Fig-1 format: default backend
    assert cfg.routing_policy == "fill-first"
    sim = Simulation.from_config(cfg, nodes=onprem_nodes(1, gpus=8))
    assert len(sim.backends) == 1 and sim.backends[0].name == "default"


def test_build_backends_materializes_sections():
    cfg = load_ini(FEDERATION_INI)
    backends = build_backends(cfg)
    assert [b.name for b in backends] == ["onprem", "cloud", "spot"]
    assert len(backends[0].cluster.nodes) == 2          # static pool, t=0
    assert backends[1].autoscaler is not None
    assert backends[1].autoscaler.max_nodes == 6
    assert backends[2].spot and backends[2].autoscaler is not None


# ---------------------------------------------------------------------------
# End-to-end federation + per-backend stats attribution
# ---------------------------------------------------------------------------

def test_federated_simulation_attributes_stats_per_backend():
    cfg = load_ini(FEDERATION_INI)
    cfg.routing_policy = "fill-first"
    # shrink on-prem so demand spills into the cloud
    cfg.backends[0].nodes = 1
    cfg.backends = (cfg.backends[0],
                    dataclass_with(cfg.backends[1], max_nodes=4))
    sim = Simulation.from_config(cfg, tick_s=5)
    sim.submit_jobs(0, [gpu_job(300, gpus=1) for _ in range(20)])
    sim.run_until_drained(max_t=20000)
    assert sim.queue.drained()
    per = sim.provisioner.stats.per_backend_submitted
    assert per.get("onprem", 0) > 0 and per.get("cloud", 0) > 0
    assert sum(per.values()) == sim.provisioner.stats.submitted
    s = sim.summary()
    assert set(s["backends"]) == {"onprem", "cloud"}
    assert s["backends"]["cloud"]["cost"] > 0       # billed nodes ran
    assert s["backends"]["onprem"]["cost"] == 0     # sunk/donated
    assert s["backends"]["onprem"]["waste_fraction"] == 0.0
    assert 0 <= s["backends"]["cloud"]["waste_fraction"] < 1
    assert (s["backends"]["onprem"]["pods_submitted"]
            + s["backends"]["cloud"]["pods_submitted"]
            == s["pods_submitted"])
    # per-backend recorder series exist in multi-backend mode
    assert set(sim.recorder.backends_recorded()) == {"onprem", "cloud"}
    assert sim.recorder.backend_values("live_pods", "cloud")


def dataclass_with(bc, **kw):
    import dataclasses
    return dataclasses.replace(bc, **kw)


def test_spot_reclaim_is_survivable_and_attributed():
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30,
                            routing_policy="spot-with-fallback")
    ondemand = elastic_backend("ondemand", hourly=2.0, max_nodes=4)
    spot = elastic_backend("spot", hourly=0.5, spot=True, max_nodes=4)
    sim = Simulation(cfg, backends=[ondemand, spot], tick_s=5)
    sim.submit_jobs(0, [gpu_job(400, gpus=1) for _ in range(10)])
    sim.inject_pod_preemption(300, frac=0.5, backend="spot")
    sim.run_until_drained(max_t=30000)
    assert sim.queue.drained()
    assert spot.stats.pods_reclaimed >= 1
    assert spot.stats.pods_submitted > 0        # spot was preferred
    s = sim.summary()
    assert s["jobs"]["n"] == 10
    assert s["backends"]["spot"]["pods_reclaimed"] >= 1


# ---------------------------------------------------------------------------
# Single-backend compatibility adapter
# ---------------------------------------------------------------------------

def test_bare_cluster_still_accepted_by_provisioner():
    from repro.core import Collector, JobQueue
    cluster = KubeCluster(onprem_nodes(2, gpus=8))
    prov = Provisioner(ProvisionerConfig(), JobQueue(), Collector(),
                       cluster)
    assert len(prov.backends) == 1
    assert prov.cluster is cluster          # compat property
    assert prov.backends[0].name == "default"


def test_seed_simulation_signature_unchanged():
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    sim = Simulation(cfg, nodes=onprem_nodes(2, gpus=8), tick_s=5)
    assert sim.cluster is sim.backends[0].cluster
    assert sim.autoscaler is None
    sim.submit_jobs(0, [gpu_job(200, gpus=1) for _ in range(4)])
    sim.run_until_drained(max_t=10000)
    assert sim.queue.drained()
    sim.run(sim.now + 500)                  # let idle timeouts expire
    assert not sim.collector.workers        # C2 scale-to-zero intact
    s = sim.summary()
    assert s["backends"]["default"]["pods_submitted"] == s["pods_submitted"]


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------

def test_group_label_stable_across_hash_seeds():
    """builtin hash() is salted per-process; labels must not be, or a
    provisioner restart orphans every pending pod's group count."""
    snippet = (
        "from repro.core import Collector, JobQueue, KubeCluster, "
        "Provisioner, ProvisionerConfig\n"
        "from repro.core.groups import GroupSignature\n"
        "p = Provisioner(ProvisionerConfig(), JobQueue(), Collector(), "
        "KubeCluster([]))\n"
        "print(p._pod_group_label(GroupSignature(cpus=2, gpus=1, "
        "arch='x86_64')))\n"
    )
    labels = set()
    for hash_seed in ("0", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=hash_seed)
        env["PYTHONPATH"] = (
            "src" + os.pathsep + env.get("PYTHONPATH", ""))
        out = subprocess.run(
            [sys.executable, "-c", snippet], env=env, cwd=os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, check=True)
        labels.add(out.stdout.strip())
    assert len(labels) == 1 and labels.pop().startswith("grp-")


def test_autoscaler_counts_live_headroom_before_booting_nodes():
    """Regression: freshly-submitted pods that FIT existing free capacity
    must not boot spurious nodes while the scheduler hasn't placed them."""
    cluster = KubeCluster([], name="cloud")
    tmpl = NodeTemplate(capacity={"cpu": 64, "gpu": 7, "memory": 512,
                                  "disk": 1024},
                        provision_delay_s=0, scale_down_delay_s=600)
    scaler = NodeAutoscaler(cluster, tmpl, max_nodes=8)
    cluster.add_node(Node(name="np-0", capacity=dict(tmpl.capacity)),
                     now=0.0)
    for i in range(7):      # exactly one live node's worth of pods
        cluster.create_pod(Pod(name=f"p{i}", request=dict(GPU1)), now=0.0)
    assert scaler._nodes_needed() == 0
    cluster.create_pod(Pod(name="p7", request=dict(GPU1)), now=0.0)
    assert scaler._nodes_needed() == 1      # true overflow still scales


def test_autoscaler_seeding_respects_taints_and_selectors():
    """A pod blocked from live nodes by taints/affinity must still drive
    a scale-up — free capacity it can never use is not headroom."""
    cluster = KubeCluster([], name="cloud")
    tmpl = NodeTemplate(capacity={"cpu": 64, "gpu": 7, "memory": 512,
                                  "disk": 1024},
                        provision_delay_s=0, scale_down_delay_s=600)
    scaler = NodeAutoscaler(cluster, tmpl, max_nodes=8)
    cluster.add_node(
        Node(name="dedicated-0", capacity={"cpu": 64, "gpu": 7,
                                           "memory": 512, "disk": 1024},
             taints=("dedicated",)),
        now=0.0)
    cluster.create_pod(Pod(name="p0", request=dict(GPU1)), now=0.0)
    assert scaler._nodes_needed() == 1      # can't use the tainted node
    cluster.create_pod(
        Pod(name="p1", request=dict(GPU1),
            node_selector={"zone": "east"}), now=0.0)
    # selector misses the live node too, but p1 shares p0's NEW node bin
    assert scaler._nodes_needed() == 1
    cluster.create_pod(
        Pod(name="p2", request=dict(GPU1), tolerations=("dedicated",)),
        now=0.0)
    assert scaler._nodes_needed() == 1      # tolerating pod rides free cap


def test_federationwide_preemption_attributes_reclaims():
    cfg = ProvisionerConfig(submit_interval_s=30, idle_timeout_s=120,
                            startup_delay_s=30)
    a = static_backend("a", n_nodes=1, gpus=4)
    b = static_backend("b", n_nodes=1, gpus=4)
    sim = Simulation(cfg, backends=[a, b], tick_s=5)
    sim.submit_jobs(0, [gpu_job(400, gpus=1) for _ in range(8)])
    sim.inject_pod_preemption(200, frac=1.0)      # no backend arg
    sim.run_until_drained(max_t=20000)
    assert sim.queue.drained()
    assert a.stats.pods_reclaimed + b.stats.pods_reclaimed >= 1


def test_autoscaler_no_spurious_node_when_pods_unplaced():
    cluster = KubeCluster([], name="cloud")
    tmpl = NodeTemplate(capacity={"cpu": 64, "gpu": 7, "memory": 512,
                                  "disk": 1024},
                        provision_delay_s=0, scale_down_delay_s=600)
    scaler = NodeAutoscaler(cluster, tmpl, max_nodes=8)
    for i in range(7):
        cluster.create_pod(Pod(name=f"p{i}", request=dict(GPU1)), now=0.0)
    scaler.tick(0.0, 5.0)       # books exactly one node
    scaler.tick(5.0, 5.0)       # node is live, pods still PENDING here:
    # a second tick before the scheduler runs must not double-provision
    assert scaler.provisioned_total == 1
