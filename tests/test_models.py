"""Per-arch smoke tests (reduced configs, CPU) + serving consistency.

The strongest integration check: prefill + token-by-token decode must
reproduce the teacher-forced forward logits for every architecture family
(attention KV caches, SSM states, rolling windows, cross-attention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, reduced_config
from repro.data.pipeline import stub_modality_inputs
from repro.models import model as model_lib
from repro.models.param import materialize

ATOL = 2e-2  # fp32 reduced configs; chunked-vs-dense attention reorders sums


def _params(cfg, seed=0):
    return materialize(model_lib.init_model(cfg), jax.random.PRNGKey(seed))


def _batch(cfg, rng, B=2, S=32):
    St = S - (cfg.frontend.n_prefix if cfg.frontend else 0)
    toks = rng.integers(0, cfg.vocab_size, size=(B, St + 1))
    batch = {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }
    for k, v in stub_modality_inputs(cfg, B).items():
        batch[k] = jnp.asarray(v)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced_config(arch)
    params = _params(cfg)
    batch = _batch(cfg, rng)
    logits, aux = model_lib.forward(params, cfg, batch, remat="none")
    St = batch["tokens"].shape[1]
    assert logits.shape == (2, St, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step_decreases_nothing_nan(arch, rng):
    """One SGD-ish step must produce finite loss/grads (per-arch smoke)."""
    cfg = reduced_config(arch)
    params = _params(cfg)
    batch = _batch(cfg, rng)

    def loss(p):
        return model_lib.loss_fn(p, cfg, batch, remat="none")[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = sum(
        float(jnp.sum(jnp.square(g.astype(jnp.float32))))
        for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # one step in the -grad direction lowers the loss (sanity of autodiff)
    lr = 1e-2
    p2 = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    l1 = loss(p2)
    assert float(l1) < float(l0) + 1e-3, (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch, rng):
    """Greedy decode continuation from a prefix must produce the same
    logits as the teacher-forced forward pass at those positions."""
    cfg = reduced_config(arch)
    params = _params(cfg)
    B, S = 1, 24
    batch = _batch(cfg, rng, B=B, S=S)
    tokens = batch["tokens"]
    St = tokens.shape[1]
    n_pre = St // 2

    # teacher-forced logits for the whole sequence
    full_logits, _ = model_lib.forward(params, cfg, batch, remat="none")

    # prefill the first half, then decode with the *same* ground-truth
    # tokens and compare logits position by position
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :n_pre]
    pre_batch.pop("labels")
    cache = model_lib.init_cache(cfg, B, S + 64)
    logits, cache, lengths = model_lib.prefill(params, cfg, pre_batch,
                                               cache)
    prefix = cfg.frontend.n_prefix if cfg.frontend else 0
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, n_pre - 1]),
        atol=ATOL, rtol=ATOL)

    for t in range(n_pre, St):
        tok = tokens[:, t - 1:t]  # careful: feed gt token t-1? no:
        # decode_step consumes the token AT position (prefix+t) which is
        # tokens[:, t]; its output logits predict position t+1.
        tok = tokens[:, t:t + 1]
        logits, cache, lengths = model_lib.decode_step(
            params, cfg, tok, cache, lengths)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            atol=ATOL, rtol=ATOL,
            err_msg=f"{arch}: decode logits diverge at position {t}")


def test_vlm_prefix_handling(rng):
    """VLM: patches prepend to the sequence; logits cover text only."""
    cfg = reduced_config("llava-next-mistral-7b")
    params = _params(cfg)
    batch = _batch(cfg, rng, B=2, S=24)
    logits, _ = model_lib.forward(params, cfg, batch, remat="none")
    assert logits.shape[1] == batch["tokens"].shape[1]


def test_remat_consistency(rng):
    """remat=full/none must give identical losses (same math)."""
    cfg = reduced_config("granite-8b")
    params = _params(cfg)
    batch = _batch(cfg, rng)
    l_none = model_lib.loss_fn(params, cfg, batch, remat="none")[0]
    l_full = model_lib.loss_fn(params, cfg, batch, remat="full")[0]
    np.testing.assert_allclose(float(l_none), float(l_full), rtol=1e-5)


def test_window_attention_limits_context(rng):
    """llama4-style local layers: tokens beyond the window must not
    influence the output (checked via the config's kv_cache_len)."""
    cfg = reduced_config("llama4-scout-17b-a16e")
    assert cfg.attn_window == 16
    # local layer capacity == window; global layer capacity == seq
    assert cfg.kv_cache_len(0, 64) == 16       # local layer
    g = cfg.global_attn_every - 1
    assert cfg.kv_cache_len(g, 64) == 64       # global layer
